//! End-to-end validation driver (EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real workload —
//!
//!   L1 Pallas kernels → lowered inside the L2 HLO artifacts →
//!   executed through the PJRT runtime → driven by the L3 coordinator
//!   over a byte-metered ring of 8 node threads.
//!
//! Trains the CNN with C-ECL (10%) on the heterogeneous split for a few
//! hundred communication rounds, logging the full loss/accuracy curve,
//! then cross-checks the two dual-update paths (native vs the L1 kernel
//! through PJRT) give identical learning trajectories.
//!
//! ```bash
//! cargo run --release --example end_to_end            # full run
//! cargo run --release --example end_to_end -- --fast  # CI-sized
//! ```

use cecl::prelude::*;
use cecl::algorithms::DualPath;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let epochs = if fast { 4 } else { 30 };
    let graph = Graph::ring(8);

    let mut spec = ExperimentSpec {
        dataset: "fashion".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: true,
        },
        partition: Partition::Heterogeneous { classes_per_node: 8 },
        epochs,
        eval_every: 2,
        verbose: true,
        ..ExperimentSpec::default()
    };

    println!("== end-to-end: C-ECL(10%) / heterogeneous / ring(8) ==");
    println!("   epochs={epochs} (10 batches/epoch/node, K=5 → {} rounds)",
             epochs * 2);
    let report = run_experiment(&spec, &graph)?;
    println!("\nloss/accuracy curve:");
    println!("{}", report.history.to_table().render());
    println!(
        "final acc {:.1}% | best {:.1}% | {:.0} KB/node/epoch | {:.1}s",
        report.final_accuracy * 100.0,
        report.best_accuracy * 100.0,
        report.mean_bytes_per_epoch / 1024.0,
        report.wallclock_secs
    );
    report
        .history
        .to_table()
        .write_csv(cecl::experiments::results_dir().join("end_to_end.csv"))?;

    // Cross-path check: the PJRT (L1 Pallas kernel) dual path must
    // reproduce the native path's trajectory exactly (same masks, same
    // arithmetic, modulo f32 associativity).
    println!("\n== cross-path check: DualPath::Pjrt vs ::Native ==");
    spec.epochs = 2;
    spec.eval_every = 1;
    spec.verbose = false;
    spec.dual_path = DualPath::Native;
    let native = run_experiment(&spec, &graph)?;
    spec.dual_path = DualPath::Pjrt;
    let pjrt = run_experiment(&spec, &graph)?;
    let a = native.history.final_accuracy();
    let b = pjrt.history.final_accuracy();
    println!("native acc {a:.4} vs pjrt acc {b:.4}");
    anyhow::ensure!(
        (a - b).abs() < 5e-3,
        "dual paths diverged: native {a} vs pjrt {b}"
    );
    println!("OK: L1-kernel path matches the native hot path.");
    Ok(())
}
