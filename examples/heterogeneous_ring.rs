//! The paper's headline scenario (§5.2, Table 2): heterogeneous data
//! (each node holds 8 of 10 classes) on a ring of 8 nodes.
//!
//! Runs D-PSGD (the uncompressed gossip baseline), ECL, and C-ECL (10%)
//! and prints a mini Table-2: on heterogeneous data the primal-dual
//! methods should hold their accuracy while D-PSGD degrades, and C-ECL
//! should get there with a fraction of the bytes.
//!
//! ```bash
//! cargo run --release --example heterogeneous_ring
//! ```

use cecl::prelude::*;
use cecl::util::table::{kb_with_ratio, Table};

fn main() -> anyhow::Result<()> {
    let graph = Graph::ring(8);
    let methods = [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: true,
        },
    ];
    let mut reports = Vec::new();
    for alg in methods {
        let spec = ExperimentSpec {
            dataset: "fashion".into(),
            algorithm: alg.clone(),
            partition: Partition::Heterogeneous { classes_per_node: 8 },
            epochs: 12,
            eval_every: 4,
            ..ExperimentSpec::default()
        };
        eprintln!("running {} ...", alg.name());
        reports.push(run_experiment(&spec, &graph)?);
    }
    let baseline = reports[0].mean_bytes_per_epoch;
    let mut t = Table::new(["method", "best acc", "send/epoch"]);
    for r in &reports {
        t.row([
            r.algorithm.clone(),
            format!("{:.1}%", r.best_accuracy * 100.0),
            kb_with_ratio(r.mean_bytes_per_epoch, baseline),
        ]);
    }
    println!("\nheterogeneous ring(8), fashion-scale:\n{}", t.render());
    Ok(())
}
