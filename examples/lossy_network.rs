//! The scenario the byte tables only hint at: what compression buys in
//! *time* when the network is slow, lossy, and partially down.
//!
//! Runs D-PSGD, ECL, C-ECL (10%), and two codec variants (4-bit QSGD,
//! error-feedback top-k) on a 16-node ring under the virtual-time
//! engine with a 20 Mbit/s, 1 ms, 5%-drop link, a 4× straggler, and a
//! mid-run outage on one edge — entirely artifact-free (native softmax
//! backend), so it works on a bare checkout:
//!
//! ```bash
//! cargo run --release --example lossy_network
//! ```
//!
//! Expect all three methods to land at similar accuracy while C-ECL's
//! smaller messages finish the same schedule in a fraction of the
//! simulated time, with proportionally fewer retransmitted bytes.

use cecl::prelude::*;
use cecl::util::table::Table;

fn main() -> anyhow::Result<()> {
    let nodes = 16;
    let graph = Graph::ring(nodes);

    // One edge goes down for half a simulated second early in the run;
    // node 3 computes at quarter speed throughout.
    let mut outages = OutageSchedule::new();
    outages.add(0, 100_000_000, 600_000_000);
    let scenario = SimConfig {
        link: LinkSpec::Lossy {
            latency_us: 1_000,
            mbit_per_sec: 20.0,
            drop_p: 0.05,
        },
        compute_ns_per_step: 2_000_000, // 2 ms per local step
        stragglers: vec![(3, 4.0)],
        outages,
    };

    let methods = [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
        // The codec ladder: a 4-bit quantizer and error-feedback top-k
        // (both run the Eq. 11 dual rule automatically).
        AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse("qsgd:4").unwrap(),
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse("ef+top_k:0.1").unwrap(),
            theta: 1.0,
            dense_first_epoch: false,
        },
    ];

    let mut t = Table::new([
        "method",
        "final acc",
        "sim time (s)",
        "KB/node/epoch",
        "retrans KB",
    ]);
    for alg in methods {
        let spec = ExperimentSpec {
            dataset: "fashion".into(),
            algorithm: alg,
            epochs: 6,
            nodes,
            train_per_node: 200,
            test_size: 200,
            local_steps: 5,
            eta: 0.05,
            eval_every: 2,
            seed: 42,
            exec: ExecMode::Simulated(scenario.clone()),
            ..ExperimentSpec::default()
        };
        eprintln!("simulating {} ...", spec.algorithm.name());
        let r = run_simulated_native(&spec, &graph)?;
        t.row([
            r.algorithm.clone(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.2}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
            format!("{:.0}", r.retransmit_bytes as f64 / 1024.0),
        ]);
    }
    println!(
        "\nring({nodes}), lossy 20 Mbit/s / 1 ms / 5% drop, straggler x4, \
         one edge down 0.1s-0.6s:\n"
    );
    println!("{}", t.render());
    println!(
        "C-ECL ships ~an order of magnitude fewer bytes than the dense \
         methods, which on this link turns directly into less simulated \
         time to the same accuracy."
    );
    Ok(())
}
