//! The scenario the byte tables only hint at: what compression buys in
//! *time* when the network is slow, lossy, and partially down.
//!
//! Runs D-PSGD, ECL, C-ECL (10%), and two codec variants (4-bit QSGD,
//! error-feedback top-k) on a 16-node ring under the virtual-time
//! engine with a 20 Mbit/s, 1 ms, 5%-drop link, one 10×-latency edge
//! (heterogeneous per-edge links), a 4× straggler, and a mid-run
//! outage on one edge — entirely artifact-free (native softmax
//! backend), so it works on a bare checkout.  C-ECL(10%) runs twice:
//! under classic sync rounds and under gossip-style `async:2` rounds,
//! which hide the slow edge and the straggler inside the staleness
//! budget:
//!
//! ```bash
//! cargo run --release --example lossy_network
//! ```
//!
//! Expect all three methods to land at similar accuracy while C-ECL's
//! smaller messages finish the same schedule in a fraction of the
//! simulated time, with proportionally fewer retransmitted bytes.

use cecl::prelude::*;
use cecl::util::table::Table;

fn main() -> anyhow::Result<()> {
    let nodes = 16;
    let graph = Graph::ring(nodes);

    // One edge suffers an OUTAGE (traffic held, state preserved) for
    // half a simulated second early in the run, and a different edge
    // CHURNS out (state torn down, in-flight frames dropped, re-add is
    // a fresh edge epoch) for a window in the middle; node 3 computes
    // at quarter speed throughout; edge 7 is a 10 ms outlier link
    // (per-edge override) on an otherwise 1 ms network.
    let mut churn = ChurnSchedule::new();
    churn.add_outage(0, 100_000_000, 600_000_000);
    churn.add_edge_down(3, 300_000_000, 900_000_000);
    let scenario = SimConfig {
        link: LinkSpec::Lossy {
            latency_us: 1_000,
            mbit_per_sec: 20.0,
            drop_p: 0.05,
        },
        edge_links: vec![(
            7,
            LinkSpec::Lossy {
                latency_us: 10_000,
                mbit_per_sec: 20.0,
                drop_p: 0.05,
            },
        )],
        compute_ns_per_step: 2_000_000, // 2 ms per local step
        stragglers: vec![(3, 4.0)],
        churn,
        ..SimConfig::default()
    };

    let methods = [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
        // The codec ladder: a 4-bit quantizer and error-feedback top-k
        // (both run the Eq. 11 dual rule automatically).
        AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse("qsgd:4").unwrap(),
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse("ef+top_k:0.1").unwrap(),
            theta: 1.0,
            dense_first_epoch: false,
        },
    ];

    let mut t = Table::new([
        "method",
        "rounds",
        "final acc",
        "sim time (s)",
        "max lag",
        "churned",
        "chdrops",
        "KB/node/epoch",
        "retrans KB",
    ]);
    // Every method under sync rounds, plus C-ECL(10%) again under
    // bounded-staleness async rounds.
    let runs: Vec<(AlgorithmSpec, RoundPolicy)> = methods
        .iter()
        .cloned()
        .map(|m| (m, RoundPolicy::Sync))
        .chain(std::iter::once((
            AlgorithmSpec::CEcl {
                k_frac: 0.10,
                theta: 1.0,
                dense_first_epoch: false,
            },
            RoundPolicy::Async { max_staleness: 2 },
        )))
        .collect();
    for (alg, rounds) in runs {
        let spec = ExperimentSpec {
            dataset: "fashion".into(),
            algorithm: alg,
            epochs: 6,
            nodes,
            train_per_node: 200,
            test_size: 200,
            local_steps: 5,
            eta: 0.05,
            eval_every: 2,
            seed: 42,
            exec: ExecMode::Simulated(scenario.clone()),
            rounds,
            ..ExperimentSpec::default()
        };
        eprintln!("simulating {} ({}) ...", spec.algorithm.name(),
                  rounds.name());
        let r = run_simulated_native(&spec, &graph)?;
        t.row([
            r.algorithm.clone(),
            rounds.name(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.2}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{}", r.max_staleness),
            format!("{}", r.edges_churned),
            format!("{}", r.frames_dropped_by_churn),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
            format!("{:.0}", r.retransmit_bytes as f64 / 1024.0),
        ]);
    }
    println!(
        "\nring({nodes}), lossy 20 Mbit/s / 1 ms / 5% drop, one 10 ms edge, \
         straggler x4, one outage 0.1s-0.6s, one churned edge 0.3s-0.9s:\n"
    );
    println!("{}", t.render());
    println!(
        "C-ECL ships ~an order of magnitude fewer bytes than the dense \
         methods, which on this link turns directly into less simulated \
         time to the same accuracy; async:2 rounds additionally hide the \
         slow edge and the straggler inside the staleness budget."
    );
    Ok(())
}
