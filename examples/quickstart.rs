//! Quickstart: train C-ECL (10%) on a ring of 8 nodes for a few epochs
//! and print accuracy + communication cost.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use cecl::prelude::*;

fn main() -> anyhow::Result<()> {
    let graph = Graph::ring(8);
    let spec = ExperimentSpec {
        dataset: "fashion".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: true,
        },
        epochs: 6,
        eval_every: 2,
        verbose: true,
        ..ExperimentSpec::default()
    };
    let report = run_experiment(&spec, &graph)?;
    println!(
        "\n{}: final accuracy {:.1}%, best {:.1}%, sent {:.0} KB/node/epoch \
         ({:.1}s wallclock)",
        report.algorithm,
        report.final_accuracy * 100.0,
        report.best_accuracy * 100.0,
        report.mean_bytes_per_epoch / 1024.0,
        report.wallclock_secs,
    );
    Ok(())
}
