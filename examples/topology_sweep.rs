//! §5.3 scenario: how accuracy and communication cost vary with the
//! network topology (chain → ring → multiplex ring → fully connected).
//!
//! ```bash
//! cargo run --release --example topology_sweep
//! ```

use cecl::prelude::*;
use cecl::graph::Topology;
use cecl::util::table::Table;

fn main() -> anyhow::Result<()> {
    let alg = AlgorithmSpec::CEcl {
        k_frac: 0.10,
        theta: 1.0,
        dense_first_epoch: true,
    };
    let mut t = Table::new(["topology", "degree range", "best acc",
                            "send/epoch KB"]);
    for topology in Topology::paper_set() {
        let graph = Graph::build(topology, 8);
        let spec = ExperimentSpec {
            dataset: "fashion".into(),
            algorithm: alg.clone(),
            partition: Partition::Heterogeneous { classes_per_node: 8 },
            epochs: 8,
            eval_every: 4,
            ..ExperimentSpec::default()
        };
        eprintln!("running {} ...", topology.name());
        let report = run_experiment(&spec, &graph)?;
        t.row([
            topology.name().to_string(),
            format!(
                "[{}, {}]",
                graph.min_degree().unwrap_or(0),
                graph.max_degree().unwrap_or(0)
            ),
            format!("{:.1}%", report.best_accuracy * 100.0),
            format!("{:.0}", report.mean_bytes_per_epoch / 1024.0),
        ]);
    }
    println!("\nC-ECL (10%), heterogeneous, by topology:\n{}", t.render());
    Ok(())
}
