"""AOT pipeline: lower the L2/L1 functions once to HLO *text* artifacts.

HLO text (not serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.

Outputs, per dataset config (``fashion``, ``cifar``):

* ``train_step_<ds>.hlo.txt``  — Eq. (6) closed-form local update
* ``eval_<ds>.hlo.txt``        — correct count + summed loss
* ``dual_update_<ds>.hlo.txt`` — fused L1 Pallas compressed dual update
* ``init_w_<ds>.bin``          — raw little-endian f32[d_pad] initial params

plus ``smoke.hlo.txt`` (a tiny function for fast runtime unit tests) and
``manifest.txt`` describing shapes/layout for the rust side.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig
from .kernels.dual_update import dual_update

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    return name


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_train_step(cfg: ModelConfig) -> str:
    fn = functools.partial(model.train_step, cfg)
    lowered = jax.jit(fn).lower(
        _f32(cfg.d_pad),                                  # w
        _f32(cfg.d_pad),                                  # zsum
        _f32(cfg.batch, cfg.height, cfg.width, cfg.channels),
        _i32(cfg.batch),
        _f32(),                                           # eta
        _f32(),                                           # alpha_deg
    )
    return to_hlo_text(lowered)


def lower_eval_step(cfg: ModelConfig) -> str:
    fn = functools.partial(model.eval_step, cfg)
    lowered = jax.jit(fn).lower(
        _f32(cfg.d_pad),
        _f32(cfg.eval_batch, cfg.height, cfg.width, cfg.channels),
        _i32(cfg.eval_batch),
    )
    return to_hlo_text(lowered)


def lower_dual_update(cfg: ModelConfig) -> str:
    def fn(z, w, ycomp, m_in, m_out, theta, taa):
        return dual_update(z, w, ycomp, m_in, m_out, theta, taa)

    d = cfg.d_pad
    lowered = jax.jit(fn).lower(
        _f32(d), _f32(d), _f32(d), _f32(d), _f32(d), _f32(), _f32()
    )
    return to_hlo_text(lowered)


def lower_smoke() -> str:
    def fn(x, y):
        return (x * y + 1.0,)

    lowered = jax.jit(fn).lower(_f32(4), _f32(4))
    return to_hlo_text(lowered)


def write_init_w(cfg: ModelConfig, out_dir: str, seed: int = 0) -> str:
    w = model.init_params(cfg, seed=seed)
    name = f"init_w_{cfg.name}.bin"
    import numpy as np

    np.asarray(w, dtype="<f4").tofile(os.path.join(out_dir, name))
    return name


def build(out_dir: str, datasets=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    datasets = datasets or list(CONFIGS)
    lines = [f"version {MANIFEST_VERSION}"]
    smoke = _write(out_dir, "smoke.hlo.txt", lower_smoke())
    lines.append(f"smoke {smoke}")
    for name in datasets:
        cfg = CONFIGS[name]
        print(f"[aot] {cfg.name}: d={cfg.d} d_pad={cfg.d_pad} "
              f"input={cfg.height}x{cfg.width}x{cfg.channels}")
        train = _write(out_dir, f"train_step_{cfg.name}.hlo.txt",
                       lower_train_step(cfg))
        print(f"[aot]   train_step -> {train}")
        evalf = _write(out_dir, f"eval_{cfg.name}.hlo.txt",
                       lower_eval_step(cfg))
        print(f"[aot]   eval_step  -> {evalf}")
        dual = _write(out_dir, f"dual_update_{cfg.name}.hlo.txt",
                      lower_dual_update(cfg))
        print(f"[aot]   dual_update-> {dual}")
        init = write_init_w(cfg, out_dir)
        print(f"[aot]   init_w     -> {init}")
        lines.append(f"dataset {cfg.name}")
        lines.append(f"d {cfg.d}")
        lines.append(f"d_pad {cfg.d_pad}")
        lines.append(f"input {cfg.height} {cfg.width} {cfg.channels}")
        lines.append(f"classes {cfg.classes}")
        lines.append(f"batch {cfg.batch}")
        lines.append(f"eval_batch {cfg.eval_batch}")
        lines.append(f"train_step {train}")
        lines.append(f"eval_step {evalf}")
        lines.append(f"dual_update {dual}")
        lines.append(f"init_w {init}")
        for spec in cfg.layers():
            dims = " ".join(str(s) for s in spec.shape)
            lines.append(f"layer {spec.name} {dims}")
        lines.append("end")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[aot] manifest -> {os.path.join(out_dir, 'manifest.txt')}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--datasets", nargs="*", default=None,
                        help="subset of dataset configs to build")
    args = parser.parse_args()
    build(args.out, args.datasets)


if __name__ == "__main__":
    main()
