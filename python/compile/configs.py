"""Model / dataset configurations shared by the L2 model and the AOT pipeline.

Two dataset-scale configs mirror the paper's two benchmarks:

* ``fashion`` — FashionMNIST-shaped: 1x28x28 greyscale, 10 classes.
* ``cifar``   — CIFAR10-shaped: 3x32x32 colour, 10 classes.

The model is the paper's architecture family: a 5-learnable-layer CNN with
GroupNorm (3 conv + GN blocks, then 2 dense layers), width scaled to the
CPU-PJRT budget of this sandbox (see DESIGN.md §2 for the substitution
rationale — communication-cost *ratios* and the accuracy ordering across
methods are what the paper's tables measure, and both are dimension-free).

All parameters live in a single flat ``f32[d_pad]`` vector.  ``d_pad`` is
``d`` rounded up to ``PAD_MULTIPLE`` so that the L1 Pallas dual-update
kernel sees block-aligned shapes; the tail is mathematically inert (zero
gradients, zero dual state).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

# Flat vectors are padded to a multiple of this so the Pallas dual-update
# kernel's (8, 128) blocks tile exactly.
PAD_MULTIPLE = 1024

# Pallas matmul tile sizes (MXU-shaped: 128x128 systolic array).
MATMUL_BLOCK_N = 128
MATMUL_BLOCK_K = 128


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One named parameter tensor within the flat vector."""

    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A dataset-scale instantiation of the 5-layer CNN + GroupNorm."""

    name: str
    height: int
    width: int
    channels: int
    classes: int
    batch: int
    eval_batch: int
    conv_channels: Tuple[int, int, int]
    hidden: int
    gn_groups: int

    # ---- derived ---------------------------------------------------------

    @property
    def spatial_after_convs(self) -> Tuple[int, int]:
        """conv2 and conv3 are stride-2 SAME: H -> ceil(H/2) -> ceil(H/4)."""
        h = -(-self.height // 2)
        h = -(-h // 2)
        w = -(-self.width // 2)
        w = -(-w // 2)
        return h, w

    @property
    def flat_features(self) -> int:
        h, w = self.spatial_after_convs
        return h * w * self.conv_channels[2]

    def layers(self) -> List[LayerSpec]:
        """Parameter layout, in flat-vector order.

        Conv kernels are HWIO (the jax.lax default for NHWC convs); dense
        kernels are (in, out).  GroupNorm has per-channel scale and bias.
        """
        c1, c2, c3 = self.conv_channels
        specs = [
            LayerSpec("conv1_w", (3, 3, self.channels, c1)),
            LayerSpec("conv1_b", (c1,)),
            LayerSpec("gn1_scale", (c1,)),
            LayerSpec("gn1_bias", (c1,)),
            LayerSpec("conv2_w", (3, 3, c1, c2)),
            LayerSpec("conv2_b", (c2,)),
            LayerSpec("gn2_scale", (c2,)),
            LayerSpec("gn2_bias", (c2,)),
            LayerSpec("conv3_w", (3, 3, c2, c3)),
            LayerSpec("conv3_b", (c3,)),
            LayerSpec("gn3_scale", (c3,)),
            LayerSpec("gn3_bias", (c3,)),
            LayerSpec("dense1_w", (self.flat_features, self.hidden)),
            LayerSpec("dense1_b", (self.hidden,)),
            LayerSpec("dense2_w", (self.hidden, self.classes)),
            LayerSpec("dense2_b", (self.classes,)),
        ]
        return specs

    @property
    def d(self) -> int:
        return sum(s.size for s in self.layers())

    @property
    def d_pad(self) -> int:
        return -(-self.d // PAD_MULTIPLE) * PAD_MULTIPLE


# Width (6, 12, 24)/48 is the 1-CPU-budget point: ~2x faster per train
# step than (8, 16, 32)/64 with the same architecture and phenomena (see
# DESIGN.md §2 — the paper's table quantities are ratio- and
# ordering-based, not parameter-count-based).
FASHION = ModelConfig(
    name="fashion",
    height=28,
    width=28,
    channels=1,
    classes=10,
    batch=50,
    eval_batch=100,
    conv_channels=(6, 12, 24),
    hidden=48,
    gn_groups=4,
)

CIFAR = ModelConfig(
    name="cifar",
    height=32,
    width=32,
    channels=3,
    classes=10,
    batch=50,
    eval_batch=100,
    conv_channels=(6, 12, 24),
    hidden=48,
    gn_groups=4,
)

CONFIGS = {c.name: c for c in (FASHION, CIFAR)}
