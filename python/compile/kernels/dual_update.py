"""L1 Pallas kernel: fused C-ECL compressed dual update.

This is the paper's per-edge hot spot (Alg. 1 lines 4 & 9).  The unfused
jnp chain reads ``z`` three times and ``w``/``ycomp``/masks once each and
writes two outputs, with intermediates materialized between ops; the fused
kernel makes exactly one pass: each (8, 128) block of the five operands is
staged in VMEM once, both outputs are produced from registers, one write
each.

TPU mapping (DESIGN.md §Hardware-Adaptation): the flat ``f32[d_pad]``
vectors are viewed as ``(d_pad/1024, 8, 128)`` — an (8, 128) VPU-register
tile per grid step, ``BlockSpec`` expressing the HBM->VMEM schedule that a
CUDA port would express with threadblocks over a 1-D grid.  VMEM residency
per step is 5 inputs + 2 outputs = 7 blocks x 4 KiB = 28 KiB, far under
the ~16 MiB VMEM budget, so the kernel is purely HBM-bandwidth bound
(arithmetic intensity ~= 5 flops / 28 bytes).

Runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the lowered HLO is what the rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One grid step processes BLOCK_ROWS x BLOCK_LANES elements = 1024 f32.
BLOCK_ROWS = 8
BLOCK_LANES = 128
BLOCK_ELEMS = BLOCK_ROWS * BLOCK_LANES


def _dual_update_kernel(theta_ref, taa_ref, z_ref, w_ref, yc_ref, mi_ref,
                        mo_ref, znew_ref, ysend_ref):
    """Fused elementwise body for one (8, 128) block.

    theta / two_alpha_a arrive as scalar-prefetch-style (1, 1) blocks so a
    single lowered module serves every (theta, alpha, edge-sign) setting —
    the rust coordinator feeds them per edge at call time.
    """
    theta = theta_ref[0, 0]
    taa = taa_ref[0, 0]
    z = z_ref[...]
    # Eq. 4: y_{i|j} = z_{i|j} - 2 alpha A_{i|j} w, A folded into taa.
    y_send = z - taa * w_ref[...]
    ysend_ref[...] = mo_ref[...] * y_send
    # Eq. 13 via Assumption-1 linearity: comp(y - z) = comp(y) - m*z.
    znew_ref[...] = z + theta * (yc_ref[...] - mi_ref[...] * z)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dual_update(z, w, ycomp_in, m_in, m_out, theta, two_alpha_a,
                interpret=True):
    """Fused dual update over flat f32[d_pad] vectors.

    Args:
      z, w, ycomp_in, m_in, m_out: f32[d_pad] with d_pad % 1024 == 0.
      theta: scalar relaxation parameter of the Douglas-Rachford splitting.
      two_alpha_a: scalar ``2 * alpha * a`` where ``a = +-1`` is A_{i|j}.

    Returns:
      (z_new, y_send_comp): both f32[d_pad].
    """
    d = z.shape[0]
    if d % BLOCK_ELEMS != 0:
        raise ValueError(f"d_pad={d} must be a multiple of {BLOCK_ELEMS}")
    blocks = d // BLOCK_ELEMS
    shape3 = (blocks, BLOCK_ROWS, BLOCK_LANES)

    def as3(v):
        return v.reshape(shape3)

    theta_arr = jnp.asarray(theta, jnp.float32).reshape(1, 1)
    taa_arr = jnp.asarray(two_alpha_a, jnp.float32).reshape(1, 1)

    scalar_spec = pl.BlockSpec((1, 1), lambda b: (0, 0))
    block_spec = pl.BlockSpec((1, BLOCK_ROWS, BLOCK_LANES),
                              lambda b: (b, 0, 0))

    znew, ysend = pl.pallas_call(
        _dual_update_kernel,
        grid=(blocks,),
        in_specs=[scalar_spec, scalar_spec] + [block_spec] * 5,
        out_specs=[block_spec, block_spec],
        out_shape=[
            jax.ShapeDtypeStruct(shape3, jnp.float32),
            jax.ShapeDtypeStruct(shape3, jnp.float32),
        ],
        interpret=interpret,
    )(theta_arr, taa_arr, as3(z), as3(w), as3(ycomp_in), as3(m_in),
      as3(m_out))
    return znew.reshape(d), ysend.reshape(d)
