"""L1 Pallas kernel: MXU-tiled matmul for the dense classifier head.

The CNN's two dense layers (``dense1``: features -> hidden, ``dense2``:
hidden -> classes) route their GEMMs through this kernel, so the L1 layer
lowers into the very same HLO module as the L2 model (one artifact, no
graph breaks).

TPU mapping (DESIGN.md §Hardware-Adaptation): classic systolic-array
tiling — grid ``(n_blocks, k_blocks)`` with a ``(B, 128)`` activation
block, a ``(128, 128)`` weight block (the MXU's native tile), and a
``(B, 128)`` output accumulator that stays resident in VMEM across the
K-loop (revisited output block, initialized at k == 0).  A CUDA version
would stage the same tiles in shared memory per threadblock; here the
HBM<->VMEM schedule is the two BlockSpec index_maps.

Runs under ``interpret=True`` for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import MATMUL_BLOCK_K, MATMUL_BLOCK_N


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (n, k) grid step: o[n] (+)= x[k] @ w[k, n]."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(v, axis, multiple):
    size = v.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return v
    widths = [(0, 0)] * v.ndim
    widths[axis] = (0, pad)
    return jnp.pad(v, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x, w, interpret=True):
    """``x[B, K] @ w[K, N]`` via the tiled Pallas kernel.

    K and N are zero-padded up to the 128-multiple tile grid; the result is
    sliced back to ``(B, N)``.  B rides along whole (it is small — the
    training batch) as the tile's sublane dimension.
    """
    b, k_dim = x.shape
    k2, n_dim = w.shape
    if k_dim != k2:
        raise ValueError(f"shape mismatch: {x.shape} @ {w.shape}")
    x32 = _pad_to(x.astype(jnp.float32), 1, MATMUL_BLOCK_K)
    w32 = _pad_to(
        _pad_to(w.astype(jnp.float32), 0, MATMUL_BLOCK_K), 1, MATMUL_BLOCK_N
    )
    kp, np_ = w32.shape
    grid = (np_ // MATMUL_BLOCK_N, kp // MATMUL_BLOCK_K)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, MATMUL_BLOCK_K), lambda n, k: (0, k)),
            pl.BlockSpec((MATMUL_BLOCK_K, MATMUL_BLOCK_N),
                         lambda n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((b, MATMUL_BLOCK_N), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((b, np_), jnp.float32),
        interpret=interpret,
    )(x32, w32)
    return out[:, :n_dim]


# ---------------------------------------------------------------------------
# Differentiable wrapper: pallas_call has no built-in reverse-mode rule, so
# the backward GEMMs (dx = g @ wᵀ, dw = xᵀ @ g) are routed through the very
# same tiled kernel — the L1 layer stays on both the forward and backward
# paths of the lowered training artifact.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul_ad(x, w):
    """Differentiable ``x @ w`` backed by the Pallas kernel."""
    return matmul(x, w)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(residual, g):
    x, w = residual
    return matmul(g, w.T), matmul(x.T, g)


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)
