"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops.  ``python/tests`` asserts
``allclose(kernel, ref)`` over hypothesis-generated shape/dtype sweeps —
this is the core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp


def dual_update_ref(z, w, ycomp_in, m_in, m_out, theta, two_alpha_a):
    """Oracle for the fused C-ECL dual update (Alg. 1 lines 4 & 9).

    Given the per-edge dual state ``z = z_{i|j}``, the local model ``w``,
    the received compressed dual ``ycomp_in = comp(y_{j|i}; w_{i|j})``
    (dense representation: masked-out entries are zero), the inbound mask
    ``m_in`` and outbound mask ``m_out`` (0/1 vectors), computes

        y_send      = z - two_alpha_a * w              (Eq. 4, A_{i|j} folded
                                                        into two_alpha_a = 2*alpha*a)
        y_send_comp = m_out * y_send                   (what gets transmitted)
        z_new       = z + theta * (ycomp_in - m_in*z)  (Eq. 13 via Assumption-1
                                                        linearity: comp(y - z)
                                                        = comp(y) - comp(z))

    With ``m_in = m_out = 1`` this is exactly the uncompressed ECL update
    ``z_new = (1-theta) z + theta y_recv`` (Eq. 5).
    """
    y_send = z - two_alpha_a * w
    y_send_comp = m_out * y_send
    z_new = z + theta * (ycomp_in - m_in * z)
    return z_new, y_send_comp


def matmul_ref(x, w):
    """Oracle for the tiled Pallas matmul: plain jnp matmul in f32."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
