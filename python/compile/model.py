"""L2: the paper's model and update steps in JAX, over a flat parameter vector.

Implements the 5-layer CNN with GroupNorm used in the paper's §5 (scaled —
DESIGN.md §2) plus the three jit-able entry points that the rust
coordinator executes through PJRT:

* ``train_step`` — one local update of Eq. (6) in closed form.  Because
  ``A_{i|j} = ±I`` and ``A² = I``, setting the gradient of the quadratic
  surrogate to zero gives

      w⁺ = (w/η − ∇f(w) + Σ_j A_{i|j} z_{i|j}) / (1/η + α·|N_i|)

  With ``alpha_deg = α·|N_i| = 0`` and ``zsum = 0`` this is exactly the
  plain SGD step ``w − η∇f(w)`` — one artifact serves ECL, C-ECL, D-PSGD
  and single-node SGD.
* ``eval_step`` — correct-prediction count + summed loss over a batch.
* the L1 Pallas kernels (``kernels.matmul`` inside the dense head here,
  ``kernels.dual_update`` as its own artifact) lower into the same HLO.

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once and the rust runtime never imports Python again.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.matmul import matmul_ad


def unpack(cfg: ModelConfig, wflat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat f32[d_pad] vector into named parameter tensors.

    The padding tail (entries d..d_pad) is ignored; its gradient is
    therefore exactly zero and it stays inert through training.
    """
    params = {}
    offset = 0
    for spec in cfg.layers():
        chunk = jax.lax.dynamic_slice_in_dim(wflat, offset, spec.size)
        params[spec.name] = chunk.reshape(spec.shape)
        offset += spec.size
    return params


def pack(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Inverse of :func:`unpack`; zero-pads up to d_pad."""
    flat = jnp.concatenate(
        [params[spec.name].reshape(-1) for spec in cfg.layers()]
    )
    return jnp.pad(flat, (0, cfg.d_pad - cfg.d))


def group_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               groups: int, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm over NHWC: normalize each (H, W, C/G) group per sample.

    The group count is the largest divisor of C not exceeding ``groups``
    (so any channel width is valid).
    """
    b, h, w, c = x.shape
    g = max(d for d in range(1, min(groups, c) + 1) if c % d == 0)
    xg = x.reshape(b, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


def _conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
          stride: int) -> jnp.ndarray:
    """3x3 SAME conv, NHWC x HWIO."""
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def forward(cfg: ModelConfig, wflat: jnp.ndarray,
            x: jnp.ndarray) -> jnp.ndarray:
    """Logits for a batch ``x: f32[B, H, W, C]``."""
    p = unpack(cfg, wflat)
    h = _conv(x, p["conv1_w"], p["conv1_b"], stride=1)
    h = jax.nn.relu(group_norm(h, p["gn1_scale"], p["gn1_bias"],
                               cfg.gn_groups))
    h = _conv(h, p["conv2_w"], p["conv2_b"], stride=2)
    h = jax.nn.relu(group_norm(h, p["gn2_scale"], p["gn2_bias"],
                               cfg.gn_groups))
    h = _conv(h, p["conv3_w"], p["conv3_b"], stride=2)
    h = jax.nn.relu(group_norm(h, p["gn3_scale"], p["gn3_bias"],
                               cfg.gn_groups))
    h = h.reshape(h.shape[0], -1)
    # Dense head routed through the L1 Pallas matmul kernel.
    h = jax.nn.relu(matmul_ad(h, p["dense1_w"]) + p["dense1_b"])
    return matmul_ad(h, p["dense2_w"]) + p["dense2_b"]


def _cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-sample softmax cross-entropy; ``y: i32[B]`` class indices."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(
        logits, y[:, None].astype(jnp.int32), axis=-1
    )[:, 0]
    return logz - true_logit


def loss_fn(cfg: ModelConfig, wflat: jnp.ndarray, x: jnp.ndarray,
            y: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy loss — the f_i(w) of Eq. (1)."""
    return _cross_entropy(forward(cfg, wflat, x), y).mean()


def train_step(cfg: ModelConfig, wflat: jnp.ndarray, zsum: jnp.ndarray,
               x: jnp.ndarray, y: jnp.ndarray, eta: jnp.ndarray,
               alpha_deg: jnp.ndarray):
    """One local prox-SGD update (Eq. 6 closed form). Returns (w⁺, loss)."""
    loss, grad = jax.value_and_grad(loss_fn, argnums=1)(cfg, wflat, x, y)
    denom = 1.0 / eta + alpha_deg
    w_next = (wflat / eta - grad + zsum) / denom
    return w_next, loss


def eval_step(cfg: ModelConfig, wflat: jnp.ndarray, x: jnp.ndarray,
              y: jnp.ndarray):
    """Returns (correct_count, summed_loss) over an eval batch."""
    logits = forward(cfg, wflat, x)
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32).sum()
    loss_sum = _cross_entropy(logits, y).sum()
    return correct, loss_sum


def init_params(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """He-normal conv/dense kernels, zero biases, unit GN scales.

    Returns the flat f32[d_pad] vector every node starts from (standard
    shared initialization in decentralized training).
    """
    key = jax.random.PRNGKey(seed)
    params = {}
    for spec in cfg.layers():
        key, sub = jax.random.split(key)
        if spec.name.endswith("_w"):
            fan_in = int(jnp.prod(jnp.asarray(spec.shape[:-1])))
            std = (2.0 / fan_in) ** 0.5
            params[spec.name] = std * jax.random.normal(
                sub, spec.shape, jnp.float32
            )
        elif spec.name.endswith("_scale"):
            params[spec.name] = jnp.ones(spec.shape, jnp.float32)
        else:
            params[spec.name] = jnp.zeros(spec.shape, jnp.float32)
    return pack(cfg, params)
