"""AOT pipeline tests: lowering produces loadable HLO text + manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.configs import FASHION


def test_smoke_lowering_is_hlo_text():
    text = aot.lower_smoke()
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_hlo_text_has_no_64bit_ids():
    """xla_extension 0.5.1 requires instruction ids <= INT_MAX; HLO *text*
    round-trips because the parser reassigns ids.  Guard the format: we
    must be emitting text, not a serialized proto."""
    text = aot.lower_smoke()
    assert text.lstrip().startswith(("HloModule", "ENTRY"))


def test_dual_update_lowering_shapes():
    # Lower against a tiny stand-in dimension by monkeypatching is overkill;
    # instead check the real fashion artifact contains the padded dim.
    text = aot.lower_dual_update(FASHION)
    assert f"f32[{FASHION.d_pad}]" in text
    # Two outputs in a tuple.
    assert "tuple" in text.lower()


def test_write_init_w(tmp_path):
    name = aot.write_init_w(FASHION, str(tmp_path), seed=0)
    data = np.fromfile(os.path.join(tmp_path, name), dtype="<f4")
    assert data.shape == (FASHION.d_pad,)
    w = np.asarray(model.init_params(FASHION, seed=0))
    np.testing.assert_array_equal(data, w)


def test_manifest_format(tmp_path):
    """Build a manifest with only the smoke artifact lowered; dataset
    sections are validated against the real artifacts/ dir when present."""
    repo_manifest = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt"
    )
    if not os.path.exists(repo_manifest):
        import pytest

        pytest.skip("run `make artifacts` first")
    lines = [l.strip() for l in open(repo_manifest) if l.strip()]
    assert lines[0] == "version 1"
    assert lines[1].startswith("smoke ")
    # Every dataset block is terminated and carries the required keys.
    blocks = "\n".join(lines).split("dataset ")[1:]
    assert len(blocks) >= 2
    for block in blocks:
        for key in ("d ", "d_pad ", "input ", "classes ", "batch ",
                    "train_step ", "eval_step ", "dual_update ", "init_w ",
                    "layer ", "end"):
            assert key in block, f"missing {key!r} in manifest block"


def test_train_step_scalar_inputs_lower():
    """eta / alpha_deg are runtime scalars (not baked): the lowered module
    must take 6 parameters."""
    lowered = jax.jit(
        lambda w, z, x, y, e, a: model.train_step(FASHION, w, z, x, y, e, a)
    ).lower(
        jax.ShapeDtypeStruct((FASHION.d_pad,), jnp.float32),
        jax.ShapeDtypeStruct((FASHION.d_pad,), jnp.float32),
        jax.ShapeDtypeStruct(
            (FASHION.batch, FASHION.height, FASHION.width, FASHION.channels),
            jnp.float32,
        ),
        jax.ShapeDtypeStruct((FASHION.batch,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
