"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/parameters; every case asserts
``allclose(kernel, ref)``.  This is the core correctness signal for the
kernels that end up inside the AOT artifacts.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.dual_update import BLOCK_ELEMS, dual_update
from compile.kernels.matmul import matmul, matmul_ad

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _rng_vec(seed, d, scale=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(0, scale, d), jnp.float32)


def _rng_mask(seed, d, p):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.random(d) < p, jnp.float32)


# ---------------------------------------------------------------------------
# dual_update
# ---------------------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    blocks=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    theta=st.floats(0.05, 1.5),
    alpha=st.floats(0.0, 2.0),
    sign=st.sampled_from([-1.0, 1.0]),
    p_in=st.floats(0.0, 1.0),
    p_out=st.floats(0.0, 1.0),
)
def test_dual_update_matches_ref(blocks, seed, theta, alpha, sign, p_in,
                                 p_out):
    d = blocks * BLOCK_ELEMS
    z = _rng_vec(seed, d)
    w = _rng_vec(seed + 1, d)
    y_in = _rng_vec(seed + 2, d)
    m_in = _rng_mask(seed + 3, d, p_in)
    m_out = _rng_mask(seed + 4, d, p_out)
    ycomp = m_in * y_in
    taa = 2.0 * alpha * sign

    zk, yk = dual_update(z, w, ycomp, m_in, m_out, theta, taa)
    zr, yr = ref.dual_update_ref(z, w, ycomp, m_in, m_out, theta, taa)
    np.testing.assert_allclose(zk, zr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)


def test_dual_update_uncompressed_is_ecl():
    """m = 1 must reduce exactly to Eq. (5): z' = (1-θ)z + θ·y_recv."""
    d = BLOCK_ELEMS
    z = _rng_vec(0, d)
    w = _rng_vec(1, d)
    y_recv = _rng_vec(2, d)
    ones = jnp.ones(d)
    theta = 0.6
    zk, yk = dual_update(z, w, y_recv, ones, ones, theta, 0.8)
    np.testing.assert_allclose(
        zk, (1 - theta) * z + theta * y_recv, rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(yk, z - 0.8 * w, rtol=1e-5, atol=1e-6)


def test_dual_update_fixed_point_is_stationary():
    """At the DR fixed point (y_recv == z, full mask) z must not move."""
    d = BLOCK_ELEMS
    z = _rng_vec(3, d)
    w = _rng_vec(4, d)
    ones = jnp.ones(d)
    zk, _ = dual_update(z, w, z, ones, ones, 1.0, 0.5)
    np.testing.assert_allclose(zk, z, rtol=1e-6, atol=1e-6)


def test_dual_update_zero_mask_keeps_z():
    """comp ≡ 0 (τ→0 limit) must leave z untouched regardless of θ."""
    d = BLOCK_ELEMS
    z = _rng_vec(5, d)
    w = _rng_vec(6, d)
    zero = jnp.zeros(d)
    zk, yk = dual_update(z, w, zero, zero, zero, 1.0, 1.0)
    np.testing.assert_allclose(zk, z, rtol=0, atol=0)
    np.testing.assert_allclose(yk, zero, rtol=0, atol=0)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(blocks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_dual_update_linearity_identity(blocks, seed):
    """comp(y−z) == comp(y) − comp(z) for mask compression (Assumption 1).

    The kernel implements the RHS; this checks it equals the LHS that the
    paper's Eq. (13) is derived from.
    """
    d = blocks * BLOCK_ELEMS
    z = _rng_vec(seed, d)
    w = _rng_vec(seed + 1, d)
    y = _rng_vec(seed + 2, d)
    m = _rng_mask(seed + 3, d, 0.3)
    theta = 0.9
    zk, _ = dual_update(z, w, m * y, m, m, theta, 0.0)
    expected = z + theta * (m * (y - z))
    np.testing.assert_allclose(zk, expected, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    b=st.integers(1, 64),
    k=st.integers(1, 300),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_f32(b, k, n, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, 1, (b, k)), jnp.float32)
    w = jnp.asarray(r.normal(0, 1, (k, n)), jnp.float32)
    np.testing.assert_allclose(
        matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    b=st.integers(1, 16),
    k=st.integers(1, 140),
    n=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_bf16_inputs(b, k, n, seed):
    """bf16 inputs accumulate in f32 (preferred_element_type)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, 1, (b, k)), jnp.bfloat16)
    w = jnp.asarray(r.normal(0, 1, (k, n)), jnp.bfloat16)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_matmul_exact_tile_boundary():
    """K and N exactly at the 128 tile size (no padding path)."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(0, 1, (8, 256)), jnp.float32)
    w = jnp.asarray(r.normal(0, 1, (256, 128)), jnp.float32)
    np.testing.assert_allclose(
        matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_matmul_ad_gradients_match_jnp():
    """The custom-vjp (Pallas backward GEMMs) must match jnp autodiff."""
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(0, 1, (6, 50)), jnp.float32)
    w = jnp.asarray(r.normal(0, 1, (50, 30)), jnp.float32)

    def f_pallas(x, w):
        return (matmul_ad(x, w) ** 2).sum()

    def f_ref(x, w):
        return (jnp.matmul(x, w) ** 2).sum()

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-3, atol=1e-3)


def test_dual_update_rejects_unaligned():
    d = BLOCK_ELEMS + 1
    v = jnp.zeros(d)
    with pytest.raises(ValueError):
        dual_update(v, v, v, v, v, 1.0, 1.0)
