"""L2 correctness: flat-parameter model, Eq. (6) closed form, eval."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.configs import CIFAR, CONFIGS, FASHION

# A tiny config keeps the hypothesis sweeps fast.
TINY = FASHION


def _batch(cfg, b, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(
        r.normal(0, 1, (b, cfg.height, cfg.width, cfg.channels)), jnp.float32
    )
    y = jnp.asarray(r.integers(0, cfg.classes, b), jnp.int32)
    return x, y


def test_layout_sizes():
    """The documented parameter counts (DESIGN.md §2) stay pinned."""
    assert FASHION.d == 60406
    assert FASHION.d_pad == 60416
    assert CIFAR.d == 77794
    assert CIFAR.d_pad == 77824
    for cfg in CONFIGS.values():
        assert cfg.d_pad % 1024 == 0
        assert sum(s.size for s in cfg.layers()) == cfg.d


def test_pack_unpack_roundtrip():
    for cfg in CONFIGS.values():
        w = model.init_params(cfg, seed=3)
        params = model.unpack(cfg, w)
        assert set(params) == {s.name for s in cfg.layers()}
        w2 = model.pack(cfg, params)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))


def test_init_params_statistics():
    w = model.init_params(FASHION, seed=0)
    p = model.unpack(FASHION, w)
    # He init: std ~= sqrt(2/fan_in) for kernels, biases zero, GN scale one.
    np.testing.assert_array_equal(p["conv1_b"], 0)
    np.testing.assert_array_equal(p["gn2_scale"], 1)
    d1 = np.asarray(p["dense1_w"])
    expect = (2.0 / FASHION.flat_features) ** 0.5
    assert abs(d1.std() - expect) / expect < 0.1
    # Padding tail is zero.
    assert np.all(np.asarray(w)[FASHION.d:] == 0)


def test_forward_shapes_and_finite():
    for cfg in CONFIGS.values():
        w = model.init_params(cfg, seed=1)
        x, _ = _batch(cfg, 7)
        logits = model.forward(cfg, w, x)
        assert logits.shape == (7, cfg.classes)
        assert bool(jnp.isfinite(logits).all())


def test_train_step_alpha_zero_is_sgd():
    """With alpha_deg=0, zsum=0, Eq. (6) closed form == plain SGD."""
    cfg = TINY
    w = model.init_params(cfg, seed=2)
    x, y = _batch(cfg, cfg.batch, seed=5)
    eta = jnp.float32(0.05)
    zero = jnp.zeros(cfg.d_pad)
    w_next, loss = model.train_step(cfg, w, zero, x, y, eta, jnp.float32(0))
    grad = jax.grad(model.loss_fn, argnums=1)(cfg, w, x, y)
    np.testing.assert_allclose(
        w_next, w - eta * grad, rtol=1e-4, atol=1e-6
    )
    assert float(loss) > 0


@hypothesis.settings(max_examples=5, deadline=None)
@hypothesis.given(
    eta=st.floats(1e-3, 0.1),
    alpha_deg=st.floats(1e-3, 5.0),
    seed=st.integers(0, 10_000),
)
def test_train_step_solves_surrogate(eta, alpha_deg, seed):
    """w⁺ must be the exact argmin of the Eq. (6) quadratic surrogate.

    The surrogate gradient at w⁺ is
        ∇f(w_r) + (w⁺ − w_r)/η + alpha_deg·w⁺ − zsum
    and must vanish identically (closed-form check, not an optimizer run).
    """
    cfg = TINY
    r = np.random.default_rng(seed)
    w = model.init_params(cfg, seed=seed % 7)
    zsum = jnp.asarray(r.normal(0, 0.1, cfg.d_pad), jnp.float32)
    x, y = _batch(cfg, cfg.batch, seed=seed + 1)
    w_next, _ = model.train_step(
        cfg, w, zsum, x, y, jnp.float32(eta), jnp.float32(alpha_deg)
    )
    grad = jax.grad(model.loss_fn, argnums=1)(cfg, w, x, y)
    resid = grad + (w_next - w) / eta + alpha_deg * w_next - zsum
    scale = float(jnp.abs(grad).max()) + float(jnp.abs(zsum).max()) + 1.0
    assert float(jnp.abs(resid).max()) / scale < 1e-4


def test_padding_tail_inert():
    """Gradient on the padding tail is zero; with zsum=0 the tail decays
    multiplicatively but never receives signal."""
    cfg = TINY
    w = model.init_params(cfg, seed=4)
    x, y = _batch(cfg, cfg.batch, seed=9)
    grad = jax.grad(model.loss_fn, argnums=1)(cfg, w, x, y)
    assert np.all(np.asarray(grad)[cfg.d:] == 0)


def test_eval_step_counts():
    cfg = TINY
    w = model.init_params(cfg, seed=6)
    x, y = _batch(cfg, cfg.eval_batch, seed=11)
    correct, loss_sum = model.eval_step(cfg, w, x, y)
    logits = model.forward(cfg, w, x)
    expect = int((jnp.argmax(logits, -1) == y).sum())
    assert int(correct) == expect
    assert float(loss_sum) > 0


def test_eval_matches_loss_mean():
    cfg = TINY
    w = model.init_params(cfg, seed=8)
    x, y = _batch(cfg, cfg.eval_batch, seed=13)
    _, loss_sum = model.eval_step(cfg, w, x, y)
    mean = model.loss_fn(cfg, w, x, y)
    np.testing.assert_allclose(
        float(loss_sum) / cfg.eval_batch, float(mean), rtol=1e-5
    )


def test_training_reduces_loss():
    """A few SGD steps on one batch must reduce its loss (sanity e2e)."""
    cfg = TINY
    w = model.init_params(cfg, seed=10)
    x, y = _batch(cfg, cfg.batch, seed=17)
    zero = jnp.zeros(cfg.d_pad)
    first = None
    loss = None
    for _ in range(5):
        w, loss = model.train_step(
            cfg, w, zero, x, y, jnp.float32(0.05), jnp.float32(0)
        )
        if first is None:
            first = float(loss)
    assert float(loss) < first
