//! Micro-benchmarks of the L3 hot paths (harness = false; criterion is
//! unavailable offline — see util::bench).
//!
//! Covers: the fused dual update (native sparse / native dense / PJRT
//! L1-Pallas), mask sampling, COO gather/scatter, codec decode vs
//! `decode_into`, the fused round kernels vs their `_reference` twins,
//! gossip averaging, the PowerGossip power-iteration halves, and the
//! PJRT train/eval steps.  These are the per-round costs behind every
//! table.

use cecl::compress::codec::QsgdCodec;
use cecl::compress::low_rank::{matvec_f32, matvec_f32_reference,
                               matvec_t_f32, matvec_t_f32_reference};
use cecl::compress::{CodecSpec, CooVec, EdgeCodec, EdgeCtx, RandK};
use cecl::linalg::{consensus_mix_f32, consensus_mix_f32_reference,
                   dual_mix_f32, dual_mix_f32_reference,
                   fused_prox_step_f32, fused_prox_step_f32_reference};
use cecl::model::Manifest;
use cecl::runtime::{native, Engine, ModelRuntime};
use cecl::util::bench::BenchSet;
use cecl::util::rng::Pcg;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn main() {
    let d: usize = 60416; // fashion-scale d_pad
    let mut set = BenchSet::new(
        "micro_hotpath — per-edge/per-round primitives (fashion-scale d)",
    );

    // ---- mask sampling (the shared-seed ω derivation) ------------------
    // A/B: geometric gap-sampling (current) vs naive per-coordinate
    // Bernoulli (pre-optimization baseline) — §Perf iteration 1.
    let op = RandK::new(0.1);
    let mut rng = Pcg::new(1);
    set.bench_throughput("mask_sample rand_10% (gap-sampling)", 3, 20,
                         d as f64, "elem", || {
        let m = op.sample_mask(d, &mut rng);
        std::hint::black_box(m.len());
    });
    set.bench_throughput("mask_sample rand_10% (naive baseline)", 3, 20,
                         d as f64, "elem", || {
        let m = op.sample_mask_naive(d, &mut rng);
        std::hint::black_box(m.len());
    });

    // ---- fused dual update: native sparse (default hot path) -----------
    let z0 = randn(d, 2);
    let w = randn(d, 3);
    let y = randn(d, 4);
    let mask_in = op.sample_mask(d, &mut Pcg::new(5));
    let mask_out = op.sample_mask(d, &mut Pcg::new(6));
    let coo = CooVec::gather(&y, &mask_in);
    let mut z = z0.clone();
    let mut yvals = Vec::new();
    set.bench_throughput(
        "dual_update native-sparse (k=10%)", 3, 50,
        (mask_in.len() + mask_out.len()) as f64, "elem",
        || {
            native::dual_update_sparse(&mut z, &w, &coo, &mask_out, 1.0,
                                       0.5, &mut yvals);
        },
    );

    // ---- fused dual update: native dense (ECL path) --------------------
    let mut mi = Vec::new();
    let mut mo = Vec::new();
    RandK::mask_to_dense(d, &mask_in, &mut mi);
    RandK::mask_to_dense(d, &mask_out, &mut mo);
    let ycomp: Vec<f32> = y.iter().zip(&mi).map(|(a, b)| a * b).collect();
    let mut zn = vec![0.0f32; d];
    let mut ys = vec![0.0f32; d];
    set.bench_throughput("dual_update native-dense", 3, 50, d as f64, "elem",
                         || {
        native::dual_update_into(&z0, &w, &ycomp, &mi, &mo, 1.0, 0.5,
                                 &mut zn, &mut ys);
    });

    // ---- COO wire ops ---------------------------------------------------
    let mut buf = CooVec::new(d);
    set.bench_throughput("coo gather (k=10%)", 3, 50,
                         mask_in.len() as f64 * 4.0, "B", || {
        buf.gather_into(&y, &mask_in);
    });
    let mut dense = Vec::new();
    set.bench_throughput("coo scatter->dense", 3, 50, d as f64 * 4.0, "B",
                         || {
        coo.scatter_into_cleared(&mut dense);
    });

    // ---- edge codecs: encode + decode (the codec wire hot path) ---------
    let ctx = EdgeCtx {
        seed: 7,
        edge: 0,
        round: 0,
        receiver: 1,
        dim: d,
        epoch: 0,
    };
    for spec_str in ["identity", "rand_k:0.1", "rand_k:0.1:values",
                     "top_k:0.1", "qsgd:4", "sign", "ef+top_k:0.1"] {
        let spec = CodecSpec::parse(spec_str).expect("bench codec spec");
        let mut enc = spec.build();
        let frame = spec.build().encode(&y, &ctx);
        let mut dec = spec.build();
        set.bench_throughput(
            &format!("codec encode {spec_str}"), 2, 15, d as f64, "elem",
            || {
                let f = enc.encode(&y, &ctx);
                std::hint::black_box(f.wire_bytes());
            },
        );
        set.bench_throughput(
            &format!("codec decode {spec_str}"), 2, 15, d as f64, "elem",
            || {
                let out = dec.decode(&frame, &ctx).expect("decode");
                std::hint::black_box(out.len());
            },
        );
        // A/B against the allocation-free receive path the sim engine
        // actually runs: same frame, reusable scratch, zero Vec churn.
        let mut dec_into = spec.build();
        let mut scratch = vec![0.0f32; d];
        set.bench_throughput(
            &format!("codec decode_into {spec_str}"), 2, 15, d as f64,
            "elem",
            || {
                dec_into
                    .decode_into(&frame, &ctx, &mut scratch)
                    .expect("decode_into");
                std::hint::black_box(scratch[0]);
            },
        );
    }

    // ---- qsgd encode: branch-free bucketed kernel vs scalar ref ---------
    // Both paths produce byte-identical frames (pinned by a unit
    // test); the A/B here is purely the wall-clock win.
    let mut q4 = QsgdCodec { bits: 4 };
    set.bench_throughput("qsgd:4 encode (branch-free)", 3, 20, d as f64,
                         "elem", || {
        let f = q4.encode(&y, &ctx);
        std::hint::black_box(f.wire_bytes());
    });
    set.bench_throughput("qsgd:4 encode (reference)", 3, 20, d as f64,
                         "elem", || {
        let f = q4.encode_reference(&y, &ctx);
        std::hint::black_box(f.wire_bytes());
    });

    // ---- fused round kernels vs plain-loop references -------------------
    // Each pair is pinned bit-identical in linalg; the rows here are
    // purely the wall-clock delta of the 4-way unroll.
    let g = randn(d, 30);
    let zsum = randn(d, 31);
    let mut wf = randn(d, 32);
    set.bench_throughput("fused_prox_step (4-way unrolled)", 3, 50,
                         d as f64, "elem", || {
        fused_prox_step_f32(&mut wf, &g, &zsum, 0.05, 1.1);
        std::hint::black_box(wf[0]);
    });
    set.bench_throughput("fused_prox_step (reference)", 3, 50,
                         d as f64, "elem", || {
        fused_prox_step_f32_reference(&mut wf, &g, &zsum, 0.05, 1.1);
        std::hint::black_box(wf[0]);
    });
    let ymix = randn(d, 33);
    let mut zmix = randn(d, 34);
    let mut accm = randn(d, 35);
    set.bench_throughput("dual_mix (4-way unrolled)", 3, 50, d as f64,
                         "elem", || {
        dual_mix_f32(&mut zmix, &mut accm, &ymix, 0.5, 1.0);
        std::hint::black_box(zmix[0]);
    });
    set.bench_throughput("dual_mix (reference)", 3, 50, d as f64,
                         "elem", || {
        dual_mix_f32_reference(&mut zmix, &mut accm, &ymix, 0.5, 1.0);
        std::hint::black_box(zmix[0]);
    });
    set.bench_throughput("consensus_mix (4-way unrolled)", 3, 50,
                         d as f64, "elem", || {
        consensus_mix_f32(&mut accm, &ymix, &zmix, 0.3);
        std::hint::black_box(accm[0]);
    });
    set.bench_throughput("consensus_mix (reference)", 3, 50,
                         d as f64, "elem", || {
        consensus_mix_f32_reference(&mut accm, &ymix, &zmix, 0.3);
        std::hint::black_box(accm[0]);
    });

    // ---- gossip weighted average (D-PSGD inner loop) --------------------
    let wj = randn(d, 7);
    let mut acc = randn(d, 8);
    set.bench_throughput("gossip axpy (1 neighbor)", 3, 50, d as f64 * 4.0,
                         "B", || {
        for (a, &v) in acc.iter_mut().zip(&wj) {
            *a += 0.333 * v;
        }
        std::hint::black_box(&acc);
    });

    // ---- PowerGossip halves (dense1-scale matrix) -----------------------
    let (rows, cols) = (1176, 48);
    let m = randn(rows * cols, 9);
    let q = randn(cols, 10);
    let p = randn(rows, 11);
    set.bench_throughput("powergossip p = M q", 3, 50,
                         (rows * cols) as f64, "flop", || {
        std::hint::black_box(matvec_f32(&m, rows, cols, &q));
    });
    set.bench_throughput("powergossip s = M^T p", 3, 50,
                         (rows * cols) as f64, "flop", || {
        std::hint::black_box(matvec_t_f32(&m, rows, cols, &p));
    });
    // A/B: the pre-blocking scalar kernels (same math, serial
    // accumulation) — the low-rank GEMV is the per-round cost of every
    // `low_rank:R` row, so the win here is a table-level win.
    set.bench_throughput("powergossip p = M q (reference)", 3, 50,
                         (rows * cols) as f64, "flop", || {
        std::hint::black_box(matvec_f32_reference(&m, rows, cols, &q));
    });
    set.bench_throughput("powergossip s = M^T p (reference)", 3, 50,
                         (rows * cols) as f64, "flop", || {
        std::hint::black_box(matvec_t_f32_reference(&m, rows, cols, &p));
    });

    // ---- PJRT layers (needs artifacts) ----------------------------------
    if let Ok(manifest) = Manifest::load_default() {
        let engine = Engine::cpu().expect("pjrt cpu");
        let ds = manifest.dataset("fashion").expect("fashion").clone();
        let rt = ModelRuntime::load(&engine, &ds).expect("compile");
        let dd = ds.d_pad;
        let w = randn(dd, 20);
        let zsum = vec![0.0f32; dd];
        let x = randn(ds.batch * ds.sample_len(), 21);
        let yb: Vec<i32> = (0..ds.batch as i32).map(|i| i % 10).collect();
        set.bench("pjrt train_step (fwd+bwd+prox)", 2, 20, || {
            let (wn, _) = rt.train_step(&w, &zsum, &x, &yb, 0.02, 1.0)
                .expect("train");
            std::hint::black_box(wn[0]);
        });
        let xe = randn(ds.eval_batch * ds.sample_len(), 22);
        let ye: Vec<i32> = (0..ds.eval_batch as i32).map(|i| i % 10).collect();
        set.bench("pjrt eval_batch", 2, 20, || {
            std::hint::black_box(rt.eval_batch(&w, &xe, &ye).expect("eval"));
        });
        let zv = randn(dd, 23);
        let yv = randn(dd, 24);
        let op2 = RandK::new(0.1);
        let m_in = op2.sample_mask(dd, &mut Pcg::new(25));
        let m_out = op2.sample_mask(dd, &mut Pcg::new(26));
        let mut mid = Vec::new();
        let mut mod_ = Vec::new();
        RandK::mask_to_dense(dd, &m_in, &mut mid);
        RandK::mask_to_dense(dd, &m_out, &mut mod_);
        let yc: Vec<f32> = yv.iter().zip(&mid).map(|(a, b)| a * b).collect();
        set.bench("pjrt dual_update (L1 Pallas kernel)", 2, 20, || {
            std::hint::black_box(
                rt.dual_update(&zv, &w, &yc, &mid, &mod_, 1.0, 0.5)
                    .expect("dual"),
            );
        });
    } else {
        eprintln!("artifacts missing: PJRT benches skipped (make artifacts)");
    }

    set.report();
}
