//! Scale bench for the real-socket engine: a 16-node loopback TCP
//! deployment (C-ECL codec ladder) with measured wall-clock
//! time-to-accuracy next to the virtual clock's forecast for the same
//! spec — the sim predicts, the sockets measure.
//!
//! Entirely artifact-free (native softmax backend) and loopback-only:
//! `cargo bench --bench net_scale` works on a bare checkout with no
//! network beyond 127.0.0.1.
//!
//! `-- --json FILE` additionally writes the timing rows as flat JSON
//! (same [`JsonReport`] format as `sim_scale`).

use cecl::algorithms::{AlgorithmSpec, RoundPolicy};
use cecl::compress::CodecSpec;
use cecl::coordinator::{run_simulated_native, ExecMode, ExperimentSpec};
use cecl::graph::Graph;
use cecl::net::{run_net_native, NetConfig};
use cecl::sim::{LinkSpec, SimConfig};
use cecl::util::bench::{BenchSet, JsonReport};
use cecl::util::table::Table;

fn spec(nodes: usize, epochs: usize, codec: &str) -> ExperimentSpec {
    ExperimentSpec {
        dataset: "tiny".into(),
        algorithm: AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse(codec).expect("bench codec"),
            theta: 1.0,
            dense_first_epoch: false,
        },
        epochs,
        nodes,
        train_per_node: 40,
        test_size: 50,
        local_steps: 2,
        eta: 0.1,
        eval_every: epochs,
        seed: 42,
        exec: ExecMode::Simulated(SimConfig::default()),
        ..Default::default()
    }
}

fn main() {
    let mut json_path = None;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => json_path = Some(it.next().expect("--json FILE")),
            "--bench" => {}
            other => eprintln!("net_scale: ignoring unknown arg {other}"),
        }
    }
    let nodes = 16usize;
    let graph = Graph::ring(nodes);

    // Wall-clock per real round: rendezvous + framed TCP exchange for a
    // whole 16-node deployment in one process.  Each run is 2 epochs x
    // 2 rounds = 4 rounds.
    let mut set = BenchSet::new("net_rungs");
    for codec in ["identity", "rand_k:0.1"] {
        let s = spec(nodes, 2, codec);
        set.bench_throughput(
            &format!("ring({nodes}) {codec} 4 rounds"),
            1,
            3,
            4.0 * nodes as f64,
            "node-round",
            || {
                let r = run_net_native(&s, &graph, &NetConfig::default())
                    .expect("net run");
                std::hint::black_box(r.total_bytes);
            },
        );
    }
    set.report();

    // The payload: measured time-to-accuracy over real sockets vs the
    // virtual clock's forecast of the same deployment.  The sim rows
    // model loopback as an ideal link and as a 1 Gbit/s link; the net
    // row is a measurement, not a model.  Payload bytes line up across
    // all three by construction (asserted).
    let mut t = Table::new([
        "codec", "final acc", "net secs (measured)",
        "sim secs (ideal)", "sim secs (1 Gbit/s)", "KB/node/epoch",
        "hdr KB",
    ]);
    for codec in ["identity", "rand_k:0.1", "ef+top_k:0.1"] {
        let s = spec(nodes, 2, codec);
        let net = run_net_native(&s, &graph, &NetConfig::default())
            .expect("net run");
        let ideal = run_simulated_native(&s, &graph).expect("sim run");
        let mut banded = s.clone();
        banded.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth { latency_us: 30, mbit_per_sec: 1000.0 },
            ..SimConfig::default()
        });
        let forecast = run_simulated_native(&banded, &graph).expect("sim run");
        assert_eq!(
            net.edge_payload_bytes, ideal.edge_payload_bytes,
            "net payload bytes must match the sim prediction"
        );
        t.row([
            codec.to_string(),
            format!("{:.3}", net.final_accuracy),
            format!("{:.3}", net.wallclock_secs),
            format!("{:.4}", ideal.sim_time_secs.unwrap_or(0.0)),
            format!("{:.4}", forecast.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", net.mean_bytes_per_epoch / 1024.0),
            format!("{:.0}", net.header_overhead_bytes as f64 / 1024.0),
        ]);
    }
    println!(
        "\nring({nodes}), C-ECL codec ladder, measured loopback vs \
         virtual-clock forecast:\n{}",
        t.render()
    );

    // Async rounds off the simulator: event-driven exchange over real
    // arrivals, staleness bound enforced in-protocol and reported.
    let mut t = Table::new([
        "rounds", "final acc", "net secs", "max lag", "KB/node/epoch",
    ]);
    for rounds in [
        RoundPolicy::Sync,
        RoundPolicy::Async { max_staleness: 2 },
    ] {
        let mut s = spec(nodes, 2, "rand_k:0.1");
        s.rounds = rounds;
        let r = run_net_native(&s, &graph, &NetConfig::default())
            .expect("net run");
        t.row([
            rounds.name(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.wallclock_secs),
            format!("{}", r.max_staleness),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
        ]);
    }
    println!(
        "\nring({nodes}), rand_k:0.1, sync vs async:2 over loopback \
         TCP:\n{}",
        t.render()
    );

    if let Some(path) = json_path {
        let mut rep = JsonReport::new();
        rep.add_set(&set);
        std::fs::write(&path, rep.render()).expect("write --json file");
        println!("wrote {path}");
    }
}
