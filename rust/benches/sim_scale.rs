//! Scale bench for the virtual-time engine: the 64 → 512 → 8k → 100k
//! → 1M rung ladder (C-ECL(10%) softmax-tiny rungs plus NullLocal
//! protocol-only rungs that isolate pure engine throughput, plus a
//! degree-4 torus(16x32) rung next to ring(512)), the
//! simulated time-to-accuracy ladder across link models, and the
//! sync-vs-async / churn / PowerGossip wall-clock tables at n = 64.
//!
//! Entirely artifact-free (native softmax backend): `cargo bench
//! --bench sim_scale` works on a bare checkout.
//!
//! Flags (after `--`):
//!   --max-nodes N   largest rung to run (default 512 — the quick set;
//!                   the checked-in BENCH_sim_scale.json is produced
//!                   with --max-nodes 1000000)
//!   --json FILE     also write every timing row as flat JSON
//!                   ([`JsonReport`] format)
//!   --check FILE    compare against a previous --json file (the
//!                   checked-in BENCH_sim_scale.json) and exit(1) if
//!                   any shared row regressed by more than 2x

use std::sync::Arc;

use cecl::algorithms::{build_machine, AlgorithmSpec, BuildCtx, DualPath,
                       RoundPolicy};
use cecl::compress::CodecSpec;
use cecl::coordinator::{run_simulated_native, ExecMode, ExperimentSpec};
use cecl::graph::{ChurnSchedule, Graph};
use cecl::model::DatasetManifest;
use cecl::sim::{simulate, LinkSpec, NodeSetup, NullLocal, Schedule,
                SimConfig};
use cecl::util::bench::{parse_mean_secs, BenchSet, JsonReport};
use cecl::util::rng::Pcg;
use cecl::util::table::Table;

struct Opts {
    max_nodes: usize,
    json: Option<String>,
    check: Option<String>,
}

fn opts() -> Opts {
    let mut o = Opts { max_nodes: 512, json: None, check: None };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--max-nodes" => {
                o.max_nodes = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--max-nodes N");
            }
            "--json" => o.json = Some(it.next().expect("--json FILE")),
            "--check" => o.check = Some(it.next().expect("--check FILE")),
            "--bench" => {} // cargo passes this through
            other => eprintln!("sim_scale: ignoring unknown arg {other}"),
        }
    }
    o
}

fn spec(nodes: usize, epochs: usize, link: LinkSpec) -> ExperimentSpec {
    ExperimentSpec {
        dataset: "tiny".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
        epochs,
        nodes,
        train_per_node: 40,
        test_size: 50,
        local_steps: 2,
        eta: 0.1,
        eval_every: epochs,
        seed: 42,
        exec: ExecMode::Simulated(SimConfig {
            link,
            ..SimConfig::default()
        }),
        ..Default::default()
    }
}

/// Protocol-only node setups: ECL machines over a d = 15 synthetic
/// manifest with [`NullLocal`] numerics — the rung isolates the event
/// engine (queue, courier, codec framing) from training cost.
fn null_setups(graph: &Arc<Graph>, rounds_per_epoch: usize)
               -> Vec<NodeSetup> {
    let ds = DatasetManifest::synthetic_linear("t", (2, 2, 1), 3, 2, 2);
    let alg = AlgorithmSpec::Ecl { theta: 1.0 };
    (0..graph.n())
        .map(|node| {
            let ctx = BuildCtx {
                node,
                graph: Arc::clone(graph),
                manifest: ds.clone(),
                seed: 7,
                eta: 0.05,
                local_steps: 1,
                rounds_per_epoch,
                dual_path: DualPath::Native,
                runtime: None,
                round_policy: RoundPolicy::Sync,
            };
            let mut rng = Pcg::new(900 + node as u64);
            let w = (0..ds.d_pad).map(|_| rng.normal_f32()).collect();
            NodeSetup {
                machine: build_machine(&alg, &ctx).expect("bench machine"),
                local: Box::new(NullLocal),
                w,
            }
        })
        .collect()
}

fn main() {
    let opts = opts();
    let mut json = JsonReport::new();

    // ----- the rung ladder: softmax-tiny time-to-accuracy runs -------
    // (nodes, threads, timing iters): big rungs run once, and 8k runs
    // both serial and partition-parallel so the A/B is in the JSON.
    let mut set = BenchSet::new("softmax_rungs");
    for &(nodes, threads, iters) in &[
        (64usize, 1usize, 3usize),
        (512, 1, 3),
        (8_192, 1, 1),
        (8_192, 8, 1),
        (100_000, 8, 1),
    ] {
        if nodes > opts.max_nodes {
            continue;
        }
        let graph = Graph::ring(nodes);
        let mut s = spec(
            nodes,
            2,
            LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
        );
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
            threads,
            ..SimConfig::default()
        });
        let name = if threads == 1 {
            format!("ring({nodes}) 4 rounds")
        } else {
            format!("ring({nodes}) 4 rounds t{threads}")
        };
        set.bench_throughput(
            &name,
            usize::from(iters > 1),
            iters,
            4.0 * nodes as f64,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                std::hint::black_box(r.total_bytes);
            },
        );
    }
    // Torus rung: the same 512 nodes as ring(512) but degree 4 — twice
    // the edges at equal node count, so next to the ring row it
    // isolates how the message path scales with edge count.
    if 512 <= opts.max_nodes {
        let graph = Graph::torus(16, 32);
        let mut s = spec(
            512,
            2,
            LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
        );
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
            ..SimConfig::default()
        });
        set.bench_throughput(
            "torus(16x32) 4 rounds",
            1,
            3,
            4.0 * 512.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                std::hint::black_box(r.total_bytes);
            },
        );
    }
    set.report();
    json.add_set(&set);

    // ----- NullLocal protocol-only rungs up to 1M nodes --------------
    // Setup construction (machines + initial params) is inside the
    // timed closure on purpose: at 1M nodes, building the fleet is
    // part of what "one machine can run this" has to mean.
    let mut set = BenchSet::new("nulllocal_rungs");
    for &(nodes, threads) in &[
        (8_192usize, 1usize),
        (100_000, 1),
        (1_000_000, 1),
        (1_000_000, 8),
    ] {
        if nodes > opts.max_nodes {
            continue;
        }
        let graph = Arc::new(Graph::ring(nodes));
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 100 },
            threads,
            ..SimConfig::default()
        };
        let sched = Schedule::new(1, 2, 1, 1);
        let name = if threads == 1 {
            format!("ring({nodes}) 2 rounds null")
        } else {
            format!("ring({nodes}) 2 rounds null t{threads}")
        };
        set.bench_throughput(
            &name,
            0,
            1,
            2.0 * nodes as f64,
            "node-round",
            || {
                let setups = null_setups(&graph, 2);
                let out = simulate(&graph, &cfg, 7, &sched, setups,
                                   RoundPolicy::Sync, false)
                    .expect("null sim run");
                std::hint::black_box(out.vtime_ns);
            },
        );
    }
    set.report();
    json.add_set(&set);

    // ----- simulated time-to-accuracy across link models -------------
    let mut t = Table::new([
        "link", "final acc", "sim secs", "KB/node/epoch", "retrans KB",
    ]);
    let graph = Graph::ring(64);
    for link in [
        LinkSpec::Ideal,
        LinkSpec::Constant { latency_us: 500 },
        LinkSpec::Bandwidth {
            latency_us: 500,
            mbit_per_sec: 50.0,
        },
        LinkSpec::Lossy {
            latency_us: 500,
            mbit_per_sec: 50.0,
            drop_p: 0.05,
        },
    ] {
        let s = spec(64, 4, link.clone());
        let r = run_simulated_native(&s, &graph).expect("sim run");
        t.row([
            link.name(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
            format!("{:.0}", r.retransmit_bytes as f64 / 1024.0),
        ]);
    }
    println!("\nring(64), C-ECL(10%), 4 epochs:\n{}", t.render());

    // Codec ladder on a bandwidth-limited ring(64): bytes buy time.
    let mut t = Table::new([
        "codec", "final acc", "sim secs", "KB/node/epoch",
    ]);
    for codec_str in ["identity", "rand_k:0.1", "rand_k:0.1:values",
                      "top_k:0.1", "qsgd:4", "sign", "ef+top_k:0.1"] {
        let mut s = spec(
            64,
            4,
            LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 50.0 },
        );
        s.algorithm = AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse(codec_str).expect("bench codec"),
            theta: 1.0,
            dense_first_epoch: false,
        };
        let r = run_simulated_native(&s, &graph).expect("sim run");
        t.row([
            codec_str.to_string(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
        ]);
    }
    println!(
        "\nring(64), C-ECL codec ladder, bandwidth 50 Mbit/s:\n{}",
        t.render()
    );

    // Rival ladder: same ring, link, and schedule, the algorithm
    // varies at matched codecs — CHOCO-SGD and LEAD next to the C-ECL
    // row they rival (the byte columns line up by construction).
    let mut t = Table::new([
        "algorithm", "final acc", "sim secs", "KB/node/epoch",
    ]);
    for alg in [
        AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse("rand_k:0.1").expect("bench codec"),
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::Choco {
            codec: CodecSpec::parse("rand_k:0.1").expect("bench codec"),
        },
        AlgorithmSpec::Lead {
            codec: CodecSpec::parse("qsgd:4").expect("bench codec"),
        },
    ] {
        let mut s = spec(
            64,
            4,
            LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 50.0 },
        );
        s.algorithm = alg;
        let r = run_simulated_native(&s, &graph).expect("sim run");
        t.row([
            s.algorithm.name(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
        ]);
    }
    println!(
        "\nring(64), rival baselines at matched codecs:\n{}",
        t.render()
    );

    // Sync vs async rounds under one 8x straggler: wall-clock cost of
    // the event-driven scheduler is tracked alongside the simulated-
    // time win (the whole point of the per-edge-clock refactor).
    let mut set = BenchSet::new("sync_vs_async");
    let mut t = Table::new([
        "rounds", "final acc", "sim secs", "max lag", "KB/node/epoch",
    ]);
    let graph = Graph::ring(64);
    for rounds in [
        RoundPolicy::Sync,
        RoundPolicy::Async { max_staleness: 1 },
        RoundPolicy::Async { max_staleness: 4 },
    ] {
        // spec()'s link is irrelevant here — the exec is replaced
        // wholesale with the straggler scenario just below.
        let mut s = spec(64, 4, LinkSpec::Ideal);
        s.rounds = rounds;
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Constant { latency_us: 10_000 },
            stragglers: vec![(7, 8.0)],
            ..SimConfig::default()
        });
        let mut last = None;
        set.bench_throughput(
            &format!("rounds {}", rounds.name()),
            1,
            3,
            8.0 * 64.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                last = Some((
                    r.final_accuracy,
                    r.sim_time_secs.unwrap_or(0.0),
                    r.max_staleness,
                    r.mean_bytes_per_epoch,
                ));
            },
        );
        let (acc, secs, lag, kb) = last.expect("at least one run");
        t.row([
            rounds.name(),
            format!("{acc:.3}"),
            format!("{secs:.3}"),
            format!("{lag}"),
            format!("{:.0}", kb / 1024.0),
        ]);
    }
    set.report();
    json.add_set(&set);
    println!(
        "\nring(64), C-ECL(10%), one 8x straggler, constant 10 ms links:\n{}",
        t.render()
    );

    // Churn-scheduler overhead: the static path (no churn events, one
    // version compare per callback) vs `random:0.05` edge churn on a
    // ring(64) — wall-clock cost of the first-class churn events plus
    // the protocol cost the counters surface.
    let mut set = BenchSet::new("churn_vs_static");
    let mut t = Table::new([
        "schedule", "final acc", "sim secs", "churned", "chdrops",
        "KB/node/epoch",
    ]);
    let graph = Graph::ring(64);
    for churny in [false, true] {
        let mut s = spec(
            64,
            4,
            LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
        );
        let mut churn = ChurnSchedule::new();
        if churny {
            churn.random_edge_churn_with_slot(0.05, 11, 1_000_000);
        }
        let label = churn.label();
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
            churn,
            ..SimConfig::default()
        });
        let mut last = None;
        set.bench_throughput(
            &format!("schedule {label}"),
            1,
            3,
            8.0 * 64.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                last = Some((
                    r.final_accuracy,
                    r.sim_time_secs.unwrap_or(0.0),
                    r.edges_churned,
                    r.frames_dropped_by_churn,
                    r.mean_bytes_per_epoch,
                ));
            },
        );
        let (acc, secs, churned, drops, kb) = last.expect("one run");
        t.row([
            label,
            format!("{acc:.3}"),
            format!("{secs:.3}"),
            if churny { format!("{churned}") } else { "—".into() },
            if churny { format!("{drops}") } else { "—".into() },
            format!("{:.0}", kb / 1024.0),
        ]);
    }
    set.report();
    json.add_set(&set);
    println!(
        "\nring(64), C-ECL(10%), static vs random:0.05 edge churn \
         (1 ms slots):\n{}",
        t.render()
    );

    // Async PowerGossip: the multi-phase conversation pipeline under
    // per-edge clocks — wall-clock cost of round-straddling
    // conversations next to its own sync baseline.
    let mut set = BenchSet::new("powergossip_async");
    let mut t = Table::new([
        "rounds", "final acc", "sim secs", "max lag", "KB/node/epoch",
    ]);
    let graph = Graph::ring(64);
    for rounds in [
        RoundPolicy::Sync,
        RoundPolicy::Async { max_staleness: 2 },
    ] {
        let mut s = spec(64, 4, LinkSpec::Ideal);
        s.algorithm = AlgorithmSpec::PowerGossip { iters: 2 };
        s.rounds = rounds;
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Constant { latency_us: 10_000 },
            stragglers: vec![(7, 8.0)],
            ..SimConfig::default()
        });
        let mut last = None;
        set.bench_throughput(
            &format!("powergossip rounds {}", rounds.name()),
            1,
            3,
            8.0 * 64.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                last = Some((
                    r.final_accuracy,
                    r.sim_time_secs.unwrap_or(0.0),
                    r.max_staleness,
                    r.mean_bytes_per_epoch,
                ));
            },
        );
        let (acc, secs, lag, kb) = last.expect("at least one run");
        t.row([
            rounds.name(),
            format!("{acc:.3}"),
            format!("{secs:.3}"),
            format!("{lag}"),
            format!("{:.0}", kb / 1024.0),
        ]);
    }
    set.report();
    json.add_set(&set);
    println!(
        "\nring(64), PowerGossip(2), one 8x straggler, constant 10 ms \
         links:\n{}",
        t.render()
    );

    // ----- machine-readable output and the regression gate -----------
    if let Some(path) = &opts.json {
        std::fs::write(path, json.render()).expect("write --json file");
        println!("wrote {path}");
    }
    if let Some(path) = &opts.check {
        let baseline = std::fs::read_to_string(path).expect("read --check file");
        let old = parse_mean_secs(&baseline).expect("parse --check file");
        let new = parse_mean_secs(&json.render()).expect("parse own rows");
        let mut failures = Vec::new();
        for (name, mean) in &new {
            let Some((_, base)) = old.iter().find(|(n, _)| n == name) else {
                continue; // new row: no baseline yet
            };
            // Sub-5 ms rows are timer noise at 2x; the gate is for the
            // rung ladder, which is well above that.
            if *base < 0.005 {
                continue;
            }
            let ratio = mean / base;
            let verdict = if ratio > 2.0 { "REGRESSED" } else { "ok" };
            println!("check {name}: {mean:.4}s vs {base:.4}s ({ratio:.2}x) \
                      {verdict}");
            if ratio > 2.0 {
                failures.push(name.clone());
            }
        }
        if !failures.is_empty() {
            eprintln!("sim_scale: >2x regression vs {path}: {failures:?}");
            std::process::exit(1);
        }
        println!("sim_scale: no >2x regressions vs {path}");
    }
}
