//! Scale bench for the virtual-time engine: C-ECL(10%) on rings of
//! n ∈ {64, 256, 512} nodes — node counts that are simply impossible
//! with the thread-per-node engine (OS threads + blocking channels) —
//! plus the wall-clock cost per simulated round and the simulated
//! time-to-accuracy ladder across link models at n = 64.
//!
//! Entirely artifact-free (native softmax backend): `cargo bench
//! --bench sim_scale` works on a bare checkout.

use cecl::algorithms::{AlgorithmSpec, RoundPolicy};
use cecl::compress::CodecSpec;
use cecl::coordinator::{run_simulated_native, ExecMode, ExperimentSpec};
use cecl::graph::{ChurnSchedule, Graph};
use cecl::sim::{LinkSpec, SimConfig};
use cecl::util::bench::BenchSet;
use cecl::util::table::Table;

fn spec(nodes: usize, epochs: usize, link: LinkSpec) -> ExperimentSpec {
    ExperimentSpec {
        dataset: "tiny".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
        epochs,
        nodes,
        train_per_node: 40,
        test_size: 50,
        local_steps: 2,
        eta: 0.1,
        eval_every: epochs,
        seed: 42,
        exec: ExecMode::Simulated(SimConfig {
            link,
            ..SimConfig::default()
        }),
        ..Default::default()
    }
}

fn main() {
    let mut set = BenchSet::new(
        "sim_scale — virtual-time C-ECL(10%) ring, native softmax backend",
    );
    // Wall-clock per simulated round at growing node counts.  Each run
    // is 2 epochs x 2 rounds = 4 rounds.
    for nodes in [64usize, 256, 512] {
        let graph = Graph::ring(nodes);
        let s = spec(
            nodes,
            2,
            LinkSpec::Bandwidth {
                latency_us: 200,
                mbit_per_sec: 100.0,
            },
        );
        set.bench_throughput(
            &format!("ring({nodes}) 4 rounds"),
            1,
            3,
            4.0 * nodes as f64,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                std::hint::black_box(r.total_bytes);
            },
        );
    }
    set.report();

    // The payload: simulated time-to-accuracy across link models.
    let mut t = Table::new([
        "link", "final acc", "sim secs", "KB/node/epoch", "retrans KB",
    ]);
    let graph = Graph::ring(64);
    for link in [
        LinkSpec::Ideal,
        LinkSpec::Constant { latency_us: 500 },
        LinkSpec::Bandwidth {
            latency_us: 500,
            mbit_per_sec: 50.0,
        },
        LinkSpec::Lossy {
            latency_us: 500,
            mbit_per_sec: 50.0,
            drop_p: 0.05,
        },
    ] {
        let s = spec(64, 4, link.clone());
        let r = run_simulated_native(&s, &graph).expect("sim run");
        t.row([
            link.name(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
            format!("{:.0}", r.retransmit_bytes as f64 / 1024.0),
        ]);
    }
    println!("\nring(64), C-ECL(10%), 4 epochs:\n{}", t.render());

    // Codec ladder on a bandwidth-limited ring(64): bytes buy time.
    let mut t = Table::new([
        "codec", "final acc", "sim secs", "KB/node/epoch",
    ]);
    for codec_str in ["identity", "rand_k:0.1", "rand_k:0.1:values",
                      "top_k:0.1", "qsgd:4", "sign", "ef+top_k:0.1"] {
        let mut s = spec(
            64,
            4,
            LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 50.0 },
        );
        s.algorithm = AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse(codec_str).expect("bench codec"),
            theta: 1.0,
            dense_first_epoch: false,
        };
        let r = run_simulated_native(&s, &graph).expect("sim run");
        t.row([
            codec_str.to_string(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
        ]);
    }
    println!(
        "\nring(64), C-ECL codec ladder, bandwidth 50 Mbit/s:\n{}",
        t.render()
    );

    // Rival ladder: same ring, link, and schedule, the algorithm
    // varies at matched codecs — CHOCO-SGD and LEAD next to the C-ECL
    // row they rival (the byte columns line up by construction).
    let mut t = Table::new([
        "algorithm", "final acc", "sim secs", "KB/node/epoch",
    ]);
    for alg in [
        AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse("rand_k:0.1").expect("bench codec"),
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::Choco {
            codec: CodecSpec::parse("rand_k:0.1").expect("bench codec"),
        },
        AlgorithmSpec::Lead {
            codec: CodecSpec::parse("qsgd:4").expect("bench codec"),
        },
    ] {
        let mut s = spec(
            64,
            4,
            LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 50.0 },
        );
        s.algorithm = alg;
        let r = run_simulated_native(&s, &graph).expect("sim run");
        t.row([
            s.algorithm.name(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.3}", r.sim_time_secs.unwrap_or(0.0)),
            format!("{:.0}", r.mean_bytes_per_epoch / 1024.0),
        ]);
    }
    println!(
        "\nring(64), rival baselines at matched codecs:\n{}",
        t.render()
    );

    // Sync vs async rounds under one 8x straggler: wall-clock cost of
    // the event-driven scheduler is tracked alongside the simulated-
    // time win (the whole point of the per-edge-clock refactor).
    let mut set = BenchSet::new(
        "sim_scale — sync vs async rounds, ring(64), one 8x straggler",
    );
    let mut t = Table::new([
        "rounds", "final acc", "sim secs", "max lag", "KB/node/epoch",
    ]);
    let graph = Graph::ring(64);
    for rounds in [
        RoundPolicy::Sync,
        RoundPolicy::Async { max_staleness: 1 },
        RoundPolicy::Async { max_staleness: 4 },
    ] {
        // spec()'s link is irrelevant here — the exec is replaced
        // wholesale with the straggler scenario just below.
        let mut s = spec(64, 4, LinkSpec::Ideal);
        s.rounds = rounds;
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Constant { latency_us: 10_000 },
            stragglers: vec![(7, 8.0)],
            ..SimConfig::default()
        });
        let mut last = None;
        set.bench_throughput(
            &format!("rounds {}", rounds.name()),
            1,
            3,
            8.0 * 64.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                last = Some((
                    r.final_accuracy,
                    r.sim_time_secs.unwrap_or(0.0),
                    r.max_staleness,
                    r.mean_bytes_per_epoch,
                ));
            },
        );
        let (acc, secs, lag, kb) = last.expect("at least one run");
        t.row([
            rounds.name(),
            format!("{acc:.3}"),
            format!("{secs:.3}"),
            format!("{lag}"),
            format!("{:.0}", kb / 1024.0),
        ]);
    }
    set.report();
    println!(
        "\nring(64), C-ECL(10%), one 8x straggler, constant 10 ms links:\n{}",
        t.render()
    );

    // Churn-scheduler overhead: the static path (no churn events, one
    // version compare per callback) vs `random:0.05` edge churn on a
    // ring(64) — wall-clock cost of the first-class churn events plus
    // the protocol cost the counters surface.
    let mut set = BenchSet::new(
        "sim_scale — churn events vs static path, ring(64), C-ECL(10%)",
    );
    let mut t = Table::new([
        "schedule", "final acc", "sim secs", "churned", "chdrops",
        "KB/node/epoch",
    ]);
    let graph = Graph::ring(64);
    for churny in [false, true] {
        let mut s = spec(
            64,
            4,
            LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
        );
        let mut churn = ChurnSchedule::new();
        if churny {
            churn.random_edge_churn_with_slot(0.05, 11, 1_000_000);
        }
        let label = churn.label();
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth { latency_us: 200, mbit_per_sec: 100.0 },
            churn,
            ..SimConfig::default()
        });
        let mut last = None;
        set.bench_throughput(
            &format!("schedule {label}"),
            1,
            3,
            8.0 * 64.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                last = Some((
                    r.final_accuracy,
                    r.sim_time_secs.unwrap_or(0.0),
                    r.edges_churned,
                    r.frames_dropped_by_churn,
                    r.mean_bytes_per_epoch,
                ));
            },
        );
        let (acc, secs, churned, drops, kb) = last.expect("one run");
        t.row([
            label,
            format!("{acc:.3}"),
            format!("{secs:.3}"),
            if churny { format!("{churned}") } else { "—".into() },
            if churny { format!("{drops}") } else { "—".into() },
            format!("{:.0}", kb / 1024.0),
        ]);
    }
    set.report();
    println!(
        "\nring(64), C-ECL(10%), static vs random:0.05 edge churn \
         (1 ms slots):\n{}",
        t.render()
    );

    // Async PowerGossip: the multi-phase conversation pipeline under
    // per-edge clocks — wall-clock cost of round-straddling
    // conversations next to its own sync baseline.
    let mut set = BenchSet::new(
        "sim_scale — PowerGossip(2) sync vs async, ring(64), one 8x straggler",
    );
    let mut t = Table::new([
        "rounds", "final acc", "sim secs", "max lag", "KB/node/epoch",
    ]);
    let graph = Graph::ring(64);
    for rounds in [
        RoundPolicy::Sync,
        RoundPolicy::Async { max_staleness: 2 },
    ] {
        let mut s = spec(64, 4, LinkSpec::Ideal);
        s.algorithm = AlgorithmSpec::PowerGossip { iters: 2 };
        s.rounds = rounds;
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Constant { latency_us: 10_000 },
            stragglers: vec![(7, 8.0)],
            ..SimConfig::default()
        });
        let mut last = None;
        set.bench_throughput(
            &format!("powergossip rounds {}", rounds.name()),
            1,
            3,
            8.0 * 64.0,
            "node-round",
            || {
                let r = run_simulated_native(&s, &graph).expect("sim run");
                last = Some((
                    r.final_accuracy,
                    r.sim_time_secs.unwrap_or(0.0),
                    r.max_staleness,
                    r.mean_bytes_per_epoch,
                ));
            },
        );
        let (acc, secs, lag, kb) = last.expect("at least one run");
        t.row([
            rounds.name(),
            format!("{acc:.3}"),
            format!("{secs:.3}"),
            format!("{lag}"),
            format!("{:.0}", kb / 1024.0),
        ]);
    }
    set.report();
    println!(
        "\nring(64), PowerGossip(2), one 8x straggler, constant 10 ms \
         links:\n{}",
        t.render()
    );
}
