//! Table-2 bench: per-round cost under the heterogeneous split.
//! Identical harness to table1_round_cost but with the 8-of-10 class
//! partition — byte costs must be partition-independent (the protocol
//! never looks at the data), which this bench demonstrates.

use cecl::algorithms::AlgorithmSpec;
use cecl::coordinator::{run_with_engine, ExperimentSpec};
use cecl::data::Partition;
use cecl::graph::Graph;
use cecl::model::Manifest;
use cecl::runtime::Engine;
use cecl::util::bench::{BenchSet, Measurement};
use cecl::util::stats::Summary;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("run `make artifacts` first");
        return;
    };
    let engine = Engine::cpu().expect("pjrt");
    let graph = Graph::ring(8);
    let mut set = BenchSet::new(
        "table2_round_cost — heterogeneous(8/10) ring(8), 1 epoch per method",
    );
    let methods = [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 10 },
        AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: false },
        AlgorithmSpec::NaiveCEcl { k_frac: 0.10, theta: 1.0 },
    ];
    for alg in methods {
        let spec = ExperimentSpec {
            dataset: "fashion".into(),
            algorithm: alg.clone(),
            epochs: 1,
            nodes: 8,
            train_per_node: 100,
            test_size: 100,
            local_steps: 1,
            eta: 0.04,
            eval_every: 1,
            partition: Partition::Heterogeneous { classes_per_node: 8 },
            ..Default::default()
        };
        let mut samples = Vec::new();
        let mut bytes = 0.0;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let report = run_with_engine(&engine, &manifest, &spec, &graph)
                .expect("run");
            samples.push(t0.elapsed().as_secs_f64());
            bytes = report.mean_bytes_per_epoch;
        }
        set.record(Measurement {
            name: format!("{} [{:.0} KB/node/epoch]", alg.name(),
                          bytes / 1024.0),
            iters: samples.len(),
            secs: Summary::of(&samples),
            items_per_iter: Some(bytes * 8.0),
            items_unit: "B",
        });
    }
    set.report();
}
