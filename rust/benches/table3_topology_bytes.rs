//! Table-3 bench: Send/Epoch across the four paper topologies for the
//! four reported methods.  Bytes are measured from real (1-epoch) runs
//! and cross-checked against the analytic per-round formulas — if the
//! two disagree the bench panics, so this doubles as an accounting
//! regression gate.

use cecl::algorithms::AlgorithmSpec;
use cecl::coordinator::{run_with_engine, ExperimentSpec};
use cecl::data::Partition;
use cecl::graph::{Graph, Topology};
use cecl::model::Manifest;
use cecl::runtime::Engine;
use cecl::util::table::Table;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("run `make artifacts` first");
        return;
    };
    let engine = Engine::cpu().expect("pjrt");
    let ds = manifest.dataset("fashion").expect("fashion");
    let d = ds.d_pad as f64;

    let methods = [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 10 },
        AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: false },
    ];
    let mut t = Table::new([
        "method", "topology", "KB/node/epoch (measured)",
        "KB/node/epoch (analytic)", "secs/epoch",
    ]);
    for topology in Topology::paper_set() {
        let graph = Graph::build(topology, 8);
        let mean_degree = 2.0 * graph.edges().len() as f64 / 8.0;
        for alg in &methods {
            let spec = ExperimentSpec {
                dataset: "fashion".into(),
                algorithm: alg.clone(),
                epochs: 1,
                nodes: 8,
                train_per_node: 250, // 5 batches, K=5 -> 1 round/epoch
                test_size: 100,
                local_steps: 5,
                eta: 0.04,
                eval_every: 1,
                partition: Partition::Homogeneous,
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let report =
                run_with_engine(&engine, &manifest, &spec, &graph).expect("run");
            let secs = t0.elapsed().as_secs_f64();
            let measured = report.mean_bytes_per_epoch;
            // Analytic: 1 round/epoch x mean_degree x payload.
            let analytic = match alg {
                AlgorithmSpec::DPsgd | AlgorithmSpec::Ecl { .. } => {
                    mean_degree * d * 4.0
                }
                AlgorithmSpec::CEcl { k_frac, .. } => {
                    mean_degree * d * k_frac * 8.0
                }
                AlgorithmSpec::PowerGossip { iters } => {
                    let mat: usize = ds
                        .matrix_views()
                        .iter()
                        .map(|&(_, _, r, c)| (r + c) * 4)
                        .sum();
                    let vecs: usize =
                        ds.vector_views().iter().map(|&(_, _, l)| l * 4).sum();
                    mean_degree * (mat * iters + vecs) as f64
                }
                _ => 0.0,
            };
            let tol = analytic * 0.06 + 1.0;
            assert!(
                (measured - analytic).abs() < tol,
                "{} on {}: measured {measured} vs analytic {analytic}",
                alg.name(),
                topology.name()
            );
            t.row([
                alg.name(),
                topology.name().to_string(),
                format!("{:.0}", measured / 1024.0),
                format!("{:.0}", analytic / 1024.0),
                format!("{secs:.2}"),
            ]);
        }
    }
    println!("## table3_topology_bytes — measured vs analytic\n");
    println!("{}", t.render());
}
