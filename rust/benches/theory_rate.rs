//! Theorem-1 bench: cost of the exact C-ECL round on the quadratic
//! substrate (Cholesky prox + compressed dual exchange) as the problem
//! dimension grows, plus the measured-vs-bound rate table that `repro
//! theory` reports — regenerating the theory validation is a one-second
//! affair and runs entirely in rust.

use cecl::graph::Graph;
use cecl::quadratic::{
    rate_bound, run_cecl, tau_threshold, DualRule, QuadraticNetwork,
};
use cecl::util::bench::BenchSet;
use cecl::util::stats::empirical_rate;
use cecl::util::table::Table;

fn main() {
    let graph = Graph::ring(8);
    let mut set = BenchSet::new("theory_rate — exact C-ECL rounds (ring 8)");
    for dim in [8usize, 16, 32, 64] {
        let net = QuadraticNetwork::random(8, dim, dim + 16, 0.5, 0.5, 42);
        let alpha = net.best_alpha(&graph).expect("non-empty graph");
        set.bench_throughput(
            &format!("50 rounds @ dim {dim}"),
            1,
            5,
            50.0,
            "round",
            || {
                std::hint::black_box(run_cecl(
                    &net, &graph, alpha, 1.0, 0.8, 50, 1,
                    DualRule::CompressDiff,
                ));
            },
        );
    }
    set.report();

    // Rate table (the bench's correctness payload).
    let net = QuadraticNetwork::random(8, 24, 40, 0.5, 0.5, 42);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let delta = net.delta(alpha, &graph).expect("non-empty graph");
    let mut t = Table::new(["tau", "bound rho", "measured rate", "converged"]);
    for tau in [1.0, 0.8, 0.6, (tau_threshold(delta) + 1.0) / 2.0] {
        let errors = run_cecl(&net, &graph, alpha, 1.0, tau, 150, 2,
                              DualRule::CompressDiff);
        let rate = empirical_rate(&errors[30..]);
        t.row([
            format!("{tau:.3}"),
            format!("{:.4}", rate_bound(1.0, tau, delta)),
            format!("{rate:.4}"),
            (errors.last().unwrap() < &(errors[0] * 1e-2)).to_string(),
        ]);
    }
    println!("delta = {delta:.4} (alpha* = {alpha:.4})\n{}", t.render());
}
