//! ECL / C-ECL node: the paper's contribution.
//!
//! Maintains the per-edge dual variables `z_{i|j}` of the Douglas–
//! Rachford splitting and implements Alg. 1:
//!
//! * line 4 — `y_{i|j} = z_{i|j} − 2α A_{i|j} w_i`
//! * lines 5–6 — *omitted*: masks ω are derived from the shared seed
//!   (`Pcg::derive(seed, [EDGE_MASK, edge, round, dir])`), identically at
//!   both endpoints
//! * lines 7–8 — exchange `comp(y; ω)` as COO
//! * line 9 — `z_{i|j} += θ·comp(y_{j|i} − z_{i|j}; ω_{i|j})`, expanded
//!   via Assumption-1 linearity to `θ·(comp(y_{j|i}) − comp(z_{i|j}))`
//!
//! With `k_frac = 1` the node *is* the uncompressed ECL (dense wire
//! format, Eq. (5) update).  `DualRule::CompressY` switches to the naive
//! Eq. (11) rule for the §3.2 ablation.
//!
//! The protocol is written once in the poll-driven
//! [`NodeStateMachine`] form (`round_begin` queues the outbound
//! `comp(y)`s, `on_message` applies line 9 per neighbor, `round_end`
//! restores the `zsum` invariant); the blocking
//! [`NodeAlgorithm::exchange`] used by the threaded engine is a thin
//! driver over the same methods, so both engines run identical wire
//! traffic and identical arithmetic.
//!
//! Two execution paths for line 4+9, semantically identical:
//! [`DualPath::Native`] (fused rust loops, the default hot path) and
//! [`DualPath::Pjrt`] (the L1 Pallas `dual_update` artifact through
//! PJRT; threaded engine only).  Integration tests assert they agree
//! elementwise.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::{CooVec, RandK};
use crate::graph::Graph;
use crate::runtime::{native, ModelRuntime};
use crate::util::rng::{streams, Pcg};

use super::{paper_alpha, BuildCtx, NodeAlgorithm, NodeStateMachine};

/// Which implementation executes the fused dual update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualPath {
    /// Pure-rust fused loops (default; see EXPERIMENTS.md §Perf).
    Native,
    /// The L1 Pallas kernel through PJRT.
    Pjrt,
}

/// Eq. (13) (the C-ECL) vs Eq. (11) (naive ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualRule {
    CompressDiff,
    CompressY,
}

pub struct CEclNode {
    node: usize,
    graph: Arc<Graph>,
    seed: u64,
    d_pad: usize,
    theta: f32,
    /// Per-node α (Eq. 46/47 — depends on |N_i|).
    alpha: f32,
    alpha_deg: f32,
    k_frac: f64,
    comp: RandK,
    /// Rounds at the start trained with a full mask (paper §5.1 warmup).
    dense_rounds: usize,
    rule: DualRule,
    dual_path: DualPath,
    runtime: Option<Arc<ModelRuntime>>,
    /// Dual state, one vector per neighbor slot (sorted neighbor order).
    z: Vec<Vec<f32>>,
    /// Cached `Σ_j A_{i|j} z_{i|j}`.
    zsum: Vec<f32>,
    /// Messages still expected in the current exchange round.
    pending: usize,
    // -- preallocated scratch (no allocation in the round hot loop) -----
    scratch_vals: Vec<f32>,
    scratch_dense_a: Vec<f32>,
    scratch_dense_b: Vec<f32>,
    scratch_mask_in: Vec<f32>,
    scratch_mask_out: Vec<f32>,
}

impl CEclNode {
    pub fn new(ctx: &BuildCtx, k_frac: f64, theta: f32, dense_rounds: usize,
               rule: DualRule) -> CEclNode {
        let degree = ctx.graph.degree(ctx.node);
        assert!(degree > 0, "ECL requires no isolated nodes (Assumption 4)");
        let alpha = paper_alpha(ctx.eta, degree, ctx.local_steps, k_frac);
        let d_pad = ctx.manifest.d_pad;
        CEclNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            seed: ctx.seed,
            d_pad,
            theta,
            alpha,
            alpha_deg: alpha * degree as f32,
            k_frac,
            comp: RandK::new(k_frac.clamp(1e-9, 1.0)),
            dense_rounds,
            rule,
            dual_path: ctx.dual_path,
            runtime: ctx.runtime.clone(),
            z: vec![vec![0.0; d_pad]; degree],
            zsum: vec![0.0; d_pad],
            pending: 0,
            scratch_vals: Vec::new(),
            scratch_dense_a: vec![0.0; d_pad],
            scratch_dense_b: vec![0.0; d_pad],
            scratch_mask_in: vec![0.0; d_pad],
            scratch_mask_out: vec![0.0; d_pad],
        }
    }

    /// Mask RNG for messages flowing `from -> to` on `edge` at `round`.
    /// The direction tag is the *receiver's* side so ω_{i|j} (mask for
    /// what node i receives from j) is distinct from ω_{j|i}.
    fn mask_rng(&self, edge: usize, round: usize, receiver: usize) -> Pcg {
        Pcg::derive(
            self.seed,
            &[
                streams::EDGE_MASK,
                edge as u64,
                round as u64,
                receiver as u64,
            ],
        )
    }

    fn is_dense_round(&self, round: usize) -> bool {
        round < self.dense_rounds || self.k_frac >= 1.0
    }

    /// Debug-build invariant: the incrementally-maintained zsum matches
    /// its definition within f32 accumulation error.
    fn debug_check_zsum(&self) {
        let mut want = vec![0.0f32; self.d_pad];
        for (jj, &j) in self.graph.neighbors(self.node).iter().enumerate() {
            let a = self.graph.edge_sign(self.node, j);
            for (acc, &zv) in want.iter_mut().zip(&self.z[jj]) {
                *acc += a * zv;
            }
        }
        for (i, (&got, &w)) in self.zsum.iter().zip(&want).enumerate() {
            debug_assert!(
                (got - w).abs() < 1e-3 + 1e-3 * w.abs(),
                "zsum drift at {i}: {got} vs {w}"
            );
        }
    }

    fn recompute_zsum(&mut self) {
        self.zsum.iter_mut().for_each(|v| *v = 0.0);
        for (jj, &j) in self.graph.neighbors(self.node).iter().enumerate() {
            let a = self.graph.edge_sign(self.node, j);
            for (acc, &zv) in self.zsum.iter_mut().zip(&self.z[jj]) {
                *acc += a * zv;
            }
        }
    }

    /// Compressed exchange via the PJRT / L1-Pallas path (threaded
    /// engine only). One `dual_update` artifact call per neighbor; the
    /// artifact computes both the outbound y values and the z update, so
    /// the send happens after the kernel (results are identical — y uses
    /// the pre-update z inside the kernel).
    fn exchange_sparse_pjrt(&mut self, round: usize, w: &[f32],
                            comm: &NodeComm) -> Result<()> {
        let rt = Arc::clone(
            self.runtime
                .as_ref()
                .ok_or_else(|| anyhow!("DualPath::Pjrt requires a ModelRuntime"))?,
        );
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        // Phase 1: everyone sends. The kernel needs ycomp_in, which we
        // only have after receiving — so the PJRT path runs the kernel
        // twice per edge conceptually; in practice we compute y_send via
        // the kernel with a zero ycomp (z update discarded), send, then
        // after receive run it again for the z update. This keeps the
        // wire protocol identical to the native path.
        let mut masks_out: Vec<Vec<u32>> = Vec::with_capacity(neighbors.len());
        for &j in &neighbors {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let mut rng = self.mask_rng(e, round, j);
            masks_out.push(self.comp.sample_mask(self.d_pad, &mut rng));
        }
        for (jj, &j) in neighbors.iter().enumerate() {
            let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
            RandK::mask_to_dense(self.d_pad, &masks_out[jj],
                                 &mut self.scratch_mask_out);
            // zero ycomp / m_in: only the y output matters here.
            self.scratch_dense_a.iter_mut().for_each(|v| *v = 0.0);
            let (_, y_send) = rt
                .dual_update(
                    &self.z[jj],
                    w,
                    &self.scratch_dense_a,
                    &self.scratch_dense_a,
                    &self.scratch_mask_out,
                    self.theta,
                    taa,
                )
                .context("pjrt dual_update (send)")?;
            comm.send(j, Msg::Sparse(CooVec::gather(&y_send, &masks_out[jj])))?;
        }
        // Phase 2: receive and update z through the kernel.
        for (jj, &j) in neighbors.iter().enumerate() {
            let coo = comm.recv(j)?.into_sparse()?;
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let mut rng = self.mask_rng(e, round, self.node);
            let mask_in = self.comp.sample_mask(self.d_pad, &mut rng);
            debug_assert_eq!(coo.idx, mask_in, "shared-seed mask mismatch");
            RandK::mask_to_dense(self.d_pad, &mask_in, &mut self.scratch_mask_in);
            coo.scatter_into_cleared(&mut self.scratch_dense_b);
            self.scratch_mask_out.iter_mut().for_each(|v| *v = 0.0);
            let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
            let (z_new, _) = rt
                .dual_update(
                    &self.z[jj],
                    w,
                    &self.scratch_dense_b,
                    &self.scratch_mask_in,
                    &self.scratch_mask_out,
                    self.theta,
                    taa,
                )
                .context("pjrt dual_update (recv)")?;
            match self.rule {
                DualRule::CompressDiff => self.z[jj] = z_new,
                DualRule::CompressY => {
                    // The kernel implements Eq. (13); Eq. (11) is the
                    // naive rule, only supported natively.
                    let theta = self.theta;
                    let z = &mut self.z[jj];
                    for zv in z.iter_mut() {
                        *zv *= 1.0 - theta;
                    }
                    coo.axpy_into(theta, z);
                }
            }
        }
        Ok(())
    }

    /// Test/bench access: per-neighbor dual state.
    pub fn dual_state(&self) -> &[Vec<f32>] {
        &self.z
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }
}

impl NodeStateMachine for CEclNode {
    fn name(&self) -> String {
        NodeAlgorithm::name(self)
    }

    fn alpha_deg(&self) -> f32 {
        self.alpha_deg
    }

    fn zsum(&self) -> Option<&[f32]> {
        Some(&self.zsum)
    }

    fn round_begin(&mut self, round: usize, w: &mut [f32],
                   out: &mut Outbox) -> Result<()> {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.pending = neighbors.len();
        if self.is_dense_round(round) {
            // Line 4, dense wire: y_{i|j} = z_{i|j} − 2α a w.
            for (jj, &j) in neighbors.iter().enumerate() {
                let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
                let y: Vec<f32> = self.z[jj]
                    .iter()
                    .zip(w.iter())
                    .map(|(&zv, &wv)| zv - taa * wv)
                    .collect();
                out.send(j, Msg::Dense(y));
            }
        } else {
            // Lines 4–8, compressed wire: gather comp(y; ω_{j|i}).
            for (jj, &j) in neighbors.iter().enumerate() {
                let e = self
                    .graph
                    .edge_index(self.node, j)
                    .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
                // ω_{j|i}: what j receives from us.
                let mut rng = self.mask_rng(e, round, j);
                let mask_out = self.comp.sample_mask(self.d_pad, &mut rng);
                let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
                self.scratch_vals.clear();
                self.scratch_vals.reserve(mask_out.len());
                let z = &self.z[jj];
                for &idx in &mask_out {
                    let idx = idx as usize;
                    self.scratch_vals.push(z[idx] - taa * w[idx]);
                }
                out.send(
                    j,
                    Msg::Sparse(CooVec {
                        dim: self.d_pad,
                        idx: mask_out,
                        val: self.scratch_vals.clone(),
                    }),
                );
            }
        }
        Ok(())
    }

    fn on_message(&mut self, round: usize, from: usize, msg: Msg,
                  _w: &mut [f32], _out: &mut Outbox) -> Result<()> {
        ensure!(
            self.pending > 0,
            "C-ECL node {}: unexpected message from {from} in round {round}",
            self.node
        );
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        let theta = self.theta;
        if self.is_dense_round(round) {
            // Line 9, dense: z' = (1−θ)z + θ y_recv.
            let y_recv = msg.into_dense()?;
            ensure!(
                y_recv.len() == self.d_pad,
                "dense payload len {} != d_pad {}",
                y_recv.len(),
                self.d_pad
            );
            for (zv, &yv) in self.z[jj].iter_mut().zip(&y_recv) {
                *zv = (1.0 - theta) * *zv + theta * yv;
            }
        } else {
            // `zsum` is maintained INCREMENTALLY here: only the ~k·d
            // masked coordinates change, so touching the full deg·d_pad
            // state per round (the naive recompute) is wasted —
            // EXPERIMENTS.md §Perf records the win.
            let coo = msg.into_sparse()?;
            ensure!(
                coo.dim == self.d_pad,
                "sparse payload dim {} != d_pad {}",
                coo.dim,
                self.d_pad
            );
            let a = self.graph.edge_sign(self.node, from);
            match self.rule {
                DualRule::CompressDiff => {
                    // z += θ(comp(y) − comp(z)) on masked coords only.
                    let z = &mut self.z[jj];
                    for (&idx, &yv) in coo.idx.iter().zip(&coo.val) {
                        let idx = idx as usize;
                        let delta = theta * (yv - z[idx]);
                        z[idx] += delta;
                        self.zsum[idx] += a * delta;
                    }
                }
                DualRule::CompressY => {
                    // Eq. (11): z' = (1−θ)z + θ comp(y). Touches every
                    // coordinate — fall back to a full pass (ablation
                    // path only).
                    let z = &mut self.z[jj];
                    for (zv, acc) in z.iter_mut().zip(self.zsum.iter_mut()) {
                        let delta = -theta * *zv;
                        *zv += delta;
                        *acc += a * delta;
                    }
                    for (&idx, &yv) in coo.idx.iter().zip(&coo.val) {
                        let idx = idx as usize;
                        let delta = theta * yv;
                        z[idx] += delta;
                        self.zsum[idx] += a * delta;
                    }
                }
            }
        }
        self.pending -= 1;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        self.pending == 0
    }

    fn round_end(&mut self, round: usize, _w: &mut [f32]) -> Result<()> {
        ensure!(
            self.pending == 0,
            "C-ECL node {}: round_end with {} messages outstanding",
            self.node,
            self.pending
        );
        if self.is_dense_round(round) {
            self.recompute_zsum();
        } else if cfg!(debug_assertions) {
            self.debug_check_zsum();
        }
        Ok(())
    }
}

impl NodeAlgorithm for CEclNode {
    fn name(&self) -> String {
        match (self.rule, self.k_frac >= 1.0) {
            (DualRule::CompressDiff, true) => "ECL".to_string(),
            (DualRule::CompressDiff, false) => {
                format!("C-ECL ({}%)", (self.k_frac * 100.0).round() as u32)
            }
            (DualRule::CompressY, _) => {
                format!("naive-C-ECL ({}%)", (self.k_frac * 100.0).round() as u32)
            }
        }
    }

    fn alpha_deg(&self) -> f32 {
        self.alpha_deg
    }

    fn zsum(&self) -> Option<&[f32]> {
        Some(&self.zsum)
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        if !self.is_dense_round(round) && self.dual_path == DualPath::Pjrt {
            self.exchange_sparse_pjrt(round, w, comm)?;
            self.recompute_zsum();
            return Ok(());
        }
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        super::drive_blocking(self, &neighbors, round, w, comm)
    }
}

// The native fused single-edge update is re-exported for benches.
pub use native::dual_update_sparse;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;

    fn tiny_manifest() -> crate::model::DatasetManifest {
        // A synthetic manifest (no artifact files needed for these tests).
        let text = "\
version 1
smoke smoke.hlo.txt
dataset tiny
d 30
d_pad 32
input 2 2 1
classes 3
batch 4
eval_batch 8
train_step ts.hlo.txt
eval_step ev.hlo.txt
dual_update du.hlo.txt
init_w init.bin
layer a 5 6
end
";
        Manifest::parse(text, std::path::Path::new("/nonexistent"))
            .unwrap()
            .dataset("tiny")
            .unwrap()
            .clone()
    }

    fn ctx(node: usize, graph: &Arc<Graph>) -> BuildCtx {
        BuildCtx {
            node,
            graph: Arc::clone(graph),
            manifest: tiny_manifest(),
            seed: 77,
            eta: 0.05,
            local_steps: 5,
            rounds_per_epoch: 2,
            dual_path: DualPath::Native,
            runtime: None,
        }
    }

    /// Run one exchange over a 3-ring and return the nodes.
    fn run_ring_exchange(k_frac: f64, theta: f32, round: usize)
                         -> Vec<CEclNode> {
        let graph = Arc::new(Graph::ring(3));
        let (comms, _) = build_bus(&graph);
        let mut nodes: Vec<CEclNode> = (0..3)
            .map(|i| {
                let mut n = CEclNode::new(&ctx(i, &graph), k_frac, theta, 0,
                                          DualRule::CompressDiff);
                // Seed distinct non-trivial dual state + w.
                let mut rng = Pcg::new(100 + i as u64);
                for zv in n.z.iter_mut().flatten() {
                    *zv = rng.normal_f32();
                }
                // Restore the zsum invariant after direct z seeding (the
                // incremental maintenance assumes it holds on entry).
                n.recompute_zsum();
                n
            })
            .collect();
        let ws: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut rng = Pcg::new(200 + i as u64);
                (0..32).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        // Drive the exchange on threads (blocking recv needs concurrency).
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .iter_mut()
                .zip(comms)
                .zip(ws)
                .map(|((node, comm), mut w)| {
                    s.spawn(move || {
                        node.exchange(round, &mut w, &comm).unwrap()
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        nodes
    }

    #[test]
    fn dense_exchange_is_eq5() {
        // θ=1, k=1 (ECL): z_{i|j}' must equal y_{j|i} = z_{j|i} − 2α a_{j|i} w_j.
        let graph = Arc::new(Graph::ring(3));
        let nodes_before = run_ring_exchange(1.0, 1.0, 0);
        // Recompute expectations manually by re-deriving initial state.
        // (Initial z and w reconstructed with the same seeds as above.)
        let init_z = |i: usize| -> Vec<Vec<f32>> {
            let mut rng = Pcg::new(100 + i as u64);
            (0..2)
                .map(|_| (0..32).map(|_| rng.normal_f32()).collect())
                .collect()
        };
        let init_w = |i: usize| -> Vec<f32> {
            let mut rng = Pcg::new(200 + i as u64);
            (0..32).map(|_| rng.normal_f32()).collect()
        };
        for i in 0..3usize {
            for (jj, &j) in graph.neighbors(i).iter().enumerate() {
                let ii = graph.neighbors(j).iter().position(|&x| x == i).unwrap();
                let alpha_j = nodes_before[j].alpha();
                let a_ji = graph.edge_sign(j, i);
                let zj = init_z(j);
                let wj = init_w(j);
                for t in 0..32 {
                    let y_ji = zj[ii][t] - 2.0 * alpha_j * a_ji * wj[t];
                    let got = nodes_before[i].z[jj][t];
                    assert!(
                        (got - y_ji).abs() < 1e-5,
                        "node {i} nb {j} coord {t}: {got} vs {y_ji}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_exchange_touches_only_masked_coords() {
        let nodes = run_ring_exchange(0.2, 1.0, 3);
        // With k=20% roughly 80% of coordinates keep their initial value.
        for (i, node) in nodes.iter().enumerate() {
            let mut rng = Pcg::new(100 + i as u64);
            let orig: Vec<Vec<f32>> = (0..2)
                .map(|_| (0..32).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut unchanged = 0;
            let mut total = 0;
            for jj in 0..2 {
                for t in 0..32 {
                    total += 1;
                    if node.z[jj][t] == orig[jj][t] {
                        unchanged += 1;
                    }
                }
            }
            assert!(unchanged > total / 2, "unchanged {unchanged}/{total}");
            assert!(unchanged < total, "some coords must update");
        }
    }

    #[test]
    fn zsum_matches_definition() {
        let graph = Arc::new(Graph::ring(3));
        let nodes = run_ring_exchange(0.5, 0.8, 1);
        for (i, node) in nodes.iter().enumerate() {
            for t in 0..32 {
                let mut want = 0.0f32;
                for (jj, &j) in graph.neighbors(i).iter().enumerate() {
                    want += graph.edge_sign(i, j) * node.z[jj][t];
                }
                assert!((node.zsum[t] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn alpha_deg_consistency() {
        let graph = Arc::new(Graph::ring(4));
        let node = CEclNode::new(&ctx(0, &graph), 0.1, 1.0, 0,
                                 DualRule::CompressDiff);
        assert!((NodeAlgorithm::alpha_deg(&node) - node.alpha() * 2.0).abs()
                < 1e-6);
        // Eq. 47 with η=0.05, |N|=2, K=5, k=0.1: α = 1/(0.05·2·49).
        assert!((node.alpha() - 1.0 / (0.05 * 2.0 * 49.0)).abs() < 1e-4);
    }

    #[test]
    fn warmup_rounds_use_dense() {
        let graph = Arc::new(Graph::ring(3));
        let node = CEclNode::new(&ctx(0, &graph), 0.1, 1.0, 2,
                                 DualRule::CompressDiff);
        assert!(node.is_dense_round(0));
        assert!(node.is_dense_round(1));
        assert!(!node.is_dense_round(2));
    }

    #[test]
    fn state_machine_round_lifecycle() {
        // round_begin queues one message per neighbor; delivering both
        // completes the round; a third message errors.
        let graph = Arc::new(Graph::ring(3));
        let mut node = CEclNode::new(&ctx(0, &graph), 0.5, 1.0, 0,
                                     DualRule::CompressDiff);
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &mut w, &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert!(!node.round_complete());
        // Feed back each neighbor's expected payload (empty-ish COO with
        // the right mask shape): reuse the messages addressed to us from
        // identically-seeded peers.
        for &j in &[1usize, 2] {
            let mut peer = CEclNode::new(&ctx(j, &graph), 0.5, 1.0, 0,
                                         DualRule::CompressY);
            let mut peer_out = Outbox::new();
            let mut wj = vec![0.25f32; 32];
            NodeStateMachine::round_begin(&mut peer, 0, &mut wj, &mut peer_out)
                .unwrap();
            let msg = peer_out
                .drain()
                .find(|(to, _)| *to == 0)
                .map(|(_, m)| m)
                .unwrap();
            NodeStateMachine::on_message(&mut node, 0, j, msg, &mut w, &mut out)
                .unwrap();
        }
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 0, &mut w).unwrap();
        // Extra message after completion is a protocol error.
        let err = NodeStateMachine::on_message(
            &mut node,
            0,
            1,
            Msg::Scalar(0.0),
            &mut w,
            &mut out,
        );
        assert!(err.is_err());
    }
}
