//! ECL / C-ECL node: the paper's contribution.
//!
//! Maintains the per-edge dual variables `z_{i|j}` of the Douglas–
//! Rachford splitting and implements Alg. 1:
//!
//! * line 4 — `y_{i|j} = z_{i|j} − 2α A_{i|j} w_i`
//! * lines 5–6 — *omitted*: shared-seed randomness (masks ω, QSGD
//!   rounding draws) derives from [`EdgeCtx`] identically at both
//!   endpoints
//! * lines 7–8 — exchange `comp(y)` as an encoded [`Frame`] whose byte
//!   length *is* the metered wire size
//! * line 9 — `z_{i|j} += θ·comp(y_{j|i} − z_{i|j}; ω_{i|j})`, expanded
//!   via Assumption-1 linearity to `θ·(comp(y_{j|i}) − comp(z_{i|j}))`
//!
//! The compression operator is a pluggable [`EdgeCodec`] built from a
//! [`CodecSpec`] — one stateful instance per neighbor slot, so codecs
//! with per-edge memory (error feedback) Just Work.  Codecs that are
//! linear for fixed ω *and* expose a seed-derivable sparse support
//! (identity, rand-k in either wire mode) run the Eq. (13)
//! `DualRule::CompressDiff` update touching only `|ω|` coordinates;
//! value-dependent or quantizing codecs (top-k, QSGD, sign, `ef+…`)
//! must run the naive Eq. (11) `DualRule::CompressY` rule — the §3.2
//! ablation — which `build_machine`/`build_node` select automatically.
//!
//! With the full-rate mask (`rand_k:1`) the node *is* the uncompressed
//! ECL: it uses the dense wire (4 B/coord, no index overhead), as do
//! the paper's §5.1 first-epoch warmup rounds.  The `identity` codec
//! ships byte-identical dense frames through the codec path instead —
//! pinned equal to ECL's byte counts by the test suite.
//!
//! The protocol is written once in the poll-driven
//! [`NodeStateMachine`] form (`round_begin` queues the outbound
//! `comp(y)`s, `on_message` applies line 9 per neighbor, `round_end`
//! restores the `zsum` invariant); the blocking
//! [`NodeAlgorithm::exchange`] used by the threaded engine is a thin
//! driver over the same methods, so both engines run identical wire
//! traffic and identical arithmetic.
//!
//! ## Stale-dual async rounds
//!
//! Every edge carries its own round clock
//! ([`RoundPolicy`](super::RoundPolicy)): `on_message` receives the
//! *sender's* round stamp and applies line 9 with the shared-seed mask
//! of **that** round (`EdgeCtx.round = msg_round`), so both endpoints
//! derive the identical ω no matter how far their clocks have drifted.
//! Under `Async { max_staleness }` the node performs its local update
//! as soon as every edge has delivered a dual from round
//! `≥ r − max_staleness`, consuming the freshest `z_{i|j}` it has per
//! neighbor; a dual older than the bound is a hard protocol error
//! enforced at `round_end`.  The per-edge codec instances are the
//! natural home for this bookkeeping: codec state (error-feedback
//! residuals, masks) is already keyed per edge and per message round,
//! so stale consumption never desynchronizes the shared-seed streams.
//!
//! Two execution paths for line 4+9, semantically identical:
//! [`DualPath::Native`] (fused rust loops, the default hot path) and
//! [`DualPath::Pjrt`] (the L1 Pallas `dual_update` artifact through
//! PJRT; threaded engine only, shared-seed mask codecs only).
//! Integration tests assert they agree elementwise.

use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::{CodecSpec, EdgeCodec, EdgeCtx, RandK, WireMode};
use crate::graph::{Graph, TopologyView};
use crate::linalg::{dual_diff_mix_f32, dual_mix_f32};
use crate::model::Arena;
use crate::runtime::{native, ModelRuntime};

use super::{paper_alpha, BuildCtx, EdgeClock, NodeAlgorithm,
            NodeStateMachine, RoundPolicy};

/// Which implementation executes the fused dual update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualPath {
    /// Pure-rust fused loops (default; see EXPERIMENTS.md §Perf).
    Native,
    /// The L1 Pallas kernel through PJRT.
    Pjrt,
}

/// Eq. (13) (the C-ECL) vs Eq. (11) (naive ablation / non-linear
/// codecs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualRule {
    CompressDiff,
    CompressY,
}

pub struct CEclNode {
    node: usize,
    graph: Arc<Graph>,
    seed: u64,
    d_pad: usize,
    theta: f32,
    /// Per-node α (Eq. 46/47 — depends on |N_i| and the codec's τ).
    alpha: f32,
    alpha_deg: f32,
    codec_spec: CodecSpec,
    /// One stateful codec instance per neighbor slot (sorted neighbor
    /// order) — per-edge state such as error-feedback residuals lives
    /// inside.
    codecs: Vec<Box<dyn EdgeCodec>>,
    /// Rounds at the start trained with the dense wire (paper §5.1
    /// warmup).
    dense_rounds: usize,
    rule: DualRule,
    dual_path: DualPath,
    runtime: Option<Arc<ModelRuntime>>,
    /// Dual state, one arena row per neighbor slot (sorted neighbor
    /// order) — a single contiguous slab, stride `d_pad`.  Dead slots
    /// are retired to zero until their edge is reborn.
    z: Arena,
    /// Cached `Σ_j A_{i|j} z_{i|j}` over live edges.
    zsum: Vec<f32>,
    /// Sync vs bounded-staleness async rounds.
    policy: RoundPolicy,
    /// The node's own round clock (set by `round_begin`).
    cur_round: usize,
    /// Per-edge clocks: freshest dual stamp, liveness, activation.
    clocks: Vec<EdgeClock>,
    /// Cached edge incarnation per neighbor slot — a view epoch ahead
    /// of this triggers the birth lifecycle (fresh codec, warm-started
    /// dual).
    edge_epochs: Vec<u32>,
    /// Last `TopologyView::version` synced against (0 = static full).
    seen_view: u64,
    /// Matrix/vector layout views, kept for rebinding freshly built
    /// codecs on edge birth.
    mats: Vec<(usize, usize, usize)>,
    vecs: Vec<(usize, usize)>,
    /// Currently-live degree (scales `alpha_deg` — Eq. 46's α|N_i|
    /// with the *current* N_i).
    live_deg: usize,
    /// Cached static full view for the (epoch-constant) blocking
    /// engine — built once instead of per exchange round.
    full_view: Arc<TopologyView>,
    /// Largest per-edge lag consumed at any `round_end`.
    max_lag_seen: usize,
    /// A dense payload rewrote `z` wholesale since the last `round_end`
    /// (warmup rounds, effectively-dense codecs): `zsum` must be
    /// recomputed rather than maintained incrementally.
    zsum_dirty: bool,
    // -- preallocated scratch (no allocation in the round hot loop) -----
    scratch_y: Vec<f32>,
    scratch_dense_a: Vec<f32>,
    scratch_mask_in: Vec<f32>,
    scratch_mask_out: Vec<f32>,
    /// Reusable decode target: every dense `decode_into` lands here.
    scratch_recv: Vec<f32>,
}

impl CEclNode {
    pub fn new(ctx: &BuildCtx, codec: CodecSpec, theta: f32,
               dense_rounds: usize, rule: DualRule) -> Result<CEclNode> {
        let degree = ctx.graph.degree(ctx.node);
        ensure!(degree > 0, "ECL requires no isolated nodes (Assumption 4)");
        codec.validate()?;
        ensure!(
            rule == DualRule::CompressY || codec.is_linear_for_fixed_omega(),
            "codec `{}` violates fixed-ω linearity (Eqs. 8–9); the Eq. 13 \
             rule cannot run it — use the Eq. 11 rule",
            codec.name()
        );
        let d_pad = ctx.manifest.d_pad;
        let alpha = paper_alpha(ctx.eta, degree, ctx.local_steps,
                                codec.tau(d_pad));
        let mut codecs: Vec<Box<dyn EdgeCodec>> =
            (0..degree).map(|_| codec.build()).collect();
        // Structure-aware codecs (low_rank) compress per layer matrix —
        // hand every codec instance the manifest's layout (no-op for
        // the rest of the codec families).
        let mats: Vec<(usize, usize, usize)> = ctx
            .manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vecs: Vec<(usize, usize)> = ctx
            .manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        for c in codecs.iter_mut() {
            c.bind_layout(&mats, &vecs);
        }
        Ok(CEclNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            seed: ctx.seed,
            d_pad,
            theta,
            alpha,
            alpha_deg: alpha * degree as f32,
            codec_spec: codec,
            codecs,
            dense_rounds,
            rule,
            dual_path: ctx.dual_path,
            runtime: ctx.runtime.clone(),
            z: Arena::zeros(degree, d_pad),
            zsum: vec![0.0; d_pad],
            policy: ctx.round_policy,
            cur_round: 0,
            clocks: vec![EdgeClock::born(0); degree],
            edge_epochs: vec![0; degree],
            seen_view: 0,
            mats,
            vecs,
            live_deg: degree,
            full_view: Arc::new(TopologyView::full(
                ctx.graph.edges().len(),
            )),
            max_lag_seen: 0,
            zsum_dirty: false,
            scratch_y: Vec::with_capacity(d_pad),
            scratch_dense_a: vec![0.0; d_pad],
            scratch_mask_in: vec![0.0; d_pad],
            scratch_mask_out: vec![0.0; d_pad],
            scratch_recv: vec![0.0; d_pad],
        })
    }

    /// Per-edge lifecycle sync against the engine's topology view.
    /// Static runs never get past the version compare.  On a fresh
    /// incarnation (view epoch ahead of the cached one): allocate a new
    /// codec instance (stale error-feedback residuals can never
    /// resurrect) and warm-start the dual from the node's current
    /// primal at the consensus fixed point `z_{i|j} = α A_{i|j} w_i` —
    /// what keeps the Eq. 11 update sane on a mid-training edge birth.
    /// On edge death: retire the dual (zero it out of `zsum`).
    fn sync_view(&mut self, view: &TopologyView, w: &[f32]) -> Result<()> {
        if view.version() == self.seen_view {
            return Ok(());
        }
        self.seen_view = view.version();
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let mut changed = false;
        for (jj, &j) in neighbors.iter().enumerate() {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let life = view.edge_life(e);
            if life.epoch != self.edge_epochs[jj] {
                // Birth of a fresh incarnation.
                self.edge_epochs[jj] = life.epoch;
                let mut codec = self.codec_spec.build();
                codec.bind_layout(&self.mats, &self.vecs);
                self.codecs[jj] = codec;
                if life.live {
                    // Warm-start from the current primal.
                    let a = self.graph.edge_sign(self.node, j);
                    let alpha = self.alpha;
                    for (zv, &wv) in self.z.row_mut(jj).iter_mut().zip(w.iter()) {
                        *zv = alpha * a * wv;
                    }
                } else {
                    // The incarnation is already dead again (several
                    // transitions observed at once, e.g. by a direct
                    // TopologyView user): a dead slot carries no dual.
                    for zv in self.z.row_mut(jj).iter_mut() {
                        *zv = 0.0;
                    }
                }
                let mut clock = EdgeClock::born(life.activation_round);
                clock.live = life.live;
                self.clocks[jj] = clock;
                changed = true;
            } else if life.live != self.clocks[jj].live {
                self.clocks[jj].live = life.live;
                if !life.live {
                    // Typed teardown: the dual is retired with the
                    // edge; rebirth rebuilds it from the then-current
                    // primal under a new epoch.
                    for zv in self.z.row_mut(jj).iter_mut() {
                        *zv = 0.0;
                    }
                }
                changed = true;
            }
        }
        if changed {
            // The view's helper is the canonical live-degree query (its
            // answer is pinned equal to the clocks' live count).
            self.live_deg = view.live_degree(&self.graph, self.node);
            debug_assert_eq!(
                self.live_deg,
                self.clocks.iter().filter(|c| c.live).count()
            );
            self.alpha_deg = self.alpha * self.live_deg as f32;
            self.recompute_zsum();
            self.zsum_dirty = false;
        }
        Ok(())
    }

    /// Shared-seed context for messages received by `receiver` on
    /// `edge` at `round` — both endpoints construct it identically, so
    /// ω_{i|j} (what node i receives from j) is distinct from ω_{j|i}.
    /// `jj` is the neighbor slot: the context carries the slot's
    /// current edge epoch, keeping derived streams in lockstep across a
    /// remove/re-add (and bit-identical to the legacy derivation while
    /// the epoch is 0).
    fn edge_ctx(&self, jj: usize, edge: usize, round: usize,
                receiver: usize) -> EdgeCtx {
        EdgeCtx {
            seed: self.seed,
            edge,
            round,
            receiver,
            dim: self.d_pad,
            epoch: self.edge_epochs[jj],
        }
    }

    fn is_dense_round(&self, round: usize) -> bool {
        round < self.dense_rounds || self.codec_spec.is_effectively_dense()
    }

    /// Debug-build invariant: the incrementally-maintained zsum matches
    /// its definition within f32 accumulation error.
    fn debug_check_zsum(&self) {
        let mut want = vec![0.0f32; self.d_pad];
        for (jj, &j) in self.graph.neighbors(self.node).iter().enumerate() {
            let a = self.graph.edge_sign(self.node, j);
            for (acc, &zv) in want.iter_mut().zip(self.z.row(jj)) {
                *acc += a * zv;
            }
        }
        for (i, (&got, &w)) in self.zsum.iter().zip(&want).enumerate() {
            debug_assert!(
                (got - w).abs() < 1e-3 + 1e-3 * w.abs(),
                "zsum drift at {i}: {got} vs {w}"
            );
        }
    }

    fn recompute_zsum(&mut self) {
        self.zsum.iter_mut().for_each(|v| *v = 0.0);
        for (jj, &j) in self.graph.neighbors(self.node).iter().enumerate() {
            let a = self.graph.edge_sign(self.node, j);
            for (acc, &zv) in self.zsum.iter_mut().zip(self.z.row(jj)) {
                *acc += a * zv;
            }
        }
    }

    /// Compressed exchange via the PJRT / L1-Pallas path (threaded
    /// engine only; requires a codec with seed-derivable support, i.e.
    /// the rand-k family).  One `dual_update` artifact call per
    /// neighbor; the artifact computes both the outbound y values and
    /// the z update, so the send happens after the kernel (results are
    /// identical — y uses the pre-update z inside the kernel).
    fn exchange_sparse_pjrt(&mut self, round: usize, w: &[f32],
                            comm: &NodeComm) -> Result<()> {
        let rt = Arc::clone(
            self.runtime
                .as_ref()
                .ok_or_else(|| anyhow!("DualPath::Pjrt requires a ModelRuntime"))?,
        );
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        // Phase 1: everyone sends. The kernel needs ycomp_in, which we
        // only have after receiving — so the PJRT path runs the kernel
        // twice per edge conceptually; in practice we compute y_send via
        // the kernel with a zero ycomp (z update discarded), send, then
        // after receive run it again for the z update. This keeps the
        // wire protocol identical to the native path.
        for (jj, &j) in neighbors.iter().enumerate() {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let ctx_e = self.edge_ctx(jj, e, round, j);
            let mask_out = self.codecs[jj].sparse_support(&ctx_e).ok_or_else(
                || anyhow!(
                    "DualPath::Pjrt requires a shared-seed mask codec \
                     (rand-k family), got `{}`",
                    self.codec_spec.name()
                ),
            )?;
            RandK::mask_to_dense(self.d_pad, &mask_out,
                                 &mut self.scratch_mask_out);
            let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
            // zero ycomp / m_in: only the y output matters here.
            self.scratch_dense_a.iter_mut().for_each(|v| *v = 0.0);
            let (_, y_send) = rt
                .dual_update(
                    self.z.row(jj),
                    w,
                    &self.scratch_dense_a,
                    &self.scratch_dense_a,
                    &self.scratch_mask_out,
                    self.theta,
                    taa,
                )
                .context("pjrt dual_update (send)")?;
            let codec = &mut self.codecs[jj];
            let frame = codec.encode(&y_send, &ctx_e);
            comm.send(j, Msg::Frame(frame))?;
        }
        // Phase 2: receive, decode, and update z through the kernel.
        for (jj, &j) in neighbors.iter().enumerate() {
            let frame = comm.recv(j)?.into_frame()?;
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let ctx_e = self.edge_ctx(jj, e, round, self.node);
            self.codecs[jj].decode_into(&frame, &ctx_e, &mut self.scratch_recv)?;
            let mask_in = self.codecs[jj]
                .sparse_support(&ctx_e)
                .ok_or_else(|| anyhow!("pjrt path needs a mask codec"))?;
            RandK::mask_to_dense(self.d_pad, &mask_in, &mut self.scratch_mask_in);
            self.scratch_mask_out.iter_mut().for_each(|v| *v = 0.0);
            let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
            let (z_new, _) = rt
                .dual_update(
                    self.z.row(jj),
                    w,
                    &self.scratch_recv,
                    &self.scratch_mask_in,
                    &self.scratch_mask_out,
                    self.theta,
                    taa,
                )
                .context("pjrt dual_update (recv)")?;
            match self.rule {
                DualRule::CompressDiff => {
                    self.z.row_mut(jj).copy_from_slice(&z_new)
                }
                DualRule::CompressY => {
                    // The kernel implements Eq. (13); Eq. (11) is the
                    // naive rule, applied densely here (the decoded y is
                    // zero off the mask, so this matches the sparse form).
                    let theta = self.theta;
                    let z = self.z.row_mut(jj);
                    for (zv, &yv) in z.iter_mut().zip(&self.scratch_recv) {
                        *zv = (1.0 - theta) * *zv + theta * yv;
                    }
                }
            }
        }
        Ok(())
    }

    /// Test/bench access: per-neighbor dual state (arena row = neighbor
    /// slot in sorted neighbor order).
    pub fn dual_state(&self) -> &Arena {
        &self.z
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    fn display_name(&self) -> String {
        cecl_display_name(self.rule, &self.codec_spec)
    }
}

/// The dual rule a codec licenses: Eq. (13) for fixed-ω linear codecs,
/// the naive Eq. (11) for everything else.  Single source of truth for
/// `AlgorithmSpec::name`, `build_cecl`, and the tests.
pub fn rule_for_codec(spec: &CodecSpec) -> DualRule {
    if spec.is_linear_for_fixed_omega() {
        DualRule::CompressDiff
    } else {
        DualRule::CompressY
    }
}

/// Canonical display name for a C-ECL-family configuration — shared by
/// `AlgorithmSpec::name` and the node itself so run labels never drift.
pub fn cecl_display_name(rule: DualRule, spec: &CodecSpec) -> String {
    match (rule, spec) {
        (DualRule::CompressDiff, CodecSpec::RandK { k_frac, .. })
            if *k_frac >= 1.0 =>
        {
            "ECL".to_string()
        }
        (
            DualRule::CompressDiff,
            CodecSpec::RandK { k_frac, mode: WireMode::Explicit },
        ) => format!("C-ECL ({}%)", (*k_frac * 100.0).round() as u32),
        (
            DualRule::CompressY,
            CodecSpec::RandK { k_frac, mode: WireMode::Explicit },
        ) => format!("naive-C-ECL ({}%)", (*k_frac * 100.0).round() as u32),
        (DualRule::CompressDiff, spec) => format!("C-ECL [{}]", spec.name()),
        (DualRule::CompressY, spec) => {
            format!("C-ECL [{}] (Eq.11)", spec.name())
        }
    }
}

impl NodeStateMachine for CEclNode {
    fn name(&self) -> String {
        self.display_name()
    }

    fn alpha_deg(&self) -> f32 {
        self.alpha_deg
    }

    fn zsum(&self) -> Option<&[f32]> {
        Some(&self.zsum)
    }

    fn round_begin(&mut self, round: usize, view: &TopologyView,
                   w: &mut [f32], out: &mut Outbox) -> Result<()> {
        self.sync_view(view, w)?;
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.cur_round = round;
        if self.is_dense_round(round) {
            // Line 4, dense wire: y_{i|j} = z_{i|j} − 2α a w.
            for (jj, &j) in neighbors.iter().enumerate() {
                if !self.clocks[jj].active(round) {
                    continue; // dead or not-yet-activated edge
                }
                let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
                let y: Vec<f32> = self.z
                    .row(jj)
                    .iter()
                    .zip(w.iter())
                    .map(|(&zv, &wv)| zv - taa * wv)
                    .collect();
                out.send(j, Msg::Dense(y));
            }
        } else {
            // Lines 4–8, codec wire: encode comp(y; ω_{j|i}) into an
            // owned byte frame — the frame length is the wire size.
            // Mask codecs evaluate y = z − 2αa·w on the |ω| kept
            // coordinates only (`encode_from`); dense-input codecs
            // (quantizers) stage the full y in preallocated scratch.
            for (jj, &j) in neighbors.iter().enumerate() {
                if !self.clocks[jj].active(round) {
                    continue; // dead or not-yet-activated edge
                }
                let e = self
                    .graph
                    .edge_index(self.node, j)
                    .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
                // ω_{j|i}: what j receives from us.
                let ctx_e = self.edge_ctx(jj, e, round, j);
                let taa = 2.0 * self.alpha * self.graph.edge_sign(self.node, j);
                let codec = &mut self.codecs[jj];
                let z = self.z.row(jj);
                let frame = match codec
                    .encode_from(&|i| z[i] - taa * w[i], &ctx_e)
                {
                    Some(frame) => frame,
                    None => {
                        self.scratch_y.clear();
                        self.scratch_y.extend(
                            z.iter()
                                .zip(w.iter())
                                .map(|(&zv, &wv)| zv - taa * wv),
                        );
                        codec.encode(&self.scratch_y, &ctx_e)
                    }
                };
                out.send(j, Msg::Frame(frame));
            }
        }
        Ok(())
    }

    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  view: &TopologyView, w: &mut [f32],
                  _out: &mut Outbox) -> Result<()> {
        self.sync_view(view, w)?;
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        ensure!(
            self.clocks[jj].live,
            "node {}: message from {from} on a churned-out edge \
             (the engine should have dropped it)",
            self.node
        );
        super::admit_message(self.policy, self.node, from, self.cur_round,
                             self.clocks[jj].round, msg_round)?;
        let theta = self.theta;
        // Every decode keys its shared-seed context off the SENDER's
        // round stamp, so a stale or ahead-of-us frame derives the
        // exact ω the sender encoded with.
        if self.is_dense_round(msg_round) {
            // Line 9, dense: z' = (1−θ)z + θ y_recv.
            let y_recv = msg.into_dense()?;
            ensure!(
                y_recv.len() == self.d_pad,
                "dense payload len {} != d_pad {}",
                y_recv.len(),
                self.d_pad
            );
            for (zv, &yv) in self.z.row_mut(jj).iter_mut().zip(&y_recv) {
                *zv = (1.0 - theta) * *zv + theta * yv;
            }
            self.zsum_dirty = true;
        } else {
            // Decode validates every byte — a corrupt frame surfaces a
            // typed CodecError here instead of aborting the process.
            let frame = msg.into_frame()?;
            let e = self
                .graph
                .edge_index(self.node, from)
                .ok_or_else(|| {
                    anyhow!("({}, {from}) is not an edge", self.node)
                })?;
            let ctx_e = self.edge_ctx(jj, e, msg_round, self.node);
            let a = self.graph.edge_sign(self.node, from);
            let codec = &mut self.codecs[jj];
            match self.rule {
                DualRule::CompressDiff => {
                    // z += θ(comp(y) − comp(z)) on the ω support only —
                    // `zsum` is maintained INCREMENTALLY: only the ~k·d
                    // masked coordinates change, so touching the full
                    // deg·d_pad state per round (the naive recompute) is
                    // wasted — EXPERIMENTS.md §Perf records the win.
                    // `decode_sparse` keeps this O(|ω|): no dense
                    // materialization, at most one mask derivation.
                    if let Some((idx, vals)) =
                        codec.decode_sparse(&frame, &ctx_e)?
                    {
                        let z = self.z.row_mut(jj);
                        for (&i, &yv) in idx.iter().zip(&vals) {
                            let i = i as usize;
                            debug_assert!(i < self.d_pad);
                            let delta = theta * (yv - z[i]);
                            z[i] += delta;
                            self.zsum[i] += a * delta;
                        }
                    } else if codec.is_full_support() {
                        // Identity: comp(z) = z, so Eq. (13) reduces to
                        // the fused dense update — no support list.
                        // `decode_into` lands in persistent scratch (no
                        // allocation) and the fused kernel applies the
                        // same per-element expression tree as the old
                        // zip loop.
                        codec.decode_into(&frame, &ctx_e,
                                          &mut self.scratch_recv)?;
                        dual_diff_mix_f32(self.z.row_mut(jj),
                                          &mut self.zsum,
                                          &self.scratch_recv, theta, a);
                    } else {
                        // Unreachable with the current codec set: the
                        // Eq. 13 rule requires fixed-ω linearity, and
                        // every linear codec is either sparse-decodable
                        // (rand-k) or full-support (identity).  A new
                        // linear codec must implement one of the two.
                        bail!(
                            "codec `{}` supports neither sparse decode \
                             nor full-support dense decode; the Eq. 13 \
                             rule cannot run it",
                            self.codec_spec.name()
                        );
                    }
                }
                DualRule::CompressY => {
                    // Eq. (11): z' = (1−θ)z + θ comp(y). Touches every
                    // coordinate (comp(y) is dense for quantizers); the
                    // decode lands in persistent scratch and the fused
                    // kernel keeps the exact expression tree.
                    codec.decode_into(&frame, &ctx_e,
                                      &mut self.scratch_recv)?;
                    dual_mix_f32(self.z.row_mut(jj), &mut self.zsum,
                                 &self.scratch_recv, theta, a);
                }
            }
        }
        self.clocks[jj].round = msg_round as i64;
        self.clocks[jj].spoken = true;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        super::staleness_gate(self.policy, self.cur_round, &self.clocks)
    }

    fn round_end(&mut self, round: usize, view: &TopologyView,
                 w: &mut [f32]) -> Result<()> {
        self.sync_view(view, w)?;
        // The staleness bound is a hard protocol invariant: finishing a
        // round with a dual older than `max_staleness` is an error, not
        // a silent quality loss (the property tests pin this).  It is
        // evaluated over currently-live edges only.
        let lag = super::check_staleness(self.policy, self.node, "dual",
                                         round, &self.clocks)?;
        self.max_lag_seen = self.max_lag_seen.max(lag);
        if self.zsum_dirty {
            self.recompute_zsum();
            self.zsum_dirty = false;
        } else if cfg!(debug_assertions) {
            self.debug_check_zsum();
        }
        Ok(())
    }

    fn on_topology(&mut self, view: &TopologyView, w: &mut [f32],
                   _out: &mut Outbox) -> Result<()> {
        self.sync_view(view, w)
    }

    fn max_staleness_seen(&self) -> usize {
        self.max_lag_seen
    }

    fn policy(&self) -> Option<RoundPolicy> {
        Some(self.policy)
    }
}

impl NodeAlgorithm for CEclNode {
    fn name(&self) -> String {
        self.display_name()
    }

    fn alpha_deg(&self) -> f32 {
        self.alpha_deg
    }

    fn zsum(&self) -> Option<&[f32]> {
        Some(&self.zsum)
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        if !self.is_dense_round(round) && self.dual_path == DualPath::Pjrt {
            self.exchange_sparse_pjrt(round, w, comm)?;
            self.recompute_zsum();
            return Ok(());
        }
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let view = Arc::clone(&self.full_view);
        super::drive_blocking(self, &neighbors, &view, round, w, comm)
    }
}

// The native fused single-edge update is re-exported for benches.
pub use native::dual_update_sparse;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;
    use crate::util::rng::Pcg;

    fn tiny_manifest() -> crate::model::DatasetManifest {
        // A synthetic manifest (no artifact files needed for these tests).
        let text = "\
version 1
smoke smoke.hlo.txt
dataset tiny
d 30
d_pad 32
input 2 2 1
classes 3
batch 4
eval_batch 8
train_step ts.hlo.txt
eval_step ev.hlo.txt
dual_update du.hlo.txt
init_w init.bin
layer a 5 6
end
";
        Manifest::parse(text, std::path::Path::new("/nonexistent"))
            .unwrap()
            .dataset("tiny")
            .unwrap()
            .clone()
    }

    fn ctx(node: usize, graph: &Arc<Graph>) -> BuildCtx {
        ctx_policy(node, graph, RoundPolicy::Sync)
    }

    fn ctx_policy(node: usize, graph: &Arc<Graph>,
                  round_policy: RoundPolicy) -> BuildCtx {
        BuildCtx {
            node,
            graph: Arc::clone(graph),
            manifest: tiny_manifest(),
            seed: 77,
            eta: 0.05,
            local_steps: 5,
            rounds_per_epoch: 2,
            dual_path: DualPath::Native,
            runtime: None,
            round_policy,
        }
    }

    fn rand_k(k_frac: f64) -> CodecSpec {
        CodecSpec::RandK {
            k_frac,
            mode: WireMode::Explicit,
        }
    }

    fn full_view(graph: &Graph) -> TopologyView {
        TopologyView::full(graph.edges().len())
    }

    /// Run one exchange over a 3-ring and return the nodes.
    fn run_ring_exchange(k_frac: f64, theta: f32, round: usize)
                         -> Vec<CEclNode> {
        let graph = Arc::new(Graph::ring(3));
        let (comms, _) = build_bus(&graph);
        let mut nodes: Vec<CEclNode> = (0..3)
            .map(|i| {
                let mut n = CEclNode::new(&ctx(i, &graph), rand_k(k_frac),
                                          theta, 0, DualRule::CompressDiff)
                    .unwrap();
                // Seed distinct non-trivial dual state + w.  The arena
                // stride equals d_pad here, so the slab order matches
                // the old row-by-row flatten order exactly.
                let mut rng = Pcg::new(100 + i as u64);
                for zv in n.z.as_mut_slice().iter_mut() {
                    *zv = rng.normal_f32();
                }
                // Restore the zsum invariant after direct z seeding (the
                // incremental maintenance assumes it holds on entry).
                n.recompute_zsum();
                n
            })
            .collect();
        let ws: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut rng = Pcg::new(200 + i as u64);
                (0..32).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        // Drive the exchange on threads (blocking recv needs concurrency).
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .iter_mut()
                .zip(comms)
                .zip(ws)
                .map(|((node, comm), mut w)| {
                    s.spawn(move || {
                        node.exchange(round, &mut w, &comm).unwrap()
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        nodes
    }

    #[test]
    fn dense_exchange_is_eq5() {
        // θ=1, k=1 (ECL): z_{i|j}' must equal y_{j|i} = z_{j|i} − 2α a_{j|i} w_j.
        let graph = Arc::new(Graph::ring(3));
        let nodes_before = run_ring_exchange(1.0, 1.0, 0);
        // Recompute expectations manually by re-deriving initial state.
        // (Initial z and w reconstructed with the same seeds as above.)
        let init_z = |i: usize| -> Vec<Vec<f32>> {
            let mut rng = Pcg::new(100 + i as u64);
            (0..2)
                .map(|_| (0..32).map(|_| rng.normal_f32()).collect())
                .collect()
        };
        let init_w = |i: usize| -> Vec<f32> {
            let mut rng = Pcg::new(200 + i as u64);
            (0..32).map(|_| rng.normal_f32()).collect()
        };
        for i in 0..3usize {
            for (jj, &j) in graph.neighbors(i).iter().enumerate() {
                let ii = graph.neighbors(j).iter().position(|&x| x == i).unwrap();
                let alpha_j = nodes_before[j].alpha();
                let a_ji = graph.edge_sign(j, i);
                let zj = init_z(j);
                let wj = init_w(j);
                for t in 0..32 {
                    let y_ji = zj[ii][t] - 2.0 * alpha_j * a_ji * wj[t];
                    let got = nodes_before[i].z.row(jj)[t];
                    assert!(
                        (got - y_ji).abs() < 1e-5,
                        "node {i} nb {j} coord {t}: {got} vs {y_ji}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_exchange_touches_only_masked_coords() {
        let nodes = run_ring_exchange(0.2, 1.0, 3);
        // With k=20% roughly 80% of coordinates keep their initial value.
        for (i, node) in nodes.iter().enumerate() {
            let mut rng = Pcg::new(100 + i as u64);
            let orig: Vec<Vec<f32>> = (0..2)
                .map(|_| (0..32).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut unchanged = 0;
            let mut total = 0;
            for jj in 0..2 {
                for t in 0..32 {
                    total += 1;
                    if node.z.row(jj)[t] == orig[jj][t] {
                        unchanged += 1;
                    }
                }
            }
            assert!(unchanged > total / 2, "unchanged {unchanged}/{total}");
            assert!(unchanged < total, "some coords must update");
        }
    }

    #[test]
    fn zsum_matches_definition() {
        let graph = Arc::new(Graph::ring(3));
        let nodes = run_ring_exchange(0.5, 0.8, 1);
        for (i, node) in nodes.iter().enumerate() {
            for t in 0..32 {
                let mut want = 0.0f32;
                for (jj, &j) in graph.neighbors(i).iter().enumerate() {
                    want += graph.edge_sign(i, j) * node.z.row(jj)[t];
                }
                assert!((node.zsum[t] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn alpha_deg_consistency() {
        let graph = Arc::new(Graph::ring(4));
        let node = CEclNode::new(&ctx(0, &graph), rand_k(0.1), 1.0, 0,
                                 DualRule::CompressDiff)
            .unwrap();
        assert!((NodeAlgorithm::alpha_deg(&node) - node.alpha() * 2.0).abs()
                < 1e-6);
        // Eq. 47 with η=0.05, |N|=2, K=5, k=0.1: α = 1/(0.05·2·49).
        assert!((node.alpha() - 1.0 / (0.05 * 2.0 * 49.0)).abs() < 1e-4);
    }

    #[test]
    fn warmup_rounds_use_dense() {
        let graph = Arc::new(Graph::ring(3));
        let node = CEclNode::new(&ctx(0, &graph), rand_k(0.1), 1.0, 2,
                                 DualRule::CompressDiff)
            .unwrap();
        assert!(node.is_dense_round(0));
        assert!(node.is_dense_round(1));
        assert!(!node.is_dense_round(2));
        // Identity deliberately runs the codec frame path every round.
        let ident = CEclNode::new(&ctx(0, &graph), CodecSpec::Identity, 1.0,
                                  0, DualRule::CompressDiff)
            .unwrap();
        assert!(!ident.is_dense_round(5));
        // Full-rate rand-k IS the dense ECL wire.
        let ecl = CEclNode::new(&ctx(0, &graph), rand_k(1.0), 1.0, 0,
                                DualRule::CompressDiff)
            .unwrap();
        assert!(ecl.is_dense_round(1000));
        assert_eq!(NodeAlgorithm::name(&ecl), "ECL");
    }

    #[test]
    fn nonlinear_codec_rejected_under_eq13() {
        let graph = Arc::new(Graph::ring(3));
        for spec in [
            CodecSpec::TopK { k_frac: 0.1 },
            CodecSpec::Qsgd { bits: 4 },
            CodecSpec::SignNorm,
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK {
                k_frac: 0.1,
            })),
        ] {
            let err = CEclNode::new(&ctx(0, &graph), spec.clone(), 1.0, 0,
                                    DualRule::CompressDiff)
                .err()
                .unwrap_or_else(|| panic!("{}: Eq.13 must reject", spec.name()));
            assert!(err.to_string().contains("linearity"), "{err}");
            // ... but they run fine under the Eq. 11 rule.
            assert!(CEclNode::new(&ctx(0, &graph), spec, 1.0, 0,
                                  DualRule::CompressY)
                .is_ok());
        }
    }

    #[test]
    fn codec_exchange_roundtrips_for_every_family() {
        // One full exchange round on a 3-ring for each codec family:
        // the protocol completes, dual state moves, and zsum keeps its
        // invariant — all through real encoded frames.
        let graph = Arc::new(Graph::ring(3));
        for spec in [
            CodecSpec::Identity,
            CodecSpec::RandK { k_frac: 0.4, mode: WireMode::ValuesOnly },
            CodecSpec::TopK { k_frac: 0.3 },
            CodecSpec::Qsgd { bits: 6 },
            CodecSpec::SignNorm,
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK {
                k_frac: 0.3,
            })),
        ] {
            let rule = rule_for_codec(&spec);
            let (comms, meter) = build_bus(&graph);
            let mut nodes: Vec<CEclNode> = (0..3)
                .map(|i| {
                    let mut n = CEclNode::new(&ctx(i, &graph), spec.clone(),
                                              0.9, 0, rule)
                        .unwrap();
                    let mut rng = Pcg::new(300 + i as u64);
                    for zv in n.z.as_mut_slice().iter_mut() {
                        *zv = rng.normal_f32();
                    }
                    n.recompute_zsum();
                    n
                })
                .collect();
            std::thread::scope(|s| {
                let handles: Vec<_> = nodes
                    .iter_mut()
                    .zip(comms)
                    .map(|(node, comm)| {
                        s.spawn(move || {
                            let mut w = vec![0.25f32; 32];
                            node.exchange(2, &mut w, &comm).unwrap();
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            });
            assert!(meter.total_bytes() > 0, "{}: no traffic", spec.name());
            for node in &nodes {
                node.debug_check_zsum();
                // Dual state must have moved off its seeded initial value.
                let mut rng = Pcg::new(300 + node.node as u64);
                let moved = node
                    .z
                    .as_slice()
                    .iter()
                    .filter(|&&zv| zv != rng.normal_f32())
                    .count();
                assert!(moved > 0, "{}: z never moved", spec.name());
            }
        }
    }

    #[test]
    fn state_machine_round_lifecycle() {
        // round_begin queues one message per neighbor; delivering both
        // completes the round; a third message errors.
        let graph = Arc::new(Graph::ring(3));
        let view = full_view(&graph);
        let mut node = CEclNode::new(&ctx(0, &graph), rand_k(0.5), 1.0, 0,
                                     DualRule::CompressDiff)
            .unwrap();
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(!node.round_complete());
        // Feed back each neighbor's expected payload: reuse the messages
        // addressed to us from identically-seeded peers.
        for &j in &[1usize, 2] {
            let mut peer = CEclNode::new(&ctx(j, &graph), rand_k(0.5), 1.0, 0,
                                         DualRule::CompressY)
                .unwrap();
            let mut peer_out = Outbox::new();
            let mut wj = vec![0.25f32; 32];
            NodeStateMachine::round_begin(&mut peer, 0, &view, &mut wj,
                                          &mut peer_out)
                .unwrap();
            let msg = peer_out
                .drain()
                .find(|(to, _)| *to == 0)
                .map(|(_, m)| m)
                .unwrap();
            NodeStateMachine::on_message(&mut node, 0, j, msg, &view, &mut w,
                                         &mut out)
                .unwrap();
        }
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 0, &view, &mut w).unwrap();
        // Extra message after completion is a protocol error.
        let err = NodeStateMachine::on_message(
            &mut node,
            0,
            1,
            Msg::Scalar(0.0),
            &view,
            &mut w,
            &mut out,
        );
        assert!(err.is_err());
    }

    #[test]
    fn edge_rebirth_rebuilds_codec_and_warm_starts_dual() {
        // Kill edge (0, 1) and revive it: node 0's dual toward 1 must be
        // retired (zsum excluded) while dead, then reborn warm-started
        // at the consensus fixed point z = α·a·w from the CURRENT
        // primal, with a fresh edge clock gating at the activation
        // round — and the static slot toward neighbor 2 untouched.
        let graph = Arc::new(Graph::ring(3));
        let mut view = full_view(&graph);
        let mut node = CEclNode::new(&ctx(0, &graph), rand_k(0.5), 1.0, 0,
                                     DualRule::CompressDiff)
            .unwrap();
        // Seed nonzero dual state so the teardown is observable.
        let mut rng = Pcg::new(7);
        for zv in node.z.as_mut_slice().iter_mut() {
            *zv = rng.normal_f32();
        }
        node.recompute_zsum();
        let z_to_2 = node.z.row(1).to_vec();
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();

        let e01 = graph.edge_index(0, 1).unwrap();
        view.kill_edge(e01);
        NodeStateMachine::on_topology(&mut node, &view, &mut w, &mut out)
            .unwrap();
        assert!(out.is_empty());
        assert!(node.z.row(0).iter().all(|&v| v == 0.0), "dual not retired");
        assert_eq!(node.z.row(1), &z_to_2[..], "static slot must be untouched");
        // alpha_deg tracks the live degree.
        let full_ad = node.alpha() * 2.0;
        assert!((NodeStateMachine::alpha_deg(&node) - node.alpha()).abs()
                < 1e-6);
        node.debug_check_zsum();
        // A dead edge neither sends nor gates.
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 1, "only the live neighbor 2 is addressed");
        out.drain().for_each(drop);

        view.revive_edge(e01, 3);
        NodeStateMachine::on_topology(&mut node, &view, &mut w, &mut out)
            .unwrap();
        assert!((NodeStateMachine::alpha_deg(&node) - full_ad).abs() < 1e-6);
        // Warm start: z_{0|1} = α · (+1) · w.
        for (&zv, &wv) in node.z.row(0).iter().zip(&w) {
            assert!((zv - node.alpha() * wv).abs() < 1e-6, "{zv} vs α·w");
        }
        node.debug_check_zsum();
        // Before the activation round the reborn edge stays silent…
        NodeStateMachine::round_begin(&mut node, 1, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 1);
        out.drain().for_each(drop);
        // …and from activation on it speaks again.
        NodeStateMachine::round_begin(&mut node, 3, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 2);
    }

    /// One peer's round-`round` frame addressed to node 0 (peers are
    /// seeded identically, so the frame is exactly what node 0 would
    /// receive on the wire).
    fn peer_frame_for_node0(graph: &Arc<Graph>, peer: usize, round: usize,
                            policy: RoundPolicy) -> Msg {
        let view = full_view(graph);
        let mut p = CEclNode::new(&ctx_policy(peer, graph, policy),
                                  rand_k(0.5), 1.0, 0, DualRule::CompressDiff)
            .unwrap();
        let mut out = Outbox::new();
        let mut w = vec![0.25f32; 32];
        for r in 0..=round {
            out.drain().for_each(drop);
            NodeStateMachine::round_begin(&mut p, r, &view, &mut w, &mut out)
                .unwrap();
        }
        out.drain()
            .find(|(to, _)| *to == 0)
            .map(|(_, m)| m)
            .unwrap()
    }

    #[test]
    fn async_gate_consumes_stale_duals_within_bound() {
        let graph = Arc::new(Graph::ring(3));
        let view = full_view(&graph);
        let policy = RoundPolicy::Async { max_staleness: 1 };
        let mut node = CEclNode::new(&ctx_policy(0, &graph, policy),
                                     rand_k(0.5), 1.0, 0,
                                     DualRule::CompressDiff)
            .unwrap();
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        // Round 0: staleness 1 lets the node step before hearing from
        // anyone at all.
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        assert!(node.round_complete(), "async:1 must not block round 0");
        NodeStateMachine::round_end(&mut node, 0, &view, &mut w).unwrap();
        // Start-up slack (nothing received yet) is not counted as lag.
        assert_eq!(NodeStateMachine::max_staleness_seen(&node), 0);
        // Round 1: now each edge must have delivered round ≥ 0.
        NodeStateMachine::round_begin(&mut node, 1, &view, &mut w, &mut out)
            .unwrap();
        assert!(!node.round_complete(), "round 1 needs round-0 duals");
        for &j in &[1usize, 2] {
            let msg = peer_frame_for_node0(&graph, j, 0, policy);
            // Stale (round-0) frames decode with the round-0 mask and
            // are accepted one round late.
            NodeStateMachine::on_message(&mut node, 0, j, msg, &view, &mut w,
                                         &mut out)
                .unwrap();
        }
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 1, &view, &mut w).unwrap();
        node.debug_check_zsum();
        assert_eq!(NodeStateMachine::max_staleness_seen(&node), 1);
        // Round 2 with nothing newer: the gate blocks, and forcing
        // round_end is a hard staleness-bound violation.
        NodeStateMachine::round_begin(&mut node, 2, &view, &mut w, &mut out)
            .unwrap();
        assert!(!node.round_complete());
        let err = NodeStateMachine::round_end(&mut node, 2, &view, &mut w)
            .unwrap_err();
        assert!(err.to_string().contains("would consume"), "{err}");
    }

    #[test]
    fn async_rejects_fifo_violations_sync_rejects_offround() {
        let graph = Arc::new(Graph::ring(3));
        let view = full_view(&graph);
        let policy = RoundPolicy::Async { max_staleness: 2 };
        let mut node = CEclNode::new(&ctx_policy(0, &graph, policy),
                                     rand_k(0.5), 1.0, 0,
                                     DualRule::CompressDiff)
            .unwrap();
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        // An AHEAD message (round 1 while we are at 0) is legal async.
        let msg = peer_frame_for_node0(&graph, 1, 1, policy);
        NodeStateMachine::on_message(&mut node, 1, 1, msg, &view, &mut w,
                                     &mut out)
            .unwrap();
        // ...but a round-0 message from the same edge afterwards is a
        // FIFO violation.
        let msg = peer_frame_for_node0(&graph, 1, 0, policy);
        let err = NodeStateMachine::on_message(&mut node, 0, 1, msg, &view,
                                               &mut w, &mut out)
            .unwrap_err();
        assert!(err.to_string().contains("FIFO"), "{err}");
        // Sync machines reject any off-round stamp outright.
        let mut sync_node = CEclNode::new(&ctx(0, &graph), rand_k(0.5), 1.0,
                                          0, DualRule::CompressDiff)
            .unwrap();
        NodeStateMachine::round_begin(&mut sync_node, 0, &view, &mut w,
                                      &mut out)
            .unwrap();
        let msg = peer_frame_for_node0(&graph, 1, 1, RoundPolicy::Sync);
        let err = NodeStateMachine::on_message(&mut sync_node, 1, 1, msg,
                                               &view, &mut w, &mut out)
            .unwrap_err();
        assert!(err.to_string().contains("sync round"), "{err}");
    }

    #[test]
    fn corrupt_frame_is_error_not_panic() {
        let graph = Arc::new(Graph::ring(3));
        let view = full_view(&graph);
        let mut node = CEclNode::new(&ctx(0, &graph), rand_k(0.5), 1.0, 0,
                                     DualRule::CompressDiff)
            .unwrap();
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        // A peer's frame, corrupted in flight: first index out of range.
        let mut peer = CEclNode::new(&ctx(1, &graph), rand_k(0.5), 1.0, 0,
                                     DualRule::CompressDiff)
            .unwrap();
        let mut peer_out = Outbox::new();
        let mut wj = vec![0.25f32; 32];
        NodeStateMachine::round_begin(&mut peer, 0, &view, &mut wj,
                                      &mut peer_out)
            .unwrap();
        let msg = peer_out
            .drain()
            .find(|(to, _)| *to == 0)
            .map(|(_, m)| m)
            .unwrap();
        let mut frame = msg.into_frame().unwrap();
        frame.bytes_mut()[0..4].copy_from_slice(&999u32.to_le_bytes());
        let err = NodeStateMachine::on_message(
            &mut node,
            0,
            1,
            Msg::Frame(frame),
            &view,
            &mut w,
            &mut out,
        )
        .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }
}
