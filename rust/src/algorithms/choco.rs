//! CHOCO-SGD (Koloskova et al. 2019, arXiv 1902.00340): compressed
//! gossip — the paper's strongest *gossip-family* rival.
//!
//! Every node keeps a pair of replicas per edge: `x̂_{i|j}` (what
//! neighbor `j` believes about this node — updated with the node's own
//! transmitted payload, so both endpoints hold the identical value by
//! shared-seed construction) and `x̂_{j|i}` (what this node believes
//! about neighbor `j`).  One round, after the K local SGD steps:
//!
//! * send `q_{i→j} = comp(x_i − x̂_{i|j}; ω_{j|i})` on every live edge,
//!   then apply the *decoded* `q̂` to the own-side replica — the same
//!   update the receiver applies, so replicas never fork;
//! * on receive, `x̂_{j|i} += q̂_{j→i}`;
//! * consensus step
//!   `x_i += γ Σ_j W_ij (x̂_{j|i} − x̂_{i|j})`
//!   with the Metropolis–Hastings weights `W` and consensus step size
//!   `γ = τ` (the codec's Eq. (7) contraction — Koloskova's γ ∝ δ
//!   schedule collapsed onto the one compression constant the repo
//!   already computes; `identity` ⇒ τ = 1 ⇒ γ = 1).
//!
//! **Exact-gossip degeneration** — with the `identity` codec the
//! replicas equal the true neighbor parameters bit-for-bit and γ = 1,
//! so the consensus step *is* the D-PSGD MH fold; the implementation
//! runs D-PSGD's exact accumulation order in that case, and the test
//! suite pins the two trajectories bit-identical on both engines.
//!
//! Replicas are gossip state, not dual state: `alpha_deg = 0` and no
//! `zsum`, so the Eq. (6) local step reduces to plain SGD, exactly like
//! D-PSGD.  Per-edge lifecycle, clocks, and staleness gating follow the
//! same contract as every other machine (see `algorithms` module docs):
//! an edge birth allocates fresh codec instances and zeroes both
//! replicas (the next send retransmits the full compressed state), an
//! edge death retires them, and a neighbor that has not spoken this
//! incarnation contributes nothing to the consensus sum.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::{CodecSpec, EdgeCodec, EdgeCtx};
use crate::graph::{Graph, TopologyView};
use crate::linalg::{axpy_f32, consensus_mix_f32, scaled_copy_f32};
use crate::model::Arena;

use super::{BuildCtx, EdgeClock, NodeAlgorithm, NodeStateMachine,
            RoundPolicy};

pub struct ChocoNode {
    node: usize,
    graph: Arc<Graph>,
    seed: u64,
    d_pad: usize,
    /// This node's row of the MH weight matrix.
    weights: Vec<f64>,
    /// Consensus step size γ = codec τ (1 for `identity` ⇒ D-PSGD).
    gamma: f32,
    codec_spec: CodecSpec,
    /// Outbound codec per neighbor slot: encodes this node's q and
    /// self-decodes it for the own-side replica update.
    codecs_out: Vec<Box<dyn EdgeCodec>>,
    /// Inbound codec per neighbor slot: decodes the neighbor's q.
    codecs_in: Vec<Box<dyn EdgeCodec>>,
    /// `x̂_{i|j}`: own replica as held by neighbor slot jj (arena row
    /// per slot, one contiguous slab).
    hat_self: Arena,
    /// `x̂_{j|i}`: neighbor slot jj's replica held here.
    hat_nb: Arena,
    /// `identity` codec: replicas are exact, run the D-PSGD fold.
    exact: bool,
    /// Sync vs bounded-staleness async rounds.
    policy: RoundPolicy,
    /// The node's own round clock (set by `round_begin`).
    cur_round: usize,
    /// Per-edge clocks: freshest replica stamp, liveness, activation.
    clocks: Vec<EdgeClock>,
    /// Cached edge incarnation per neighbor slot.
    edge_epochs: Vec<u32>,
    /// Last `TopologyView::version` synced against.
    seen_view: u64,
    /// Layout views for rebinding freshly built codecs on edge birth.
    mats: Vec<(usize, usize, usize)>,
    vecs: Vec<(usize, usize)>,
    /// Cached static full view for the blocking engine.
    full_view: Arc<TopologyView>,
    /// Largest per-edge lag consumed at any `round_end`.
    max_lag_seen: usize,
    // -- preallocated scratch -------------------------------------------
    acc: Vec<f32>,
    scratch_q: Vec<f32>,
    /// Reusable decode target: every `decode_into` lands here.
    scratch_recv: Vec<f32>,
}

impl ChocoNode {
    pub fn new(ctx: &BuildCtx, codec: CodecSpec) -> Result<ChocoNode> {
        let degree = ctx.graph.degree(ctx.node);
        ensure!(degree > 0, "CHOCO-SGD requires no isolated nodes");
        codec.validate()?;
        let d_pad = ctx.manifest.d_pad;
        let mats: Vec<(usize, usize, usize)> = ctx
            .manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vecs: Vec<(usize, usize)> = ctx
            .manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        let build = |mats: &[(usize, usize, usize)],
                     vecs: &[(usize, usize)]| {
            let mut c = codec.build();
            c.bind_layout(mats, vecs);
            c
        };
        let gamma = codec.tau(d_pad).clamp(0.0, 1.0) as f32;
        Ok(ChocoNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            seed: ctx.seed,
            d_pad,
            weights: ctx.graph.mh_weights()[ctx.node].clone(),
            gamma,
            exact: matches!(codec, CodecSpec::Identity),
            codecs_out: (0..degree).map(|_| build(&mats, &vecs)).collect(),
            codecs_in: (0..degree).map(|_| build(&mats, &vecs)).collect(),
            codec_spec: codec,
            hat_self: Arena::zeros(degree, d_pad),
            hat_nb: Arena::zeros(degree, d_pad),
            policy: ctx.round_policy,
            cur_round: 0,
            clocks: vec![EdgeClock::born(0); degree],
            edge_epochs: vec![0; degree],
            seen_view: 0,
            mats,
            vecs,
            full_view: Arc::new(TopologyView::full(
                ctx.graph.edges().len(),
            )),
            max_lag_seen: 0,
            acc: vec![0.0; d_pad],
            scratch_q: Vec::with_capacity(d_pad),
            scratch_recv: vec![0.0; d_pad],
        })
    }

    /// The consensus step size the codec's τ selected.
    pub fn gamma(&self) -> f32 {
        self.gamma
    }

    /// Test access: (own-side, neighbor-side) replicas per slot.
    pub fn replicas(&self) -> (&Arena, &Arena) {
        (&self.hat_self, &self.hat_nb)
    }

    /// Per-edge lifecycle sync (same contract as `CEclNode::sync_view`):
    /// a birth allocates fresh codec instances and zeroes both replicas
    /// — the next send retransmits the full compressed state, so no
    /// pre-churn replica (or error-feedback residual) can leak into a
    /// new incarnation.  A death retires the slot.
    fn sync_view(&mut self, view: &TopologyView) -> Result<()> {
        if view.version() == self.seen_view {
            return Ok(());
        }
        self.seen_view = view.version();
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let life = view.edge_life(e);
            if life.epoch != self.edge_epochs[jj] {
                self.edge_epochs[jj] = life.epoch;
                let mut codec = self.codec_spec.build();
                codec.bind_layout(&self.mats, &self.vecs);
                self.codecs_out[jj] = codec;
                let mut codec = self.codec_spec.build();
                codec.bind_layout(&self.mats, &self.vecs);
                self.codecs_in[jj] = codec;
                self.hat_self.row_mut(jj).fill(0.0);
                self.hat_nb.row_mut(jj).fill(0.0);
                let mut clock = EdgeClock::born(life.activation_round);
                clock.live = life.live;
                self.clocks[jj] = clock;
            } else if life.live != self.clocks[jj].live {
                self.clocks[jj].live = life.live;
                if !life.live {
                    self.hat_self.row_mut(jj).fill(0.0);
                    self.hat_nb.row_mut(jj).fill(0.0);
                }
            }
        }
        Ok(())
    }

    /// Shared-seed context for the payload `receiver` consumes on
    /// `edge` at `round` (identical at both endpoints).
    fn edge_ctx(&self, jj: usize, edge: usize, round: usize,
                receiver: usize) -> EdgeCtx {
        EdgeCtx {
            seed: self.seed,
            edge,
            round,
            receiver,
            dim: self.d_pad,
            epoch: self.edge_epochs[jj],
        }
    }
}

impl NodeStateMachine for ChocoNode {
    fn name(&self) -> String {
        format!("CHOCO-SGD [{}]", self.codec_spec.name())
    }

    fn round_begin(&mut self, round: usize, view: &TopologyView,
                   w: &mut [f32], out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.cur_round = round;
        for (jj, &j) in neighbors.iter().enumerate() {
            if !self.clocks[jj].active(round) {
                continue; // dead or not-yet-activated edge
            }
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            // ω_{j|i}: what j receives from us.
            let ctx_e = self.edge_ctx(jj, e, round, j);
            if self.exact {
                // Identity wire carries x itself; the replica is exact.
                let frame = self.codecs_out[jj].encode(w, &ctx_e);
                self.hat_self.row_mut(jj).copy_from_slice(w);
                out.send(j, Msg::Frame(frame));
                continue;
            }
            let codec = &mut self.codecs_out[jj];
            let hs = self.hat_self.row(jj);
            let frame = match codec.encode_from(&|i| w[i] - hs[i], &ctx_e) {
                Some(frame) => frame,
                None => {
                    self.scratch_q.clear();
                    self.scratch_q.extend(
                        w.iter().zip(hs.iter()).map(|(&wv, &h)| wv - h),
                    );
                    codec.encode(&self.scratch_q, &ctx_e)
                }
            };
            // Apply the decoded payload — exactly what the receiver
            // will apply — so both ends of the edge hold the same
            // `x̂_{i|j}` without the replica ever crossing the wire.
            // The decode lands in persistent scratch; the unit-weight
            // axpy is `h += 1.0 * q` — exact for every finite q.
            codec.decode_into(&frame, &ctx_e, &mut self.scratch_recv)?;
            axpy_f32(1.0, &self.scratch_recv, self.hat_self.row_mut(jj));
            out.send(j, Msg::Frame(frame));
        }
        Ok(())
    }

    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  view: &TopologyView, _w: &mut [f32],
                  _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        ensure!(
            self.clocks[jj].live,
            "node {}: replica update from {from} on a churned-out edge \
             (the engine should have dropped it)",
            self.node
        );
        super::admit_message(self.policy, self.node, from, self.cur_round,
                             self.clocks[jj].round, msg_round)?;
        let e = self
            .graph
            .edge_index(self.node, from)
            .ok_or_else(|| anyhow!("({}, {from}) is not an edge", self.node))?;
        // ω_{i|j}: what we receive from j — keyed off the SENDER's
        // round stamp, so both endpoints derive the same stream however
        // far their clocks have drifted.
        let ctx_e = self.edge_ctx(jj, e, msg_round, self.node);
        let frame = msg.into_frame()?;
        self.codecs_in[jj].decode_into(&frame, &ctx_e,
                                       &mut self.scratch_recv)?;
        if self.exact {
            self.hat_nb.row_mut(jj).copy_from_slice(&self.scratch_recv);
        } else {
            axpy_f32(1.0, &self.scratch_recv, self.hat_nb.row_mut(jj));
        }
        self.clocks[jj].round = msg_round as i64;
        self.clocks[jj].spoken = true;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        super::staleness_gate(self.policy, self.cur_round, &self.clocks)
    }

    fn round_end(&mut self, round: usize, view: &TopologyView,
                 w: &mut [f32]) -> Result<()> {
        self.sync_view(view)?;
        let lag = super::check_staleness(self.policy, self.node, "replica",
                                         round, &self.clocks)?;
        self.max_lag_seen = self.max_lag_seen.max(lag);
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        if self.exact {
            // Identity + γ = 1: the consensus step algebraically equals
            // the MH fold, and the replicas equal the true neighbor
            // parameters bit-for-bit — run D-PSGD's exact accumulation
            // order so the two trajectories are bit-identical (pinned).
            let wii = self.weights[self.node] as f32;
            scaled_copy_f32(wii, w, &mut self.acc);
            for (jj, &j) in neighbors.iter().enumerate() {
                let wij = self.weights[j] as f32;
                let c = &self.clocks[jj];
                if c.live && c.spoken {
                    axpy_f32(wij, self.hat_nb.row(jj), &mut self.acc);
                } else {
                    // Dead or not-yet-spoken slot: fall back to our own
                    // parameters (the MH row stays stochastic).
                    axpy_f32(wij, w, &mut self.acc);
                }
            }
            w.copy_from_slice(&self.acc);
            return Ok(());
        }
        // General compressed path: x += γ Σ_j W_ij (x̂_{j|i} − x̂_{i|j}),
        // via the fused consensus kernels (bit-identical to the plain
        // zip loops they replaced — see `linalg`).
        self.acc.fill(0.0);
        for (jj, &j) in neighbors.iter().enumerate() {
            let c = &self.clocks[jj];
            if !(c.live && c.spoken) {
                continue; // no replica pair agreed on this edge yet
            }
            let wij = self.weights[j] as f32;
            consensus_mix_f32(&mut self.acc, self.hat_nb.row(jj),
                              self.hat_self.row(jj), wij);
        }
        axpy_f32(self.gamma, &self.acc, w);
        Ok(())
    }

    fn on_topology(&mut self, view: &TopologyView, _w: &mut [f32],
                   _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)
    }

    fn max_staleness_seen(&self) -> usize {
        self.max_lag_seen
    }

    fn policy(&self) -> Option<RoundPolicy> {
        Some(self.policy)
    }
}

impl NodeAlgorithm for ChocoNode {
    fn name(&self) -> String {
        NodeStateMachine::name(self)
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let view = Arc::clone(&self.full_view);
        super::drive_blocking(self, &neighbors, &view, round, w, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::DPsgdNode;
    use crate::model::Manifest;
    use crate::util::rng::Pcg;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 8\nd_pad 8\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer l 2 4\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    fn ctx(node: usize, graph: &Arc<Graph>) -> BuildCtx {
        BuildCtx {
            node,
            graph: Arc::clone(graph),
            manifest: manifest(),
            seed: 7,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Sync,
        }
    }

    fn init_w(node: usize) -> Vec<f32> {
        let mut rng = Pcg::new(500 + node as u64);
        (0..8).map(|_| rng.normal_f32()).collect()
    }

    /// Drive a full network of state machines for `rounds` sync rounds
    /// (no local updates between rounds).
    fn run_network(machines: &mut [Box<dyn NodeStateMachine>],
                   ws: &mut [Vec<f32>], rounds: usize) {
        let view = TopologyView::full(64);
        for r in 0..rounds {
            let mut inflight: Vec<(usize, usize, Msg)> = Vec::new();
            for (i, m) in machines.iter_mut().enumerate() {
                let mut out = Outbox::new();
                m.round_begin(r, &view, &mut ws[i], &mut out).unwrap();
                for (to, msg) in out.drain() {
                    inflight.push((i, to, msg));
                }
            }
            for (from, to, msg) in inflight {
                let mut out = Outbox::new();
                machines[to]
                    .on_message(r, from, msg, &view, &mut ws[to], &mut out)
                    .unwrap();
                assert!(out.is_empty());
            }
            for (i, m) in machines.iter_mut().enumerate() {
                assert!(m.round_complete(), "round {r} node {i}");
                m.round_end(r, &view, &mut ws[i]).unwrap();
            }
        }
    }

    #[test]
    fn identity_codec_is_bitwise_dpsgd() {
        // The exact-gossip degenerate case: CHOCO-SGD with the identity
        // codec must walk D-PSGD's trajectory bit-for-bit.
        let graph = Arc::new(Graph::ring(4));
        let mut choco: Vec<Box<dyn NodeStateMachine>> = (0..4)
            .map(|i| {
                Box::new(
                    ChocoNode::new(&ctx(i, &graph), CodecSpec::Identity)
                        .unwrap(),
                ) as Box<dyn NodeStateMachine>
            })
            .collect();
        let mut dpsgd: Vec<Box<dyn NodeStateMachine>> = (0..4)
            .map(|i| {
                Box::new(DPsgdNode::new(&ctx(i, &graph)))
                    as Box<dyn NodeStateMachine>
            })
            .collect();
        let mut wc: Vec<Vec<f32>> = (0..4).map(init_w).collect();
        let mut wd = wc.clone();
        run_network(&mut choco, &mut wc, 5);
        run_network(&mut dpsgd, &mut wd, 5);
        for (c, d) in wc.iter().zip(&wd) {
            let cb: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
            let db: Vec<u32> = d.iter().map(|v| v.to_bits()).collect();
            assert_eq!(cb, db);
        }
    }

    #[test]
    fn compressed_consensus_preserves_mean_and_contracts() {
        // Per-edge replica pairs are held identically at both endpoints
        // and W is symmetric, so the node-mean is invariant and the
        // spread contracts (γ = τ = 0.5 here).
        let graph = Arc::new(Graph::ring(4));
        let spec = CodecSpec::parse("rand_k:0.5").unwrap();
        let mut machines: Vec<Box<dyn NodeStateMachine>> = (0..4)
            .map(|i| {
                Box::new(ChocoNode::new(&ctx(i, &graph), spec.clone())
                    .unwrap()) as Box<dyn NodeStateMachine>
            })
            .collect();
        let mut ws: Vec<Vec<f32>> = (0..4).map(init_w).collect();
        let mean_before: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        let spread = |ws: &[Vec<f32>]| -> f32 {
            let mut s = 0.0;
            for a in ws {
                for b in ws {
                    s += a
                        .iter()
                        .zip(b)
                        .map(|(x, y)| (x - y) * (x - y))
                        .sum::<f32>();
                }
            }
            s
        };
        let spread_before = spread(&ws);
        run_network(&mut machines, &mut ws, 30);
        let mean_after: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        assert!((mean_after - mean_before).abs() < 1e-3,
                "{mean_before} -> {mean_after}");
        let spread_after = spread(&ws);
        assert!(spread_after < spread_before * 0.1,
                "{spread_before} -> {spread_after}");
        // And the replicas have locked onto the true parameters.
        let any = machines[0].name();
        assert_eq!(any, "CHOCO-SGD [rand_k 50%]");
    }

    #[test]
    fn gamma_follows_codec_tau() {
        let graph = Arc::new(Graph::ring(4));
        let c = |s: &str| {
            ChocoNode::new(&ctx(0, &graph), CodecSpec::parse(s).unwrap())
                .unwrap()
                .gamma()
        };
        assert_eq!(c("identity"), 1.0);
        assert!((c("rand_k:0.1") - 0.1).abs() < 1e-6);
        assert!(c("qsgd:4") > 0.0 && c("qsgd:4") <= 1.0);
    }

    #[test]
    fn edge_rebirth_resets_replicas_and_codec() {
        let graph = Arc::new(Graph::ring(4));
        let spec = CodecSpec::parse("rand_k:0.5").unwrap();
        let mut node = ChocoNode::new(&ctx(0, &graph), spec).unwrap();
        let mut view = TopologyView::full(graph.edges().len());
        let mut w = init_w(0);
        let mut out = Outbox::new();
        // Round 0: both neighbors speak, replicas move off zero.
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 2);
        out.drain().for_each(drop);
        assert!(node.hat_self.row(0).iter().any(|&v| v != 0.0));
        // Kill and revive edge (0, 1): epoch bumps, slot 0 is reborn.
        let e = graph.edge_index(0, 1).unwrap();
        view.kill_edge(e);
        view.revive_edge(e, 3);
        NodeStateMachine::on_topology(&mut node, &view, &mut w, &mut out)
            .unwrap();
        assert!(node.hat_self.row(0).iter().all(|&v| v == 0.0));
        assert!(node.hat_nb.row(0).iter().all(|&v| v == 0.0));
        assert_eq!(node.clocks[0].activation, 3);
        assert!(!node.clocks[0].spoken);
        // Slot 1 (edge to neighbor 3) is untouched.
        assert!(node.hat_self.row(1).iter().any(|&v| v != 0.0));
    }
}
