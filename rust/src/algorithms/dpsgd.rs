//! D-PSGD (Lian et al. 2017): the uncompressed Gossip baseline.
//!
//! Each round: K local SGD steps (done by the coordinator with
//! `alpha_deg = 0`), then exchange full model parameters with every
//! neighbor and take the Metropolis–Hastings-weighted average
//! `w_i ← W_ii w_i + Σ_j W_ij w_j` (paper §2.2 / §D.1).
//!
//! Received parameter vectors are buffered per neighbor slot and folded
//! in sorted-neighbor order at `round_end`, so the f32 average is
//! bit-identical no matter in which order the virtual-time engine
//! delivers the messages — and identical to the threaded engine's.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::graph::Graph;

use super::{BuildCtx, NodeAlgorithm, NodeStateMachine};

pub struct DPsgdNode {
    node: usize,
    graph: Arc<Graph>,
    /// This node's row of the MH weight matrix.
    weights: Vec<f64>,
    /// Scratch accumulator (no allocation per round).
    acc: Vec<f32>,
    /// Received neighbor parameters, one slot per sorted neighbor.
    recv: Vec<Option<Vec<f32>>>,
    /// Messages still expected this round.
    pending: usize,
}

impl DPsgdNode {
    pub fn new(ctx: &BuildCtx) -> DPsgdNode {
        let weights = ctx.graph.mh_weights()[ctx.node].clone();
        let degree = ctx.graph.degree(ctx.node);
        DPsgdNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            weights,
            acc: vec![0.0; ctx.manifest.d_pad],
            recv: (0..degree).map(|_| None).collect(),
            pending: 0,
        }
    }
}

impl NodeStateMachine for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn round_begin(&mut self, _round: usize, w: &mut [f32],
                   out: &mut Outbox) -> Result<()> {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.pending = neighbors.len();
        for slot in self.recv.iter_mut() {
            *slot = None;
        }
        for &j in &neighbors {
            out.send(j, Msg::Dense(w.to_vec()));
        }
        Ok(())
    }

    fn on_message(&mut self, round: usize, from: usize, msg: Msg,
                  _w: &mut [f32], _out: &mut Outbox) -> Result<()> {
        ensure!(
            self.pending > 0,
            "D-PSGD node {}: unexpected message from {from} in round {round}",
            self.node
        );
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        ensure!(
            self.recv[jj].is_none(),
            "D-PSGD node {}: duplicate message from {from}",
            self.node
        );
        self.recv[jj] = Some(msg.into_dense()?);
        self.pending -= 1;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        self.pending == 0
    }

    fn round_end(&mut self, _round: usize, w: &mut [f32]) -> Result<()> {
        ensure!(
            self.pending == 0,
            "D-PSGD node {}: round_end with {} messages outstanding",
            self.node,
            self.pending
        );
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let wii = self.weights[self.node] as f32;
        for (a, &wv) in self.acc.iter_mut().zip(w.iter()) {
            *a = wii * wv;
        }
        for (jj, &j) in neighbors.iter().enumerate() {
            let wj = self.recv[jj]
                .take()
                .ok_or_else(|| anyhow!("missing parameters from {j}"))?;
            let wij = self.weights[j] as f32;
            for (a, &v) in self.acc.iter_mut().zip(&wj) {
                *a += wij * v;
            }
        }
        w.copy_from_slice(&self.acc);
        Ok(())
    }
}

impl NodeAlgorithm for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        // Shared blocking driver: send to all first (channels are
        // buffered; no deadlock), then drain one message per neighbor.
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        super::drive_blocking(self, &neighbors, round, w, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 8\nd_pad 8\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer l 2 4\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    #[test]
    fn gossip_average_preserves_mean_and_contracts() {
        // MH weights are doubly stochastic: the node-average of w is
        // invariant, and disagreement strictly contracts on a connected
        // graph.
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..8).map(|t| (i * 8 + t) as f32).collect())
            .collect();
        let mean_before: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        let spread_before: f32 = ws
            .iter()
            .map(|w| (w[0] - mean_before).abs())
            .sum();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        let ctx = BuildCtx {
                            node: i,
                            graph,
                            manifest: manifest(),
                            seed: 1,
                            eta: 0.1,
                            local_steps: 1,
                            rounds_per_epoch: 1,
                            dual_path: crate::algorithms::DualPath::Native,
                            runtime: None,
                        };
                        let mut node = DPsgdNode::new(&ctx);
                        node.exchange(0, w, &comm).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        let mean_after: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        assert!((mean_after - mean_before).abs() < 1e-3);
        let spread_after: f32 =
            ws.iter().map(|w| (w[0] - mean_after).abs()).sum();
        assert!(spread_after < spread_before);
        // Bytes: 4 nodes x 2 neighbors x 8 f32 = 256 B.
        assert_eq!(meter.total_bytes(), 4 * 2 * 8 * 4);
    }

    #[test]
    fn duplicate_and_stray_messages_error() {
        let graph = Arc::new(Graph::ring(4));
        let ctx = BuildCtx {
            node: 0,
            graph: Arc::clone(&graph),
            manifest: manifest(),
            seed: 1,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
        };
        let mut node = DPsgdNode::new(&ctx);
        let mut w = vec![1.0f32; 8];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &mut w, &mut out).unwrap();
        assert_eq!(out.len(), 2); // neighbors 1 and 3
        let payload = Msg::Dense(vec![2.0; 8]);
        NodeStateMachine::on_message(
            &mut node, 0, 1, payload.clone(), &mut w, &mut out,
        )
        .unwrap();
        // Duplicate from the same neighbor is a protocol error.
        assert!(NodeStateMachine::on_message(
            &mut node, 0, 1, payload.clone(), &mut w, &mut out,
        )
        .is_err());
        // Non-neighbor sender is a protocol error.
        assert!(NodeStateMachine::on_message(
            &mut node, 0, 2, payload.clone(), &mut w, &mut out,
        )
        .is_err());
        // Completing the round folds in sorted-neighbor order.
        NodeStateMachine::on_message(&mut node, 0, 3, payload, &mut w, &mut out)
            .unwrap();
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 0, &mut w).unwrap();
        // MH ring(4): W_ii = 1/3, W_ij = 1/3 each -> (1 + 2 + 2)/3.
        for &v in &w {
            assert!((v - 5.0 / 3.0).abs() < 1e-6);
        }
    }
}
