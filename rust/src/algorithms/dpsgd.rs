//! D-PSGD (Lian et al. 2017): the uncompressed Gossip baseline.
//!
//! Each round: K local SGD steps (done by the coordinator with
//! `alpha_deg = 0`), then exchange full model parameters with every
//! neighbor and take the Metropolis–Hastings-weighted average
//! `w_i ← W_ii w_i + Σ_j W_ij w_j` (paper §2.2 / §D.1).
//!
//! Received parameter vectors are buffered per neighbor slot and folded
//! in sorted-neighbor order at `round_end`, so the f32 average is
//! bit-identical no matter in which order the virtual-time engine
//! delivers the messages — and identical to the threaded engine's.
//!
//! Under [`RoundPolicy::Async`] each neighbor slot keeps the *freshest*
//! parameter vector received on its edge (slots survive across rounds
//! instead of being cleared), so a lagging edge contributes its last
//! known model up to `max_staleness` rounds old; a neighbor that has
//! not spoken at all yet (the first `max_staleness` rounds) contributes
//! the node's own parameters, which keeps the MH row stochastic.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::graph::Graph;

use super::{BuildCtx, NodeAlgorithm, NodeStateMachine, RoundPolicy};

pub struct DPsgdNode {
    node: usize,
    graph: Arc<Graph>,
    /// This node's row of the MH weight matrix.
    weights: Vec<f64>,
    /// Scratch accumulator (no allocation per round).
    acc: Vec<f32>,
    /// Freshest received neighbor parameters, one slot per sorted
    /// neighbor (cleared each round under `Sync`, persistent under
    /// `Async`).
    recv: Vec<Option<Vec<f32>>>,
    /// Sync vs bounded-staleness async rounds.
    policy: RoundPolicy,
    /// The node's own round clock (set by `round_begin`).
    cur_round: usize,
    /// Per-edge clock: round stamp of the freshest parameters received
    /// per neighbor slot (−1 = nothing yet).
    edge_round: Vec<i64>,
    /// Largest per-edge lag consumed at any `round_end`.
    max_lag_seen: usize,
}

impl DPsgdNode {
    pub fn new(ctx: &BuildCtx) -> DPsgdNode {
        let weights = ctx.graph.mh_weights()[ctx.node].clone();
        let degree = ctx.graph.degree(ctx.node);
        DPsgdNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            weights,
            acc: vec![0.0; ctx.manifest.d_pad],
            recv: (0..degree).map(|_| None).collect(),
            policy: ctx.round_policy,
            cur_round: 0,
            edge_round: vec![-1; degree],
            max_lag_seen: 0,
        }
    }
}

impl NodeStateMachine for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn round_begin(&mut self, round: usize, w: &mut [f32],
                   out: &mut Outbox) -> Result<()> {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.cur_round = round;
        if !self.policy.is_async() {
            // Sync folds exactly this round's parameters; async keeps
            // the freshest per edge across rounds.
            for slot in self.recv.iter_mut() {
                *slot = None;
            }
        }
        for &j in &neighbors {
            out.send(j, Msg::Dense(w.to_vec()));
        }
        Ok(())
    }

    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  _w: &mut [f32], _out: &mut Outbox) -> Result<()> {
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        super::admit_message(self.policy, self.node, from, self.cur_round,
                             self.edge_round[jj], msg_round)?;
        // FIFO stamps are strictly increasing, so overwriting always
        // keeps the freshest parameters for this edge.
        self.recv[jj] = Some(msg.into_dense()?);
        self.edge_round[jj] = msg_round as i64;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        super::staleness_gate(self.policy, self.cur_round, &self.edge_round)
    }

    fn round_end(&mut self, round: usize, w: &mut [f32]) -> Result<()> {
        let lag = super::check_staleness(self.policy, self.node, "parameters",
                                         round, &self.edge_round)?;
        self.max_lag_seen = self.max_lag_seen.max(lag);
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let wii = self.weights[self.node] as f32;
        for (a, &wv) in self.acc.iter_mut().zip(w.iter()) {
            *a = wii * wv;
        }
        for (jj, &j) in neighbors.iter().enumerate() {
            let wij = self.weights[j] as f32;
            match &self.recv[jj] {
                Some(wj) => {
                    for (a, &v) in self.acc.iter_mut().zip(wj) {
                        *a += wij * v;
                    }
                }
                // Only reachable in the first `max_staleness` async
                // rounds (edge_round = −1 ≥ horizon): the neighbor has
                // not spoken yet, so its MH weight falls back to our
                // own parameters — the row stays stochastic.
                None => {
                    for (a, &wv) in self.acc.iter_mut().zip(w.iter()) {
                        *a += wij * wv;
                    }
                }
            }
        }
        w.copy_from_slice(&self.acc);
        Ok(())
    }

    fn max_staleness_seen(&self) -> usize {
        self.max_lag_seen
    }

    fn policy(&self) -> Option<RoundPolicy> {
        Some(self.policy)
    }
}

impl NodeAlgorithm for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        // Shared blocking driver: send to all first (channels are
        // buffered; no deadlock), then drain one message per neighbor.
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        super::drive_blocking(self, &neighbors, round, w, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 8\nd_pad 8\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer l 2 4\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    #[test]
    fn gossip_average_preserves_mean_and_contracts() {
        // MH weights are doubly stochastic: the node-average of w is
        // invariant, and disagreement strictly contracts on a connected
        // graph.
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..8).map(|t| (i * 8 + t) as f32).collect())
            .collect();
        let mean_before: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        let spread_before: f32 = ws
            .iter()
            .map(|w| (w[0] - mean_before).abs())
            .sum();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        let ctx = BuildCtx {
                            node: i,
                            graph,
                            manifest: manifest(),
                            seed: 1,
                            eta: 0.1,
                            local_steps: 1,
                            rounds_per_epoch: 1,
                            dual_path: crate::algorithms::DualPath::Native,
                            runtime: None,
                            round_policy: RoundPolicy::Sync,
                        };
                        let mut node = DPsgdNode::new(&ctx);
                        node.exchange(0, w, &comm).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        let mean_after: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        assert!((mean_after - mean_before).abs() < 1e-3);
        let spread_after: f32 =
            ws.iter().map(|w| (w[0] - mean_after).abs()).sum();
        assert!(spread_after < spread_before);
        // Bytes: 4 nodes x 2 neighbors x 8 f32 = 256 B.
        assert_eq!(meter.total_bytes(), 4 * 2 * 8 * 4);
    }

    #[test]
    fn duplicate_and_stray_messages_error() {
        let graph = Arc::new(Graph::ring(4));
        let ctx = BuildCtx {
            node: 0,
            graph: Arc::clone(&graph),
            manifest: manifest(),
            seed: 1,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Sync,
        };
        let mut node = DPsgdNode::new(&ctx);
        let mut w = vec![1.0f32; 8];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &mut w, &mut out).unwrap();
        assert_eq!(out.len(), 2); // neighbors 1 and 3
        let payload = Msg::Dense(vec![2.0; 8]);
        NodeStateMachine::on_message(
            &mut node, 0, 1, payload.clone(), &mut w, &mut out,
        )
        .unwrap();
        // Duplicate from the same neighbor is a protocol error.
        assert!(NodeStateMachine::on_message(
            &mut node, 0, 1, payload.clone(), &mut w, &mut out,
        )
        .is_err());
        // Non-neighbor sender is a protocol error.
        assert!(NodeStateMachine::on_message(
            &mut node, 0, 2, payload.clone(), &mut w, &mut out,
        )
        .is_err());
        // Completing the round folds in sorted-neighbor order.
        NodeStateMachine::on_message(&mut node, 0, 3, payload, &mut w, &mut out)
            .unwrap();
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 0, &mut w).unwrap();
        // MH ring(4): W_ii = 1/3, W_ij = 1/3 each -> (1 + 2 + 2)/3.
        for &v in &w {
            assert!((v - 5.0 / 3.0).abs() < 1e-6);
        }
    }
}
