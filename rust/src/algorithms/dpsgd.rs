//! D-PSGD (Lian et al. 2017): the uncompressed Gossip baseline.
//!
//! Each round: K local SGD steps (done by the coordinator with
//! `alpha_deg = 0`), then exchange full model parameters with every
//! neighbor and take the Metropolis–Hastings-weighted average
//! `w_i ← W_ii w_i + Σ_j W_ij w_j` (paper §2.2 / §D.1).

use std::sync::Arc;

use crate::comm::{Msg, NodeComm};
use crate::graph::Graph;

use super::{BuildCtx, NodeAlgorithm};

pub struct DPsgdNode {
    node: usize,
    graph: Arc<Graph>,
    /// This node's row of the MH weight matrix.
    weights: Vec<f64>,
    /// Scratch accumulator (no allocation per round).
    acc: Vec<f32>,
}

impl DPsgdNode {
    pub fn new(ctx: &BuildCtx) -> DPsgdNode {
        let weights = ctx.graph.mh_weights()[ctx.node].clone();
        DPsgdNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            weights,
            acc: vec![0.0; ctx.manifest.d_pad],
        }
    }
}

impl NodeAlgorithm for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn exchange(&mut self, _round: usize, w: &mut [f32], comm: &NodeComm) {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        // Send to all first (channels are buffered; no deadlock).
        for &j in &neighbors {
            comm.send(j, Msg::Dense(w.to_vec()));
        }
        // Weighted average.
        let wii = self.weights[self.node] as f32;
        for (a, &wv) in self.acc.iter_mut().zip(w.iter()) {
            *a = wii * wv;
        }
        for &j in &neighbors {
            let wj = comm.recv(j).into_dense();
            let wij = self.weights[j] as f32;
            for (a, &v) in self.acc.iter_mut().zip(&wj) {
                *a += wij * v;
            }
        }
        w.copy_from_slice(&self.acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 8\nd_pad 8\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer l 2 4\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    #[test]
    fn gossip_average_preserves_mean_and_contracts() {
        // MH weights are doubly stochastic: the node-average of w is
        // invariant, and disagreement strictly contracts on a connected
        // graph.
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..8).map(|t| (i * 8 + t) as f32).collect())
            .collect();
        let mean_before: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        let spread_before: f32 = ws
            .iter()
            .map(|w| (w[0] - mean_before).abs())
            .sum();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        let ctx = BuildCtx {
                            node: i,
                            graph,
                            manifest: manifest(),
                            seed: 1,
                            eta: 0.1,
                            local_steps: 1,
                            rounds_per_epoch: 1,
                            dual_path: crate::algorithms::DualPath::Native,
                            runtime: None,
                        };
                        let mut node = DPsgdNode::new(&ctx);
                        node.exchange(0, w, &comm);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        let mean_after: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        assert!((mean_after - mean_before).abs() < 1e-3);
        let spread_after: f32 =
            ws.iter().map(|w| (w[0] - mean_after).abs()).sum();
        assert!(spread_after < spread_before);
        // Bytes: 4 nodes x 2 neighbors x 8 f32 = 256 B.
        assert_eq!(meter.total_bytes(), 4 * 2 * 8 * 4);
    }
}
