//! D-PSGD (Lian et al. 2017): the uncompressed Gossip baseline.
//!
//! Each round: K local SGD steps (done by the coordinator with
//! `alpha_deg = 0`), then exchange full model parameters with every
//! neighbor and take the Metropolis–Hastings-weighted average
//! `w_i ← W_ii w_i + Σ_j W_ij w_j` (paper §2.2 / §D.1).
//!
//! Received parameter vectors are buffered per neighbor slot and folded
//! in sorted-neighbor order at `round_end`, so the f32 average is
//! bit-identical no matter in which order the virtual-time engine
//! delivers the messages — and identical to the threaded engine's.
//!
//! Under [`RoundPolicy::Async`] each neighbor slot keeps the *freshest*
//! parameter vector received on its edge (slots survive across rounds
//! instead of being cleared), so a lagging edge contributes its last
//! known model up to `max_staleness` rounds old; a neighbor that has
//! not spoken at all yet (the first `max_staleness` rounds) contributes
//! the node's own parameters, which keeps the MH row stochastic.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::graph::{Graph, TopologyView};
use crate::linalg::{axpy_f32, scaled_copy_f32};
use crate::model::Arena;

use super::{BuildCtx, EdgeClock, NodeAlgorithm, NodeStateMachine,
            RoundPolicy};

pub struct DPsgdNode {
    node: usize,
    graph: Arc<Graph>,
    /// This node's row of the MH weight matrix.
    weights: Vec<f64>,
    /// Scratch accumulator (no allocation per round).
    acc: Vec<f32>,
    /// Freshest received neighbor parameters, one arena row per sorted
    /// neighbor — a contiguous slab, so the `round_end` fold walks
    /// memory linearly.  `fresh[jj]` says whether the row holds a
    /// usable vector (cleared each round under `Sync`, persistent
    /// under `Async`; retired on edge death so a churned-out
    /// neighbor's last model can never be folded in again).
    recv: Arena,
    fresh: Vec<bool>,
    /// Sync vs bounded-staleness async rounds.
    policy: RoundPolicy,
    /// The node's own round clock (set by `round_begin`).
    cur_round: usize,
    /// Per-edge clocks: freshest parameter stamp, liveness, activation.
    clocks: Vec<EdgeClock>,
    /// Cached edge incarnation per neighbor slot.
    edge_epochs: Vec<u32>,
    /// Last `TopologyView::version` synced against.
    seen_view: u64,
    /// Cached static full view for the (epoch-constant) blocking
    /// engine — built once instead of per exchange round.
    full_view: Arc<TopologyView>,
    /// Largest per-edge lag consumed at any `round_end`.
    max_lag_seen: usize,
}

impl DPsgdNode {
    pub fn new(ctx: &BuildCtx) -> DPsgdNode {
        let weights = ctx.graph.mh_weights()[ctx.node].clone();
        let degree = ctx.graph.degree(ctx.node);
        DPsgdNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            weights,
            acc: vec![0.0; ctx.manifest.d_pad],
            recv: Arena::zeros(degree, ctx.manifest.d_pad),
            fresh: vec![false; degree],
            policy: ctx.round_policy,
            cur_round: 0,
            clocks: vec![EdgeClock::born(0); degree],
            edge_epochs: vec![0; degree],
            seen_view: 0,
            full_view: Arc::new(TopologyView::full(
                ctx.graph.edges().len(),
            )),
            max_lag_seen: 0,
        }
    }

    /// Per-edge lifecycle sync (see `CEclNode::sync_view`): births reset
    /// the slot with a fresh clock, deaths retire the buffered neighbor
    /// parameters.  D-PSGD needs no codec or dual warm-start — a dead
    /// or unborn slot simply falls back to the node's own parameters in
    /// the MH fold, which keeps the weight row stochastic.
    fn sync_view(&mut self, view: &TopologyView) -> Result<()> {
        if view.version() == self.seen_view {
            return Ok(());
        }
        self.seen_view = view.version();
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let life = view.edge_life(e);
            if life.epoch != self.edge_epochs[jj] {
                self.edge_epochs[jj] = life.epoch;
                self.fresh[jj] = false;
                let mut clock = EdgeClock::born(life.activation_round);
                clock.live = life.live;
                self.clocks[jj] = clock;
            } else if life.live != self.clocks[jj].live {
                self.clocks[jj].live = life.live;
                if !life.live {
                    self.fresh[jj] = false;
                }
            }
        }
        Ok(())
    }
}

impl NodeStateMachine for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn round_begin(&mut self, round: usize, view: &TopologyView,
                   w: &mut [f32], out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.cur_round = round;
        if !self.policy.is_async() {
            // Sync folds exactly this round's parameters; async keeps
            // the freshest per edge across rounds.
            self.fresh.fill(false);
        }
        for (jj, &j) in neighbors.iter().enumerate() {
            if self.clocks[jj].active(round) {
                out.send(j, Msg::Dense(w.to_vec()));
            }
        }
        Ok(())
    }

    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  view: &TopologyView, _w: &mut [f32],
                  _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        anyhow::ensure!(
            self.clocks[jj].live,
            "node {}: parameters from {from} on a churned-out edge \
             (the engine should have dropped them)",
            self.node
        );
        super::admit_message(self.policy, self.node, from, self.cur_round,
                             self.clocks[jj].round, msg_round)?;
        // FIFO stamps are strictly increasing, so overwriting always
        // keeps the freshest parameters for this edge.
        let wj = msg.into_dense()?;
        anyhow::ensure!(
            wj.len() == self.acc.len(),
            "node {}: parameter payload len {} != d_pad {}",
            self.node,
            wj.len(),
            self.acc.len()
        );
        self.recv.row_mut(jj).copy_from_slice(&wj);
        self.fresh[jj] = true;
        self.clocks[jj].round = msg_round as i64;
        self.clocks[jj].spoken = true;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        super::staleness_gate(self.policy, self.cur_round, &self.clocks)
    }

    fn round_end(&mut self, round: usize, view: &TopologyView,
                 w: &mut [f32]) -> Result<()> {
        self.sync_view(view)?;
        let lag = super::check_staleness(self.policy, self.node, "parameters",
                                         round, &self.clocks)?;
        self.max_lag_seen = self.max_lag_seen.max(lag);
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let wii = self.weights[self.node] as f32;
        scaled_copy_f32(wii, w, &mut self.acc);
        for (jj, &j) in neighbors.iter().enumerate() {
            let wij = self.weights[j] as f32;
            if self.clocks[jj].live && self.fresh[jj] {
                axpy_f32(wij, self.recv.row(jj), &mut self.acc);
            } else {
                // Churned-out neighbor, or one that has not spoken yet
                // this incarnation (the first `max_staleness` async
                // rounds of birth slack): its MH weight falls back to
                // our own parameters — the row stays stochastic.
                axpy_f32(wij, w, &mut self.acc);
            }
        }
        w.copy_from_slice(&self.acc);
        Ok(())
    }

    fn on_topology(&mut self, view: &TopologyView, _w: &mut [f32],
                   _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)
    }

    fn max_staleness_seen(&self) -> usize {
        self.max_lag_seen
    }

    fn policy(&self) -> Option<RoundPolicy> {
        Some(self.policy)
    }
}

impl NodeAlgorithm for DPsgdNode {
    fn name(&self) -> String {
        "D-PSGD".to_string()
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        // Shared blocking driver: send to all first (channels are
        // buffered; no deadlock), then drain one message per neighbor.
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let view = Arc::clone(&self.full_view);
        super::drive_blocking(self, &neighbors, &view, round, w, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 8\nd_pad 8\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer l 2 4\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    #[test]
    fn gossip_average_preserves_mean_and_contracts() {
        // MH weights are doubly stochastic: the node-average of w is
        // invariant, and disagreement strictly contracts on a connected
        // graph.
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..8).map(|t| (i * 8 + t) as f32).collect())
            .collect();
        let mean_before: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        let spread_before: f32 = ws
            .iter()
            .map(|w| (w[0] - mean_before).abs())
            .sum();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        let ctx = BuildCtx {
                            node: i,
                            graph,
                            manifest: manifest(),
                            seed: 1,
                            eta: 0.1,
                            local_steps: 1,
                            rounds_per_epoch: 1,
                            dual_path: crate::algorithms::DualPath::Native,
                            runtime: None,
                            round_policy: RoundPolicy::Sync,
                        };
                        let mut node = DPsgdNode::new(&ctx);
                        node.exchange(0, w, &comm).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        let mean_after: f32 =
            ws.iter().flat_map(|w| w.iter()).sum::<f32>() / 32.0;
        assert!((mean_after - mean_before).abs() < 1e-3);
        let spread_after: f32 =
            ws.iter().map(|w| (w[0] - mean_after).abs()).sum();
        assert!(spread_after < spread_before);
        // Bytes: 4 nodes x 2 neighbors x 8 f32 = 256 B.
        assert_eq!(meter.total_bytes(), 4 * 2 * 8 * 4);
    }

    #[test]
    fn duplicate_and_stray_messages_error() {
        let graph = Arc::new(Graph::ring(4));
        let ctx = BuildCtx {
            node: 0,
            graph: Arc::clone(&graph),
            manifest: manifest(),
            seed: 1,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Sync,
        };
        let mut node = DPsgdNode::new(&ctx);
        let view = TopologyView::full(graph.edges().len());
        let mut w = vec![1.0f32; 8];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 2); // neighbors 1 and 3
        let payload = Msg::Dense(vec![2.0; 8]);
        NodeStateMachine::on_message(
            &mut node, 0, 1, payload.clone(), &view, &mut w, &mut out,
        )
        .unwrap();
        // Duplicate from the same neighbor is a protocol error.
        assert!(NodeStateMachine::on_message(
            &mut node, 0, 1, payload.clone(), &view, &mut w, &mut out,
        )
        .is_err());
        // Non-neighbor sender is a protocol error.
        assert!(NodeStateMachine::on_message(
            &mut node, 0, 2, payload.clone(), &view, &mut w, &mut out,
        )
        .is_err());
        // Completing the round folds in sorted-neighbor order.
        NodeStateMachine::on_message(&mut node, 0, 3, payload, &view, &mut w,
                                     &mut out)
            .unwrap();
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 0, &view, &mut w).unwrap();
        // MH ring(4): W_ii = 1/3, W_ij = 1/3 each -> (1 + 2 + 2)/3.
        for &v in &w {
            assert!((v - 5.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn churned_out_neighbor_folds_own_parameters() {
        // Kill edge (0, 1): D-PSGD stops sending there, the gate skips
        // it, and the MH fold substitutes the node's own parameters for
        // the missing neighbor — the row stays stochastic, so a vector
        // of ones stays a vector of ones.
        let graph = Arc::new(Graph::ring(4));
        let ctx = BuildCtx {
            node: 0,
            graph: Arc::clone(&graph),
            manifest: manifest(),
            seed: 1,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Sync,
        };
        let mut node = DPsgdNode::new(&ctx);
        let mut view = TopologyView::full(graph.edges().len());
        view.kill_edge(graph.edge_index(0, 1).unwrap());
        let mut w = vec![1.0f32; 8];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(out.len(), 1); // only neighbor 3
        let drained: Vec<_> = out.drain().collect();
        assert_eq!(drained[0].0, 3);
        assert!(!node.round_complete(), "live neighbor 3 still gates");
        NodeStateMachine::on_message(&mut node, 0, 3,
                                     Msg::Dense(vec![1.0; 8]), &view, &mut w,
                                     &mut out)
            .unwrap();
        assert!(node.round_complete());
        NodeStateMachine::round_end(&mut node, 0, &view, &mut w).unwrap();
        for &v in &w {
            assert!((v - 1.0).abs() < 1e-6, "{v}");
        }
    }
}
