//! LEAD (Liu et al. 2021, arXiv 2007.00232): compressed primal–dual
//! decentralized SGD — the paper's strongest *dual-family* rival.
//!
//! LEAD is, like (C-)ECL, an operator-splitting method: each node
//! carries a dual variable `d_i` (with `Σ_i d_i = 0` preserved by
//! symmetric updates) and communicates a *compressed difference*
//! against a per-edge replica, so the transmitted payload vanishes at
//! the fix point.  One round of Algorithm 1, mapped onto this repo's
//! round contract:
//!
//! 1. **Local step** (the engine's Eq. (6) kernel): the machine
//!    advertises `alpha_deg = 0` and `zsum = −d_i`, so the shared
//!    local-update kernel computes
//!    `z_i = w_i − η ∇f_i(w_i) − η d_i`
//!    — exactly LEAD's gradient + dual correction, with zero custom
//!    kernel code.
//! 2. **Compress & gossip** (`round_begin` / `on_message`): per live
//!    edge, send `q = comp(z_i − h_{i|j})`, form the estimate
//!    `ẑ_{i|j} = h_{i|j} + q̂` and mix the replica
//!    `h_{i|j} += α q̂` (both endpoints apply the *decoded* payload, so
//!    the replica pair never forks).  The mixing rate
//!    `α = 1/(2 − τ) ∈ (1/2, 1]` sits mid-interval of the contraction
//!    condition `α (1 + C) < 2` with `C = 1 − τ`, so the replica error
//!    contracts for every codec the repo ships (`identity` ⇒ α = 1).
//! 3. **Primal–dual update** (`round_end`): with
//!    `diff_i = Σ_j W_ij (ẑ_{i|j} − ẑ_{j|i})` over live, spoken edges,
//!    `d_i += γ/(2η) · diff_i` and `w_i = z_i − (γ/2) · diff_i`,
//!    using the Metropolis–Hastings weights and γ = 1.
//!
//! The dual `d_i` is node-level state and survives churn; the replica
//! pairs `h_{i|j}`, `h_{j|i}` and estimates `ẑ` are per-edge state
//! with the full lifecycle: birth allocates fresh codecs and zeroes
//! them (the next send retransmits the full compressed state), death
//! retires them, and unspoken slots contribute nothing to `diff`.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::{CodecSpec, EdgeCodec, EdgeCtx};
use crate::graph::{Graph, TopologyView};
use crate::linalg::consensus_mix_f32;
use crate::model::Arena;

use super::{BuildCtx, EdgeClock, NodeAlgorithm, NodeStateMachine,
            RoundPolicy};

pub struct LeadNode {
    node: usize,
    graph: Arc<Graph>,
    seed: u64,
    d_pad: usize,
    /// This node's row of the MH weight matrix.
    weights: Vec<f64>,
    /// Learning rate η — the dual step is γ/(2η).
    eta: f32,
    /// Primal–dual step size γ (Algorithm 1; 1.0 is the paper default).
    gamma: f32,
    /// Replica mixing rate α = 1/(2 − τ).
    alpha_mix: f32,
    codec_spec: CodecSpec,
    /// Outbound codec per slot (encode + self-decode of own payload).
    codecs_out: Vec<Box<dyn EdgeCodec>>,
    /// Inbound codec per slot (decode of the neighbor's payload).
    codecs_in: Vec<Box<dyn EdgeCodec>>,
    /// `h_{i|j}`: own-side replica as held by neighbor slot jj (arena
    /// row per slot, one contiguous slab — likewise the three below).
    h_self: Arena,
    /// `h_{j|i}`: neighbor slot jj's replica held here.
    h_nb: Arena,
    /// `ẑ_{i|j}`: freshest own-z estimate shared with slot jj.
    zhat_self: Arena,
    /// `ẑ_{j|i}`: freshest estimate of slot jj's z.
    zhat_nb: Arena,
    /// `−d_i`, exposed as `zsum` so the Eq. (6) kernel computes
    /// `w − η∇f − η d` with `alpha_deg = 0`.
    neg_d: Vec<f32>,
    /// Sync vs bounded-staleness async rounds.
    policy: RoundPolicy,
    cur_round: usize,
    clocks: Vec<EdgeClock>,
    edge_epochs: Vec<u32>,
    seen_view: u64,
    mats: Vec<(usize, usize, usize)>,
    vecs: Vec<(usize, usize)>,
    full_view: Arc<TopologyView>,
    max_lag_seen: usize,
    // -- preallocated scratch -------------------------------------------
    diff: Vec<f32>,
    scratch_q: Vec<f32>,
    /// Reusable decode target: every `decode_into` lands here.
    scratch_recv: Vec<f32>,
}

impl LeadNode {
    pub fn new(ctx: &BuildCtx, codec: CodecSpec) -> Result<LeadNode> {
        let degree = ctx.graph.degree(ctx.node);
        ensure!(degree > 0, "LEAD requires no isolated nodes");
        codec.validate()?;
        let d_pad = ctx.manifest.d_pad;
        let mats: Vec<(usize, usize, usize)> = ctx
            .manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vecs: Vec<(usize, usize)> = ctx
            .manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        let build = |mats: &[(usize, usize, usize)],
                     vecs: &[(usize, usize)]| {
            let mut c = codec.build();
            c.bind_layout(mats, vecs);
            c
        };
        let tau = codec.tau(d_pad).clamp(0.0, 1.0);
        Ok(LeadNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            seed: ctx.seed,
            d_pad,
            weights: ctx.graph.mh_weights()[ctx.node].clone(),
            eta: ctx.eta,
            gamma: 1.0,
            alpha_mix: (1.0 / (2.0 - tau)) as f32,
            codecs_out: (0..degree).map(|_| build(&mats, &vecs)).collect(),
            codecs_in: (0..degree).map(|_| build(&mats, &vecs)).collect(),
            codec_spec: codec,
            h_self: Arena::zeros(degree, d_pad),
            h_nb: Arena::zeros(degree, d_pad),
            zhat_self: Arena::zeros(degree, d_pad),
            zhat_nb: Arena::zeros(degree, d_pad),
            neg_d: vec![0.0; d_pad],
            policy: ctx.round_policy,
            cur_round: 0,
            clocks: vec![EdgeClock::born(0); degree],
            edge_epochs: vec![0; degree],
            seen_view: 0,
            mats,
            vecs,
            full_view: Arc::new(TopologyView::full(
                ctx.graph.edges().len(),
            )),
            max_lag_seen: 0,
            diff: vec![0.0; d_pad],
            scratch_q: Vec::with_capacity(d_pad),
            scratch_recv: vec![0.0; d_pad],
        })
    }

    /// Replica mixing rate the codec's τ selected.
    pub fn alpha_mix(&self) -> f32 {
        self.alpha_mix
    }

    /// Test access to the dual variable (as `−d_i`).
    pub fn neg_dual(&self) -> &[f32] {
        &self.neg_d
    }

    /// Per-edge lifecycle sync (same contract as the other machines):
    /// birth ⇒ fresh codecs + zeroed replicas/estimates; death ⇒
    /// retire.  The node-level dual `neg_d` survives churn.
    fn sync_view(&mut self, view: &TopologyView) -> Result<()> {
        if view.version() == self.seen_view {
            return Ok(());
        }
        self.seen_view = view.version();
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let life = view.edge_life(e);
            if life.epoch != self.edge_epochs[jj] {
                self.edge_epochs[jj] = life.epoch;
                let mut codec = self.codec_spec.build();
                codec.bind_layout(&self.mats, &self.vecs);
                self.codecs_out[jj] = codec;
                let mut codec = self.codec_spec.build();
                codec.bind_layout(&self.mats, &self.vecs);
                self.codecs_in[jj] = codec;
                self.h_self.row_mut(jj).fill(0.0);
                self.h_nb.row_mut(jj).fill(0.0);
                self.zhat_self.row_mut(jj).fill(0.0);
                self.zhat_nb.row_mut(jj).fill(0.0);
                let mut clock = EdgeClock::born(life.activation_round);
                clock.live = life.live;
                self.clocks[jj] = clock;
            } else if life.live != self.clocks[jj].live {
                self.clocks[jj].live = life.live;
                if !life.live {
                    self.h_self.row_mut(jj).fill(0.0);
                    self.h_nb.row_mut(jj).fill(0.0);
                    self.zhat_self.row_mut(jj).fill(0.0);
                    self.zhat_nb.row_mut(jj).fill(0.0);
                }
            }
        }
        Ok(())
    }

    fn edge_ctx(&self, jj: usize, edge: usize, round: usize,
                receiver: usize) -> EdgeCtx {
        EdgeCtx {
            seed: self.seed,
            edge,
            round,
            receiver,
            dim: self.d_pad,
            epoch: self.edge_epochs[jj],
        }
    }
}

impl NodeStateMachine for LeadNode {
    fn name(&self) -> String {
        format!("LEAD [{}]", self.codec_spec.name())
    }

    fn round_begin(&mut self, round: usize, view: &TopologyView,
                   w: &mut [f32], out: &mut Outbox) -> Result<()> {
        // On entry `w` holds z = w − η∇f − ηd (the Eq. (6) kernel with
        // alpha_deg = 0 and zsum = −d already ran the local steps).
        self.sync_view(view)?;
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        self.cur_round = round;
        for (jj, &j) in neighbors.iter().enumerate() {
            if !self.clocks[jj].active(round) {
                continue;
            }
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let ctx_e = self.edge_ctx(jj, e, round, j);
            let codec = &mut self.codecs_out[jj];
            let hs = self.h_self.row(jj);
            let frame = match codec.encode_from(&|i| w[i] - hs[i], &ctx_e) {
                Some(frame) => frame,
                None => {
                    self.scratch_q.clear();
                    self.scratch_q.extend(
                        w.iter().zip(hs.iter()).map(|(&zv, &h)| zv - h),
                    );
                    codec.encode(&self.scratch_q, &ctx_e)
                }
            };
            // Mirror the receiver: ẑ_{i|j} = h + q̂, then h += α q̂, off
            // the decoded payload (landed in persistent scratch) so the
            // pair never forks.
            codec.decode_into(&frame, &ctx_e, &mut self.scratch_recv)?;
            let alpha = self.alpha_mix;
            for ((zh, h), &q) in self.zhat_self
                .row_mut(jj)
                .iter_mut()
                .zip(self.h_self.row_mut(jj).iter_mut())
                .zip(&self.scratch_recv)
            {
                *zh = *h + q;
                *h += alpha * q;
            }
            out.send(j, Msg::Frame(frame));
        }
        Ok(())
    }

    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  view: &TopologyView, _w: &mut [f32],
                  _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        let jj = self
            .graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })?;
        ensure!(
            self.clocks[jj].live,
            "node {}: z-estimate from {from} on a churned-out edge \
             (the engine should have dropped it)",
            self.node
        );
        super::admit_message(self.policy, self.node, from, self.cur_round,
                             self.clocks[jj].round, msg_round)?;
        let e = self
            .graph
            .edge_index(self.node, from)
            .ok_or_else(|| anyhow!("({}, {from}) is not an edge", self.node))?;
        let ctx_e = self.edge_ctx(jj, e, msg_round, self.node);
        let frame = msg.into_frame()?;
        self.codecs_in[jj].decode_into(&frame, &ctx_e,
                                       &mut self.scratch_recv)?;
        let alpha = self.alpha_mix;
        for ((zh, h), &q) in self.zhat_nb
            .row_mut(jj)
            .iter_mut()
            .zip(self.h_nb.row_mut(jj).iter_mut())
            .zip(&self.scratch_recv)
        {
            *zh = *h + q;
            *h += alpha * q;
        }
        self.clocks[jj].round = msg_round as i64;
        self.clocks[jj].spoken = true;
        Ok(())
    }

    fn round_complete(&self) -> bool {
        super::staleness_gate(self.policy, self.cur_round, &self.clocks)
    }

    fn round_end(&mut self, round: usize, view: &TopologyView,
                 w: &mut [f32]) -> Result<()> {
        self.sync_view(view)?;
        let lag = super::check_staleness(self.policy, self.node, "z-estimate",
                                         round, &self.clocks)?;
        self.max_lag_seen = self.max_lag_seen.max(lag);
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        // diff = Σ_j W_ij (ẑ_{i|j} − ẑ_{j|i}) over live, spoken slots —
        // the fused consensus kernel, bit-identical to the plain loop.
        self.diff.fill(0.0);
        for (jj, &j) in neighbors.iter().enumerate() {
            let c = &self.clocks[jj];
            if !(c.live && c.spoken) {
                continue;
            }
            let wij = self.weights[j] as f32;
            consensus_mix_f32(&mut self.diff, self.zhat_self.row(jj),
                              self.zhat_nb.row(jj), wij);
        }
        // d += γ/(2η) diff  (stored negated);  w = z − (γ/2) diff.
        let dual_step = self.gamma / (2.0 * self.eta);
        let primal_step = self.gamma / 2.0;
        for ((nd, wv), &dv) in
            self.neg_d.iter_mut().zip(w.iter_mut()).zip(&self.diff)
        {
            *nd -= dual_step * dv;
            *wv -= primal_step * dv;
        }
        Ok(())
    }

    fn on_topology(&mut self, view: &TopologyView, _w: &mut [f32],
                   _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)
    }

    fn zsum(&self) -> Option<&[f32]> {
        Some(&self.neg_d)
    }

    fn max_staleness_seen(&self) -> usize {
        self.max_lag_seen
    }

    fn policy(&self) -> Option<RoundPolicy> {
        Some(self.policy)
    }
}

impl NodeAlgorithm for LeadNode {
    fn name(&self) -> String {
        NodeStateMachine::name(self)
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let view = Arc::clone(&self.full_view);
        super::drive_blocking(self, &neighbors, &view, round, w, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;
    use crate::util::rng::Pcg;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 8\nd_pad 8\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer l 2 4\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    fn ctx(node: usize, graph: &Arc<Graph>) -> BuildCtx {
        BuildCtx {
            node,
            graph: Arc::clone(graph),
            manifest: manifest(),
            seed: 9,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Sync,
        }
    }

    #[test]
    fn advertises_the_dual_through_zsum_with_zero_alpha_deg() {
        let graph = Arc::new(Graph::ring(4));
        let node =
            LeadNode::new(&ctx(0, &graph), CodecSpec::Identity).unwrap();
        assert_eq!(NodeStateMachine::alpha_deg(&node), 0.0);
        let z = NodeStateMachine::zsum(&node).expect("LEAD carries a dual");
        assert!(z.iter().all(|&v| v == 0.0), "dual starts at zero");
        assert_eq!(NodeStateMachine::name(&node), "LEAD [identity]");
    }

    #[test]
    fn alpha_mix_spans_half_to_one() {
        let graph = Arc::new(Graph::ring(4));
        let a = |s: &str| {
            LeadNode::new(&ctx(0, &graph), CodecSpec::parse(s).unwrap())
                .unwrap()
                .alpha_mix()
        };
        assert_eq!(a("identity"), 1.0);
        let r = a("rand_k:0.1");
        assert!(r > 0.5 && r < 0.54, "{r}");
    }

    #[test]
    fn consensus_rounds_drive_dual_to_disagreement_pressure() {
        // Two nodes, identity codec, no gradients: nodes should agree
        // and the duals should absorb the initial disagreement
        // symmetrically (d_0 = −d_1, so Σ d = 0).
        let graph = Arc::new(Graph::complete(2));
        let view = TopologyView::full(graph.edges().len());
        let mut nodes: Vec<LeadNode> = (0..2)
            .map(|i| LeadNode::new(&ctx(i, &graph), CodecSpec::Identity)
                .unwrap())
            .collect();
        let mut ws = vec![vec![1.0f32; 8], vec![-1.0f32; 8]];
        for r in 0..200 {
            // "Local step" with zero gradient: z = w + η·zsum.
            for (i, n) in nodes.iter().enumerate() {
                let z: Vec<f32> = NodeStateMachine::zsum(n)
                    .unwrap()
                    .to_vec();
                for (wv, zv) in ws[i].iter_mut().zip(z) {
                    *wv += 0.1 * zv;
                }
            }
            let mut inflight = Vec::new();
            for (i, n) in nodes.iter_mut().enumerate() {
                let mut out = Outbox::new();
                NodeStateMachine::round_begin(n, r, &view, &mut ws[i],
                                              &mut out)
                    .unwrap();
                for (to, msg) in out.drain() {
                    inflight.push((i, to, msg));
                }
            }
            for (from, to, msg) in inflight {
                let mut out = Outbox::new();
                NodeStateMachine::on_message(&mut nodes[to], r, from, msg,
                                             &view, &mut ws[to], &mut out)
                    .unwrap();
            }
            for (i, n) in nodes.iter_mut().enumerate() {
                assert!(NodeStateMachine::round_complete(n));
                NodeStateMachine::round_end(n, r, &view, &mut ws[i])
                    .unwrap();
            }
        }
        // Consensus: both nodes at the average (0).
        for wsn in &ws {
            for &v in wsn {
                assert!(v.abs() < 1e-3, "no consensus: {v}");
            }
        }
        // Dual symmetry: d_0 + d_1 = 0 exactly by construction.
        for (a, b) in nodes[0].neg_dual().iter().zip(nodes[1].neg_dual()) {
            assert!((a + b).abs() < 1e-4, "dual sum {a} + {b}");
        }
    }

    #[test]
    fn edge_rebirth_resets_replicas_but_keeps_the_dual() {
        let graph = Arc::new(Graph::ring(4));
        let spec = CodecSpec::parse("rand_k:0.5").unwrap();
        let mut node = LeadNode::new(&ctx(0, &graph), spec).unwrap();
        let mut view = TopologyView::full(graph.edges().len());
        let mut w: Vec<f32> = {
            let mut rng = Pcg::new(11);
            (0..8).map(|_| rng.normal_f32()).collect()
        };
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        out.drain().for_each(drop);
        node.neg_d[0] = 0.5; // pretend the dual has moved
        assert!(node.h_self.row(0).iter().any(|&v| v != 0.0));
        let e = graph.edge_index(0, 1).unwrap();
        view.kill_edge(e);
        view.revive_edge(e, 2);
        NodeStateMachine::on_topology(&mut node, &view, &mut w, &mut out)
            .unwrap();
        assert!(node.h_self.row(0).iter().all(|&v| v == 0.0));
        assert!(node.zhat_nb.row(0).iter().all(|&v| v == 0.0));
        assert_eq!(node.neg_d[0], 0.5, "dual is node state, survives churn");
        assert_eq!(node.clocks[0].activation, 2);
    }
}
