//! Decentralized-learning algorithms: the paper's C-ECL plus every
//! comparison method of §5.1.
//!
//! Each algorithm is a per-node state machine driven by the coordinator's
//! node thread.  The local-update phase is shared (the AOT train_step
//! artifact, Eq. (6) closed form — gossip methods run it with
//! `alpha_deg = 0`, reducing it to plain SGD); the algorithms differ in
//! what [`NodeAlgorithm::exchange`] puts on the wire every K local steps.

pub mod cecl;
pub mod dpsgd;
pub mod powergossip;

pub use cecl::{CEclNode, DualPath, DualRule};
pub use dpsgd::DPsgdNode;
pub use powergossip::PowerGossipNode;

use std::sync::Arc;

use crate::comm::NodeComm;
use crate::graph::Graph;
use crate::model::DatasetManifest;
use crate::runtime::ModelRuntime;

/// Per-node algorithm driven by the coordinator.
pub trait NodeAlgorithm: Send {
    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// `α·|N_i|` fed to the Eq. (6) train step (0 for gossip methods).
    fn alpha_deg(&self) -> f32 {
        0.0
    }

    /// `Σ_j A_{i|j} z_{i|j}` fed to the train step, if the algorithm
    /// maintains dual state.
    fn zsum(&self) -> Option<&[f32]> {
        None
    }

    /// Communication phase after the K local updates of round `round`.
    /// May rewrite `w` (gossip averaging) and/or internal dual state.
    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm);
}

/// Declarative algorithm selection (what the CLI and experiment drivers
/// construct).
#[derive(Debug, Clone, PartialEq)]
pub enum AlgorithmSpec {
    /// Single-node SGD on all data (the paper's reference row).
    Sgd,
    /// D-PSGD (Lian et al. 2017): gossip averaging with MH weights.
    DPsgd,
    /// ECL (Niwa et al. 2020): uncompressed primal-dual, θ ∈ (0, 1].
    Ecl { theta: f32 },
    /// C-ECL (this paper): rand_k% compression of the dual update.
    CEcl {
        k_frac: f64,
        theta: f32,
        /// Paper §5.1: k = 100% during the first epoch.
        dense_first_epoch: bool,
    },
    /// Ablation: Eq. (11) — compress y directly (§3.2 “does not work”).
    NaiveCEcl { k_frac: f64, theta: f32 },
    /// PowerGossip (Vogels et al. 2020) with the given power-iteration
    /// steps per round.
    PowerGossip { iters: usize },
}

impl AlgorithmSpec {
    pub fn name(&self) -> String {
        match self {
            AlgorithmSpec::Sgd => "SGD".to_string(),
            AlgorithmSpec::DPsgd => "D-PSGD".to_string(),
            AlgorithmSpec::Ecl { .. } => "ECL".to_string(),
            AlgorithmSpec::CEcl { k_frac, .. } => {
                format!("C-ECL ({}%)", (*k_frac * 100.0).round() as u32)
            }
            AlgorithmSpec::NaiveCEcl { k_frac, .. } => {
                format!("naive-C-ECL ({}%)", (*k_frac * 100.0).round() as u32)
            }
            AlgorithmSpec::PowerGossip { iters } => {
                format!("PowerGossip ({iters})")
            }
        }
    }

    /// Whether this algorithm exchanges anything at all.
    pub fn is_decentralized(&self) -> bool {
        !matches!(self, AlgorithmSpec::Sgd)
    }

    /// Parse CLI names like `cecl:0.1`, `powergossip:10`, `ecl`, `dpsgd`.
    pub fn parse(s: &str) -> Option<AlgorithmSpec> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "sgd" => Some(AlgorithmSpec::Sgd),
            "dpsgd" | "d-psgd" => Some(AlgorithmSpec::DPsgd),
            "ecl" => Some(AlgorithmSpec::Ecl {
                theta: arg.map(|a| a.parse().ok()).flatten().unwrap_or(1.0),
            }),
            "cecl" | "c-ecl" => Some(AlgorithmSpec::CEcl {
                k_frac: arg?.parse().ok()?,
                theta: 1.0,
                dense_first_epoch: true,
            }),
            "naive-cecl" => Some(AlgorithmSpec::NaiveCEcl {
                k_frac: arg?.parse().ok()?,
                theta: 1.0,
            }),
            "powergossip" | "pg" => Some(AlgorithmSpec::PowerGossip {
                iters: arg?.parse().ok()?,
            }),
            _ => None,
        }
    }
}

/// Everything a node algorithm needs at construction time.
pub struct BuildCtx {
    pub node: usize,
    pub graph: Arc<Graph>,
    pub manifest: DatasetManifest,
    pub seed: u64,
    pub eta: f32,
    /// K — local steps between exchanges.
    pub local_steps: usize,
    pub rounds_per_epoch: usize,
    pub dual_path: DualPath,
    pub runtime: Option<Arc<ModelRuntime>>,
}

/// The paper's α schedule (§D.1): Eq. (46) for the ECL
/// `α = 1 / (η |N_i| (K − 1))` and Eq. (47) for the C-ECL
/// `α = 1 / (η |N_i| (100K/k − 1))` — the compression stretches the
/// effective consensus interval.
pub fn paper_alpha(eta: f32, degree: usize, local_steps: usize,
                   k_frac: f64) -> f32 {
    let k_eff = local_steps as f64 / k_frac.clamp(1e-6, 1.0);
    let denom = eta as f64 * degree as f64 * (k_eff - 1.0).max(1e-6);
    (1.0 / denom) as f32
}

/// Build the per-node state machine for a spec.
pub fn build_node(spec: &AlgorithmSpec, ctx: &BuildCtx) -> Box<dyn NodeAlgorithm> {
    match spec {
        AlgorithmSpec::Sgd => Box::new(SgdNode),
        AlgorithmSpec::DPsgd => Box::new(DPsgdNode::new(ctx)),
        AlgorithmSpec::Ecl { theta } => Box::new(CEclNode::new(
            ctx,
            1.0,
            *theta,
            0,
            DualRule::CompressDiff,
        )),
        AlgorithmSpec::CEcl {
            k_frac,
            theta,
            dense_first_epoch,
        } => {
            let dense_rounds = if *dense_first_epoch {
                ctx.rounds_per_epoch
            } else {
                0
            };
            Box::new(CEclNode::new(
                ctx,
                *k_frac,
                *theta,
                dense_rounds,
                DualRule::CompressDiff,
            ))
        }
        AlgorithmSpec::NaiveCEcl { k_frac, theta } => Box::new(CEclNode::new(
            ctx,
            *k_frac,
            *theta,
            0,
            DualRule::CompressY,
        )),
        AlgorithmSpec::PowerGossip { iters } => {
            Box::new(PowerGossipNode::new(ctx, *iters))
        }
    }
}

/// Single-node SGD: no neighbors, no exchange, `alpha_deg = 0`.
pub struct SgdNode;

impl NodeAlgorithm for SgdNode {
    fn name(&self) -> String {
        "SGD".to_string()
    }

    fn exchange(&mut self, _round: usize, _w: &mut [f32], _comm: &NodeComm) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(AlgorithmSpec::parse("sgd"), Some(AlgorithmSpec::Sgd));
        assert_eq!(AlgorithmSpec::parse("dpsgd"), Some(AlgorithmSpec::DPsgd));
        assert_eq!(
            AlgorithmSpec::parse("ecl"),
            Some(AlgorithmSpec::Ecl { theta: 1.0 })
        );
        assert_eq!(
            AlgorithmSpec::parse("cecl:0.1"),
            Some(AlgorithmSpec::CEcl {
                k_frac: 0.1,
                theta: 1.0,
                dense_first_epoch: true
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("powergossip:10"),
            Some(AlgorithmSpec::PowerGossip { iters: 10 })
        );
        assert_eq!(AlgorithmSpec::parse("cecl"), None);
        assert_eq!(AlgorithmSpec::parse("bogus"), None);
    }

    #[test]
    fn spec_names_match_paper_rows() {
        assert_eq!(
            AlgorithmSpec::CEcl {
                k_frac: 0.01,
                theta: 1.0,
                dense_first_epoch: true
            }
            .name(),
            "C-ECL (1%)"
        );
        assert_eq!(
            AlgorithmSpec::PowerGossip { iters: 20 }.name(),
            "PowerGossip (20)"
        );
    }

    #[test]
    fn paper_alpha_eq46_eq47() {
        // Eq. (46): η=0.01, |N|=2, K=5 → α = 1/(0.01*2*4) = 12.5.
        let a = paper_alpha(0.01, 2, 5, 1.0);
        assert!((a - 12.5).abs() < 1e-4);
        // Eq. (47): k=10% → K_eff = 50 → α = 1/(0.01*2*49).
        let a = paper_alpha(0.01, 2, 5, 0.1);
        assert!((a - 1.0 / (0.01 * 2.0 * 49.0)).abs() < 1e-4);
        // More compression (smaller k) → smaller α.
        assert!(paper_alpha(0.01, 2, 5, 0.01) < paper_alpha(0.01, 2, 5, 0.1));
    }
}
