//! Decentralized-learning algorithms: the paper's C-ECL plus every
//! comparison method of §5.1.
//!
//! Each algorithm is a per-node protocol with two interchangeable
//! driving modes:
//!
//! * [`NodeAlgorithm::exchange`] — the blocking form used by the
//!   thread-per-node coordinator: send to every neighbor, then block on
//!   `recv` until the round's traffic has drained.
//! * [`NodeStateMachine`] — the poll-driven form used by the
//!   event-driven virtual-time engine (`crate::sim`): one round is
//!   `round_begin` → (`on_message` until [`NodeStateMachine::round_complete`])
//!   → `round_end`, with outbound traffic queued on an
//!   [`Outbox`](crate::comm::Outbox) instead of written to a channel.
//!
//! Every concrete node type implements both traits over the same state,
//! so the two engines run bit-identical protocols (the `sim` integration
//! tests pin byte-level equivalence).  The local-update phase is shared
//! (the AOT train_step artifact, Eq. (6) closed form — gossip methods
//! run it with `alpha_deg = 0`, reducing it to plain SGD); the
//! algorithms differ in what goes on the wire every K local steps.
//!
//! ## Round policies: per-edge clocks
//!
//! Rounds are **per-edge**, not global.  Every message carries the
//! round counter of the *sender* at the moment it was queued, and
//! [`NodeStateMachine::on_message`] receives that stamp (`msg_round`) —
//! not the receiver's own round.  A [`RoundPolicy`] decides when a node
//! may finish its exchange phase and run its next K local steps:
//!
//! * [`RoundPolicy::Sync`] (default) — `round_complete` requires every
//!   edge to have delivered its round-`r` message; `msg_round` always
//!   equals the receiver's round, and the trajectory is bit-identical
//!   to the classic bulk-synchronous schedule on both engines (pinned
//!   by tests).
//! * [`RoundPolicy::Async { max_staleness }`] — gossip-style: each edge
//!   advances on its own clock, messages are consumed in per-edge FIFO
//!   order the moment they arrive (any `msg_round`), and a node at
//!   round `r` may proceed once every edge has delivered a message from
//!   round `≥ r − max_staleness`.  Slow edges lag; the node consumes
//!   the freshest dual/parameters it has per neighbor.  `round_end`
//!   *enforces* the staleness bound — consuming an older dual is a
//!   protocol error, not a silent quality loss.
//!
//! The async policy needs the virtual-time engine (`ExecMode::
//! Simulated`); the blocking threaded bus is bulk-synchronous by
//! construction and rejects it.  Every algorithm supports both
//! policies: the single-phase protocols consume per-edge stale state
//! directly, and PowerGossip's interactive multi-phase pipeline runs on
//! per-edge *conversation counters* (agreed at both endpoints by
//! construction, with deferred rank-1 application for conversations
//! that straddle rounds — see `powergossip`'s module docs).

pub mod cecl;
pub mod choco;
pub mod dpsgd;
pub mod lead;
pub mod powergossip;

pub use cecl::{cecl_display_name, rule_for_codec, CEclNode, DualPath,
               DualRule};
pub use choco::ChocoNode;
pub use dpsgd::DPsgdNode;
pub use lead::LeadNode;
pub use powergossip::PowerGossipNode;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::{CodecSpec, WireMode};
use crate::graph::{Graph, TopologyView};
use crate::model::DatasetManifest;
use crate::runtime::ModelRuntime;

/// When a node may finish an exchange round and step: bulk-synchronous
/// (every edge delivers the current round) or gossip-style with
/// bounded per-edge staleness.  See the module docs (`Round policies`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoundPolicy {
    /// Barrier on every edge's round-`r` message (the classic schedule;
    /// trajectory pinned bit-identical across engines).
    #[default]
    Sync,
    /// Event-driven rounds: proceed once every edge has delivered a
    /// message from round `≥ r − max_staleness`.
    Async { max_staleness: usize },
}

/// The full `--rounds` grammar, restated verbatim in every parse error
/// (same convention as `CODEC_GRAMMAR`).
pub const ROUNDS_GRAMMAR: &str =
    "sync | async:<max_staleness>, with max_staleness a round count ≥ 0";

impl RoundPolicy {
    /// Parse the CLI grammar (see [`ROUNDS_GRAMMAR`]).  Every error
    /// names the offending token and restates the grammar.
    pub fn parse(s: &str) -> Result<RoundPolicy, String> {
        let s = s.trim();
        match s {
            "sync" => Ok(RoundPolicy::Sync),
            other => {
                let arg = other.strip_prefix("async:").ok_or_else(|| {
                    format!(
                        "unknown round policy `{other}` \
                         (grammar: {ROUNDS_GRAMMAR})"
                    )
                })?;
                let max_staleness = arg.parse().map_err(|_| {
                    format!(
                        "`{other}`: `{arg}` is not a round count \
                         (grammar: {ROUNDS_GRAMMAR})"
                    )
                })?;
                Ok(RoundPolicy::Async { max_staleness })
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            RoundPolicy::Sync => "sync".to_string(),
            RoundPolicy::Async { max_staleness } => {
                format!("async:{max_staleness}")
            }
        }
    }

    /// The staleness budget in rounds (0 under `Sync`).
    pub fn staleness(&self) -> usize {
        match self {
            RoundPolicy::Sync => 0,
            RoundPolicy::Async { max_staleness } => *max_staleness,
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self, RoundPolicy::Async { .. })
    }
}

/// Per-node algorithm driven by the blocking thread-per-node coordinator.
pub trait NodeAlgorithm: Send {
    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// `α·|N_i|` fed to the Eq. (6) train step (0 for gossip methods).
    fn alpha_deg(&self) -> f32 {
        0.0
    }

    /// `Σ_j A_{i|j} z_{i|j}` fed to the train step, if the algorithm
    /// maintains dual state.
    fn zsum(&self) -> Option<&[f32]> {
        None
    }

    /// Communication phase after the K local updates of round `round`.
    /// May rewrite `w` (gossip averaging) and/or internal dual state.
    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()>;
}

/// Poll-driven view of the same protocols for the virtual-time engine.
///
/// Contract (enforced by `crate::sim`), per-edge-clock form:
///
/// * Every callback receives the engine's current [`TopologyView`] —
///   the epoch-stamped live-edge snapshot that replaces the old fixed
///   neighbor slice.  Machines compare the view's per-edge epochs with
///   their cached ones and run per-edge **lifecycle**: on edge birth,
///   allocate a fresh codec instance and initialize the dual from the
///   node's current primal; on edge death, retire dual/residual/
///   conversation state so it can never be resurrected against a
///   different edge epoch.  A static run keeps the view at version 0,
///   so the lifecycle scan is one integer compare.
/// * `round_begin(r, ..)` is called exactly once per local round, after
///   the K local updates; it queues the round's opening sends (each
///   stamped with `r`, the sender's own edge clock) on every live edge
///   whose `activation_round` has arrived.
/// * `on_message` receives one payload at a time.  `msg_round` is the
///   **sender's** round stamp for that edge, not the receiver's
///   current round: under [`RoundPolicy::Sync`] the engine only
///   delivers `msg_round == r`, under [`RoundPolicy::Async`] a message
///   may arrive for any edge round at any virtual time (behind *or*
///   ahead of the receiver).  Messages from a given neighbor arrive in
///   FIFO order (the engine guarantees per-edge ordering even under
///   random link delays) and therefore with strictly increasing
///   `msg_round`; messages from different neighbors interleave
///   arbitrarily.  Multi-phase protocols may queue further sends from
///   inside `on_message`.  A message on a churned-out edge is a
///   protocol error (the engine drops such frames before they get
///   here).
/// * `round_complete()` reports whether the machine's staleness policy
///   is satisfied for its current round — evaluated over **currently
///   live** edges only; once true, `round_end(r, ..)` runs and may
///   rewrite `w` (gossip averaging).  Machines enforce their staleness
///   bound in `round_end`.
/// * `on_topology` is the engine's mid-round churn notification: the
///   view changed while the node may be waiting on edges that no
///   longer exist.  Machines sync their lifecycle immediately (the
///   engine re-polls `round_complete` right after).  Default: no-op
///   for topology-agnostic machines (SGD).
pub trait NodeStateMachine: Send {
    fn name(&self) -> String;

    fn alpha_deg(&self) -> f32 {
        0.0
    }

    fn zsum(&self) -> Option<&[f32]> {
        None
    }

    /// Begin the exchange phase of `round`: queue the opening sends on
    /// live, activated edges.
    fn round_begin(&mut self, round: usize, view: &TopologyView,
                   w: &mut [f32], out: &mut Outbox) -> Result<()>;

    /// Deliver the next in-FIFO-order message from neighbor `from`,
    /// stamped with the sender's round (`msg_round`).
    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  view: &TopologyView, w: &mut [f32], out: &mut Outbox)
                  -> Result<()>;

    /// Whether the staleness policy is satisfied for the current round
    /// (everything this round still *needs* from live edges has been
    /// received).
    fn round_complete(&self) -> bool;

    /// Finish the round: apply buffered updates to `w` / dual state,
    /// enforcing the staleness bound over live edges.
    fn round_end(&mut self, round: usize, view: &TopologyView,
                 w: &mut [f32]) -> Result<()>;

    /// Topology transition notification (possibly mid-round): sync
    /// per-edge lifecycle against the new view.  `w` is the node's
    /// current primal (edge births warm-start their dual from it);
    /// `out` exists for protocols that must speak on a transition
    /// (none of the current ones do).
    fn on_topology(&mut self, view: &TopologyView, w: &mut [f32],
                   out: &mut Outbox) -> Result<()> {
        let _ = (view, w, out);
        Ok(())
    }

    /// Largest per-edge lag (in rounds) of any *received* message this
    /// machine has consumed at a `round_end` — 0 under `Sync`,
    /// `≤ max_staleness` under `Async` (tests pin the bound).  Start-up
    /// slack on edges that have not spoken yet is not counted.
    fn max_staleness_seen(&self) -> usize {
        0
    }

    /// The round policy this machine was built with, or `None` for
    /// policy-agnostic machines (SGD).  The virtual-time engine asserts
    /// agreement with its own delivery policy at startup, so a machine
    /// built for one policy cannot be driven under another.
    fn policy(&self) -> Option<RoundPolicy> {
        None
    }
}

/// Declarative algorithm selection (what the CLI and experiment drivers
/// construct).
#[derive(Debug, Clone, PartialEq)]
pub enum AlgorithmSpec {
    /// Single-node SGD on all data (the paper's reference row).
    Sgd,
    /// D-PSGD (Lian et al. 2017): gossip averaging with MH weights.
    DPsgd,
    /// ECL (Niwa et al. 2020): uncompressed primal-dual, θ ∈ (0, 1].
    Ecl { theta: f32 },
    /// C-ECL (this paper): rand_k% compression of the dual update.
    CEcl {
        k_frac: f64,
        theta: f32,
        /// Paper §5.1: k = 100% during the first epoch.
        dense_first_epoch: bool,
    },
    /// Ablation: Eq. (11) — compress y directly (§3.2 “does not work”).
    NaiveCEcl { k_frac: f64, theta: f32 },
    /// C-ECL over an arbitrary edge codec (`compress::codec`).  Codecs
    /// that are linear for fixed ω run the Eq. (13) rule; everything
    /// else (top-k, quantizers, error feedback) automatically runs the
    /// Eq. (11) rule.
    CEclCodec {
        codec: CodecSpec,
        theta: f32,
        dense_first_epoch: bool,
    },
    /// PowerGossip (Vogels et al. 2020) with the given power-iteration
    /// steps per round.
    PowerGossip { iters: usize },
    /// CHOCO-SGD (Koloskova et al. 2019): compressed gossip over
    /// per-edge replicas, any edge codec.
    Choco { codec: CodecSpec },
    /// LEAD (Liu et al. 2021): primal-dual compressed-difference
    /// gossip with linear convergence, any edge codec.
    Lead { codec: CodecSpec },
}

impl AlgorithmSpec {
    pub fn name(&self) -> String {
        match self {
            AlgorithmSpec::Sgd => "SGD".to_string(),
            AlgorithmSpec::DPsgd => "D-PSGD".to_string(),
            AlgorithmSpec::Ecl { .. } => "ECL".to_string(),
            AlgorithmSpec::CEcl { k_frac, .. } => {
                format!("C-ECL ({}%)", (*k_frac * 100.0).round() as u32)
            }
            AlgorithmSpec::NaiveCEcl { k_frac, .. } => {
                format!("naive-C-ECL ({}%)", (*k_frac * 100.0).round() as u32)
            }
            AlgorithmSpec::CEclCodec { codec, .. } => {
                // Same rule selection as `build_cecl`, same label as the
                // node itself (one rule function, one naming function).
                cecl_display_name(rule_for_codec(codec), codec)
            }
            AlgorithmSpec::PowerGossip { iters } => {
                format!("PowerGossip ({iters})")
            }
            AlgorithmSpec::Choco { codec } => {
                format!("CHOCO-SGD [{}]", codec.name())
            }
            AlgorithmSpec::Lead { codec } => {
                format!("LEAD [{}]", codec.name())
            }
        }
    }

    /// Whether this algorithm exchanges anything at all.
    pub fn is_decentralized(&self) -> bool {
        !matches!(self, AlgorithmSpec::Sgd)
    }

    /// Whether the algorithm can run under `RoundPolicy::Async`.
    /// Every current algorithm does: the single-phase protocols (and
    /// SGD, trivially) consume stale per-edge state directly, and
    /// PowerGossip runs its multi-phase pipeline on per-edge
    /// conversation counters with deferred rank-1 application.  Kept as
    /// a method so future sync-only protocols slot into the same
    /// table-driver gate.
    pub fn supports_async(&self) -> bool {
        true
    }

    /// Parse CLI names like `cecl:0.1`, `powergossip:10`, `ecl`,
    /// `dpsgd`, `choco:rand_k:0.1`, `lead:qsgd:4` (see
    /// [`ALGORITHM_GRAMMAR`]).  A non-numeric `cecl:` argument parses
    /// as a codec spec (`cecl:qsgd:4`, `cecl:ef+top_k:0.01`,
    /// `cecl:rand_k:0.1:values`).  Every error names the offending
    /// token and restates the grammar, same convention as
    /// `CodecSpec::parse`.
    pub fn parse(s: &str) -> Result<AlgorithmSpec, String> {
        let s = s.trim();
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        let no_arg = |what: &str| {
            format!(
                "`{s}`: {head} takes no argument, got `{what}` \
                 (grammar: {ALGORITHM_GRAMMAR})"
            )
        };
        let codec_arg = |what: &str| -> Result<CodecSpec, String> {
            let a = arg.ok_or_else(|| {
                format!(
                    "`{s}`: {head} needs {what} \
                     (grammar: {ALGORITHM_GRAMMAR})"
                )
            })?;
            CodecSpec::parse(a).map_err(|e| format!("`{s}`: {e}"))
        };
        match head {
            "sgd" => match arg {
                None => Ok(AlgorithmSpec::Sgd),
                Some(a) => Err(no_arg(a)),
            },
            "dpsgd" | "d-psgd" => match arg {
                None => Ok(AlgorithmSpec::DPsgd),
                Some(a) => Err(no_arg(a)),
            },
            "ecl" => {
                let theta = match arg {
                    None => 1.0,
                    Some(a) => {
                        let t: f32 = a.parse().map_err(|_| {
                            format!(
                                "`{s}`: `{a}` is not a θ value \
                                 (grammar: {ALGORITHM_GRAMMAR})"
                            )
                        })?;
                        if !(t.is_finite() && t > 0.0 && t <= 2.0) {
                            return Err(format!(
                                "`{s}`: θ must be in (0, 2], got `{a}` \
                                 (grammar: {ALGORITHM_GRAMMAR})"
                            ));
                        }
                        t
                    }
                };
                Ok(AlgorithmSpec::Ecl { theta })
            }
            "cecl" | "c-ecl" => {
                let arg = arg.ok_or_else(|| {
                    format!(
                        "`{s}`: cecl needs a k fraction or codec spec \
                         (grammar: {ALGORITHM_GRAMMAR})"
                    )
                })?;
                if let Ok(k_frac) = arg.parse::<f64>() {
                    // Degenerate fractions (k = 0, k > 1) are rejected
                    // HERE, like the codec grammar does, instead of
                    // failing deep inside encode.
                    CodecSpec::validate_k_fraction(k_frac)
                        .map_err(|e| format!("`{s}`: {e}"))?;
                    Ok(AlgorithmSpec::CEcl {
                        k_frac,
                        theta: 1.0,
                        dense_first_epoch: true,
                    })
                } else {
                    Ok(AlgorithmSpec::CEclCodec {
                        codec: CodecSpec::parse(arg)
                            .map_err(|e| format!("`{s}`: {e}"))?,
                        theta: 1.0,
                        dense_first_epoch: true,
                    })
                }
            }
            "naive-cecl" => {
                let a = arg.ok_or_else(|| {
                    format!(
                        "`{s}`: naive-cecl needs a k fraction \
                         (grammar: {ALGORITHM_GRAMMAR})"
                    )
                })?;
                let k_frac: f64 = a.parse().map_err(|_| {
                    format!(
                        "`{s}`: `{a}` is not a fraction \
                         (grammar: {ALGORITHM_GRAMMAR})"
                    )
                })?;
                CodecSpec::validate_k_fraction(k_frac)
                    .map_err(|e| format!("`{s}`: {e}"))?;
                Ok(AlgorithmSpec::NaiveCEcl { k_frac, theta: 1.0 })
            }
            "powergossip" | "pg" => {
                let a = arg.ok_or_else(|| {
                    format!(
                        "`{s}`: powergossip needs an iteration count \
                         (grammar: {ALGORITHM_GRAMMAR})"
                    )
                })?;
                let iters: usize = a.parse().map_err(|_| {
                    format!(
                        "`{s}`: `{a}` is not an iteration count \
                         (grammar: {ALGORITHM_GRAMMAR})"
                    )
                })?;
                if iters == 0 {
                    return Err(format!(
                        "`{s}`: powergossip needs ≥ 1 power iteration \
                         (grammar: {ALGORITHM_GRAMMAR})"
                    ));
                }
                Ok(AlgorithmSpec::PowerGossip { iters })
            }
            "choco" | "choco-sgd" => {
                Ok(AlgorithmSpec::Choco { codec: codec_arg("a codec")? })
            }
            "lead" => {
                Ok(AlgorithmSpec::Lead { codec: codec_arg("a codec")? })
            }
            _ => Err(format!(
                "unknown algorithm `{head}` in `{s}` \
                 (grammar: {ALGORITHM_GRAMMAR})"
            )),
        }
    }
}

/// The full `--algorithm` grammar, restated verbatim in every parse
/// error (same convention as `CODEC_GRAMMAR`).
pub const ALGORITHM_GRAMMAR: &str =
    "sgd | dpsgd | ecl[:theta] | cecl:<k_frac|codec> | \
     naive-cecl:<k_frac> | powergossip:<iters> | choco:<codec> | \
     lead:<codec>, with theta in (0, 2], k_frac in (0, 1], iters ≥ 1, \
     and <codec> the --codec grammar";

/// Everything a node algorithm needs at construction time.
pub struct BuildCtx {
    pub node: usize,
    pub graph: Arc<Graph>,
    pub manifest: DatasetManifest,
    pub seed: u64,
    pub eta: f32,
    /// K — local steps between exchanges.
    pub local_steps: usize,
    pub rounds_per_epoch: usize,
    pub dual_path: DualPath,
    pub runtime: Option<Arc<ModelRuntime>>,
    /// Sync vs bounded-staleness async rounds (see module docs).
    pub round_policy: RoundPolicy,
}

/// The paper's α schedule (§D.1): Eq. (46) for the ECL
/// `α = 1 / (η |N_i| (K − 1))` and Eq. (47) for the C-ECL
/// `α = 1 / (η |N_i| (K/τ − 1))` — the compression stretches the
/// effective consensus interval by the Eq. (7) contraction τ (τ = k for
/// the paper's `rand_k%`; other codecs plug in their own τ).
pub fn paper_alpha(eta: f32, degree: usize, local_steps: usize,
                   tau: f64) -> f32 {
    let k_eff = local_steps as f64 / tau.clamp(1e-6, 1.0);
    let denom = eta as f64 * degree as f64 * (k_eff - 1.0).max(1e-6);
    (1.0 / denom) as f32
}

/// The wire codec for a `k_frac`-style spec: the paper's explicit-index
/// rand-k accounting (8 B per kept coordinate).
fn rand_k_codec(k_frac: f64) -> CodecSpec {
    CodecSpec::RandK {
        k_frac,
        mode: WireMode::Explicit,
    }
}

fn build_cecl(spec: &AlgorithmSpec, ctx: &BuildCtx) -> Result<CEclNode> {
    match spec {
        AlgorithmSpec::Ecl { theta } => CEclNode::new(
            ctx,
            rand_k_codec(1.0),
            *theta,
            0,
            DualRule::CompressDiff,
        ),
        AlgorithmSpec::CEcl {
            k_frac,
            theta,
            dense_first_epoch,
        } => {
            let dense_rounds = if *dense_first_epoch {
                ctx.rounds_per_epoch
            } else {
                0
            };
            CEclNode::new(
                ctx,
                rand_k_codec(*k_frac),
                *theta,
                dense_rounds,
                DualRule::CompressDiff,
            )
        }
        AlgorithmSpec::NaiveCEcl { k_frac, theta } => CEclNode::new(
            ctx,
            rand_k_codec(*k_frac),
            *theta,
            0,
            DualRule::CompressY,
        ),
        AlgorithmSpec::CEclCodec {
            codec,
            theta,
            dense_first_epoch,
        } => {
            let dense_rounds = if *dense_first_epoch {
                ctx.rounds_per_epoch
            } else {
                0
            };
            // Eq. (13) needs fixed-ω linearity; everything else runs
            // the naive Eq. (11) rule.
            CEclNode::new(ctx, codec.clone(), *theta, dense_rounds,
                          rule_for_codec(codec))
        }
        other => bail!("{} is not a C-ECL-family spec", other.name()),
    }
}

/// Build the per-node protocol for the blocking (threaded) engine.
pub fn build_node(spec: &AlgorithmSpec,
                  ctx: &BuildCtx) -> Result<Box<dyn NodeAlgorithm>> {
    Ok(match spec {
        AlgorithmSpec::Sgd => Box::new(SgdNode),
        AlgorithmSpec::DPsgd => Box::new(DPsgdNode::new(ctx)),
        AlgorithmSpec::PowerGossip { iters } => {
            Box::new(PowerGossipNode::new(ctx, *iters)?)
        }
        AlgorithmSpec::Choco { codec } => {
            Box::new(ChocoNode::new(ctx, codec.clone())?)
        }
        AlgorithmSpec::Lead { codec } => {
            Box::new(LeadNode::new(ctx, codec.clone())?)
        }
        other => Box::new(build_cecl(other, ctx)?),
    })
}

/// Build the same protocol as a poll-driven state machine for the
/// virtual-time engine.  Compressed duals always run the native fused
/// path here (the PJRT kernel path is a threaded-engine option).
pub fn build_machine(spec: &AlgorithmSpec,
                     ctx: &BuildCtx) -> Result<Box<dyn NodeStateMachine>> {
    Ok(match spec {
        AlgorithmSpec::Sgd => Box::new(SgdNode),
        AlgorithmSpec::DPsgd => Box::new(DPsgdNode::new(ctx)),
        AlgorithmSpec::PowerGossip { iters } => {
            Box::new(PowerGossipNode::new(ctx, *iters)?)
        }
        AlgorithmSpec::Choco { codec } => {
            Box::new(ChocoNode::new(ctx, codec.clone())?)
        }
        AlgorithmSpec::Lead { codec } => {
            Box::new(LeadNode::new(ctx, codec.clone())?)
        }
        other => Box::new(build_cecl(other, ctx)?),
    })
}

/// Blocking driver for single-phase state machines over the threaded
/// bus: queue the round's sends, drain exactly one message per sorted
/// neighbor, finish the round.  (Multi-phase protocols like PowerGossip
/// need their own drain loop.)  The threaded bus is bulk-synchronous by
/// construction — every received message carries the current round, so
/// the per-edge `msg_round` stamp is `round` itself — and
/// epoch-constant: it always drives the static full [`TopologyView`].
pub fn drive_blocking(
    machine: &mut dyn NodeStateMachine,
    neighbors: &[usize],
    view: &TopologyView,
    round: usize,
    w: &mut [f32],
    comm: &NodeComm,
) -> Result<()> {
    let mut out = Outbox::new();
    machine.round_begin(round, view, w, &mut out)?;
    for (to, msg) in out.drain() {
        comm.send(to, msg)?;
    }
    for &j in neighbors {
        let msg = comm.recv(j)?;
        machine.on_message(round, j, msg, view, w, &mut out)?;
    }
    machine.round_end(round, view, w)
}

/// One edge's per-machine clock: the freshest round stamp consumed on
/// the edge this incarnation, the incarnation's activation round, and
/// the liveness/spoken flags the staleness machinery keys on.  Dead
/// edges never gate; edges that have not spoken yet gate through their
/// birth floor (`activation − 1` — the same `−1` start-up slack the
/// static protocol always had, shifted to the incarnation's origin).
#[derive(Debug, Clone, Copy)]
pub(crate) struct EdgeClock {
    /// Freshest round stamp delivered this incarnation, or
    /// `activation − 1` as the birth floor before anything arrives.
    pub round: i64,
    /// First round this incarnation carries traffic.
    pub activation: usize,
    /// Whether the edge is currently in the topology.
    pub live: bool,
    /// Whether `round` reflects a real received message (birth slack is
    /// never counted as lag).
    pub spoken: bool,
}

impl EdgeClock {
    /// A freshly (re)born live edge activating at `activation`.
    pub fn born(activation: usize) -> EdgeClock {
        EdgeClock {
            round: activation as i64 - 1,
            activation,
            live: true,
            spoken: false,
        }
    }

    /// Whether the edge carries traffic at `round` (live + activated).
    pub fn active(&self, round: usize) -> bool {
        self.live && round >= self.activation
    }
}

/// Shared per-edge-clock admission check for single-phase machines:
/// under `Sync` a message must carry exactly the receiver's current
/// round and be the first from its edge this round; under `Async`
/// per-edge FIFO means stamps are strictly increasing, anything else
/// (duplicate, reordering) is a transport bug.  Returns an error with
/// the node/peer/rounds spelled out.
pub(crate) fn admit_message(policy: RoundPolicy, node: usize, from: usize,
                            cur_round: usize, edge_round: i64,
                            msg_round: usize) -> Result<()> {
    match policy {
        RoundPolicy::Sync => {
            anyhow::ensure!(
                msg_round == cur_round,
                "node {node}: sync round {cur_round} got a round-{msg_round} \
                 message from {from}"
            );
            anyhow::ensure!(
                edge_round < msg_round as i64,
                "node {node}: duplicate round-{msg_round} message from {from}"
            );
        }
        RoundPolicy::Async { .. } => {
            anyhow::ensure!(
                (msg_round as i64) > edge_round,
                "node {node}: per-edge FIFO violated — round-{msg_round} \
                 message from {from} after round {edge_round}"
            );
        }
    }
    Ok(())
}

/// Shared `round_complete` gate: every **live** edge has delivered
/// state from round `≥ cur_round − staleness` (birth floor =
/// `activation − 1` before the first message).  Dead edges are
/// excluded — the staleness bound is a promise about the current
/// topology, not about peers that no longer exist.
pub(crate) fn staleness_gate(policy: RoundPolicy, cur_round: usize,
                             clocks: &[EdgeClock]) -> bool {
    let horizon = cur_round as i64 - policy.staleness() as i64;
    clocks.iter().filter(|c| c.live).all(|c| c.round >= horizon)
}

/// Shared `round_end` enforcement of the staleness bound over live
/// edges: errors if any live edge's freshest `what` (dual /
/// parameters) is older than the policy allows, and returns the
/// largest lag among *received* messages (birth/start-up slack on
/// edges that have not spoken this incarnation is not counted — see
/// [`NodeStateMachine::max_staleness_seen`]).
pub(crate) fn check_staleness(policy: RoundPolicy, node: usize,
                              what: &str, round: usize,
                              clocks: &[EdgeClock]) -> Result<usize> {
    let horizon = round as i64 - policy.staleness() as i64;
    let mut max_lag = 0usize;
    for (jj, c) in clocks.iter().enumerate() {
        if !c.live {
            continue;
        }
        anyhow::ensure!(
            c.round >= horizon,
            "node {node}: round_end({round}) would consume round-{} {what} \
             from neighbor slot {jj} (policy {})",
            c.round,
            policy.name()
        );
        if c.spoken {
            max_lag = max_lag.max((round as i64 - c.round).max(0) as usize);
        }
    }
    Ok(max_lag)
}

/// Single-node SGD: no neighbors, no exchange, `alpha_deg = 0`.
pub struct SgdNode;

impl NodeAlgorithm for SgdNode {
    fn name(&self) -> String {
        "SGD".to_string()
    }

    fn exchange(&mut self, _round: usize, _w: &mut [f32], _comm: &NodeComm)
                -> Result<()> {
        Ok(())
    }
}

impl NodeStateMachine for SgdNode {
    fn name(&self) -> String {
        "SGD".to_string()
    }

    fn round_begin(&mut self, _round: usize, _view: &TopologyView,
                   _w: &mut [f32], _out: &mut Outbox) -> Result<()> {
        Ok(())
    }

    fn on_message(&mut self, msg_round: usize, from: usize, _msg: Msg,
                  _view: &TopologyView, _w: &mut [f32],
                  _out: &mut Outbox) -> Result<()> {
        anyhow::bail!(
            "SGD node received a message from {from} stamped round {msg_round}"
        )
    }

    fn round_complete(&self) -> bool {
        true
    }

    fn round_end(&mut self, _round: usize, _view: &TopologyView,
                 _w: &mut [f32]) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(AlgorithmSpec::parse("sgd"), Ok(AlgorithmSpec::Sgd));
        assert_eq!(AlgorithmSpec::parse("dpsgd"), Ok(AlgorithmSpec::DPsgd));
        assert_eq!(
            AlgorithmSpec::parse("ecl"),
            Ok(AlgorithmSpec::Ecl { theta: 1.0 })
        );
        assert_eq!(
            AlgorithmSpec::parse("ecl:0.5"),
            Ok(AlgorithmSpec::Ecl { theta: 0.5 })
        );
        assert_eq!(
            AlgorithmSpec::parse("cecl:0.1"),
            Ok(AlgorithmSpec::CEcl {
                k_frac: 0.1,
                theta: 1.0,
                dense_first_epoch: true
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("powergossip:10"),
            Ok(AlgorithmSpec::PowerGossip { iters: 10 })
        );
        assert!(AlgorithmSpec::parse("cecl").is_err());
        assert!(AlgorithmSpec::parse("bogus").is_err());
    }

    #[test]
    fn parse_errors_restate_the_grammar() {
        // The headline bug this suite pins: `ecl:<garbage>` used to
        // fall back silently to θ = 1.0.
        for bad in ["ecl:garbage", "ecl:0", "ecl:2.5", "ecl:nan", "cecl",
                    "bogus", "choco", "choco:nope:1", "lead:qsgd:99",
                    "sgd:1", "dpsgd:x", "powergossip:x", "naive-cecl:x"] {
            let err = AlgorithmSpec::parse(bad).unwrap_err();
            assert!(err.contains("grammar"), "`{bad}` -> {err}");
        }
        // Codec errors propagate the codec grammar, algorithm errors
        // the algorithm grammar — both name the offending spec.
        let err = AlgorithmSpec::parse("choco:nope:1").unwrap_err();
        assert!(err.contains("choco:nope:1") && err.contains("nope"),
                "{err}");
        let err = AlgorithmSpec::parse("ecl:garbage").unwrap_err();
        assert!(err.contains("ecl:garbage") && err.contains("θ"), "{err}");
    }

    #[test]
    fn choco_and_lead_parse_via_the_codec_grammar() {
        assert_eq!(
            AlgorithmSpec::parse("choco:rand_k:0.1"),
            Ok(AlgorithmSpec::Choco {
                codec: CodecSpec::RandK {
                    k_frac: 0.1,
                    mode: WireMode::Explicit,
                }
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("choco:qsgd:4"),
            Ok(AlgorithmSpec::Choco {
                codec: CodecSpec::Qsgd { bits: 4 }
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("lead:ef+top_k:0.01"),
            Ok(AlgorithmSpec::Lead {
                codec: CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK {
                    k_frac: 0.01,
                })),
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("choco:identity").unwrap().name(),
            "CHOCO-SGD [identity]"
        );
        assert_eq!(
            AlgorithmSpec::parse("lead:qsgd:4").unwrap().name(),
            "LEAD [qsgd 4b]"
        );
    }

    #[test]
    fn spec_parsing_codec_forms() {
        assert_eq!(
            AlgorithmSpec::parse("cecl:qsgd:4"),
            Ok(AlgorithmSpec::CEclCodec {
                codec: CodecSpec::Qsgd { bits: 4 },
                theta: 1.0,
                dense_first_epoch: true,
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("cecl:ef+top_k:0.01"),
            Ok(AlgorithmSpec::CEclCodec {
                codec: CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK {
                    k_frac: 0.01,
                })),
                theta: 1.0,
                dense_first_epoch: true,
            })
        );
        // Numeric arguments stay on the paper's rand-k path.
        assert!(matches!(
            AlgorithmSpec::parse("cecl:0.2"),
            Ok(AlgorithmSpec::CEcl { .. })
        ));
        // Broken codec specs do not parse.
        assert!(AlgorithmSpec::parse("cecl:qsgd:99").is_err());
        assert!(AlgorithmSpec::parse("cecl:nope:1").is_err());
        // Names mark the Eq. 11 fallback for non-linear codecs.
        assert_eq!(
            AlgorithmSpec::parse("cecl:qsgd:4").unwrap().name(),
            "C-ECL [qsgd 4b] (Eq.11)"
        );
        assert_eq!(
            AlgorithmSpec::parse("cecl:rand_k:0.1:values").unwrap().name(),
            "C-ECL [rand_k 10% vo]"
        );
        // PowerGossip-as-a-codec rides the same spelling.
        assert_eq!(
            AlgorithmSpec::parse("cecl:low_rank:2"),
            Ok(AlgorithmSpec::CEclCodec {
                codec: CodecSpec::LowRank { rank: 2, iters: 1 },
                theta: 1.0,
                dense_first_epoch: true,
            })
        );
        assert_eq!(
            AlgorithmSpec::parse("cecl:low_rank:2").unwrap().name(),
            "C-ECL [low_rank r2] (Eq.11)"
        );
    }

    #[test]
    fn spec_names_match_paper_rows() {
        assert_eq!(
            AlgorithmSpec::CEcl {
                k_frac: 0.01,
                theta: 1.0,
                dense_first_epoch: true
            }
            .name(),
            "C-ECL (1%)"
        );
        assert_eq!(
            AlgorithmSpec::PowerGossip { iters: 20 }.name(),
            "PowerGossip (20)"
        );
    }

    #[test]
    fn paper_alpha_eq46_eq47() {
        // Eq. (46): η=0.01, |N|=2, K=5 → α = 1/(0.01*2*4) = 12.5.
        let a = paper_alpha(0.01, 2, 5, 1.0);
        assert!((a - 12.5).abs() < 1e-4);
        // Eq. (47): k=10% → K_eff = 50 → α = 1/(0.01*2*49).
        let a = paper_alpha(0.01, 2, 5, 0.1);
        assert!((a - 1.0 / (0.01 * 2.0 * 49.0)).abs() < 1e-4);
        // More compression (smaller k) → smaller α.
        assert!(paper_alpha(0.01, 2, 5, 0.01) < paper_alpha(0.01, 2, 5, 0.1));
    }

    #[test]
    fn round_policy_parse_and_names() {
        assert_eq!(RoundPolicy::parse("sync"), Ok(RoundPolicy::Sync));
        assert_eq!(
            RoundPolicy::parse("async:3"),
            Ok(RoundPolicy::Async { max_staleness: 3 })
        );
        assert_eq!(
            RoundPolicy::parse("async:0"),
            Ok(RoundPolicy::Async { max_staleness: 0 })
        );
        for bad in ["async", "async:x", "async:-1", "gossip"] {
            let err = RoundPolicy::parse(bad).unwrap_err();
            assert!(err.contains("grammar"), "`{bad}` -> {err}");
        }
        assert_eq!(RoundPolicy::Sync.name(), "sync");
        assert_eq!(RoundPolicy::Async { max_staleness: 2 }.name(), "async:2");
        assert_eq!(RoundPolicy::Sync.staleness(), 0);
        assert_eq!(RoundPolicy::Async { max_staleness: 5 }.staleness(), 5);
        assert!(!RoundPolicy::Sync.is_async());
        assert_eq!(RoundPolicy::default(), RoundPolicy::Sync);
    }

    #[test]
    fn async_support_matrix() {
        assert!(AlgorithmSpec::Sgd.supports_async());
        assert!(AlgorithmSpec::DPsgd.supports_async());
        assert!(AlgorithmSpec::Ecl { theta: 1.0 }.supports_async());
        assert!(AlgorithmSpec::parse("cecl:0.1").unwrap().supports_async());
        assert!(AlgorithmSpec::parse("cecl:qsgd:4").unwrap().supports_async());
        // Conversation counters lifted PowerGossip's sync-only pin.
        assert!(AlgorithmSpec::PowerGossip { iters: 4 }.supports_async());
        // The compressed-gossip rivals ride the same per-edge clocks.
        assert!(AlgorithmSpec::parse("choco:rand_k:0.1")
            .unwrap()
            .supports_async());
        assert!(AlgorithmSpec::parse("lead:qsgd:4")
            .unwrap()
            .supports_async());
    }

    #[test]
    fn degenerate_numeric_specs_rejected_at_parse_time() {
        // The numeric `cecl:K` spellings share the codec grammar's
        // (0, 1] domain; `powergossip:0` has no zeroth power iteration.
        for bad in ["cecl:0", "cecl:0.0", "cecl:1.5", "cecl:-0.1",
                    "naive-cecl:0", "naive-cecl:2", "powergossip:0",
                    "pg:0"] {
            assert!(AlgorithmSpec::parse(bad).is_err(), "`{bad}` must fail");
        }
        // The boundary k = 1 (ECL) stays legal.
        assert!(AlgorithmSpec::parse("cecl:1").is_ok());
        assert!(AlgorithmSpec::parse("powergossip:1").is_ok());
    }

    #[test]
    fn sgd_state_machine_is_trivially_complete() {
        let mut sgd = SgdNode;
        let mut out = Outbox::new();
        let mut w = vec![0.0f32; 4];
        let view = TopologyView::full(0);
        sgd.round_begin(0, &view, &mut w, &mut out).unwrap();
        assert!(out.is_empty());
        assert!(NodeStateMachine::round_complete(&sgd));
        sgd.round_end(0, &view, &mut w).unwrap();
        // Topology notifications are a no-op for edge-free machines.
        NodeStateMachine::on_topology(&mut sgd, &view, &mut w, &mut out)
            .unwrap();
        assert!(sgd
            .on_message(0, 1, Msg::Scalar(0.0), &view, &mut w, &mut out)
            .is_err());
    }

    #[test]
    fn edge_clock_birth_floor_and_gating() {
        // A fresh incarnation gates through activation − 1 and is not
        // counted as lag until it actually speaks.
        let born = EdgeClock::born(5);
        assert_eq!(born.round, 4);
        assert!(born.live && !born.spoken);
        assert!(!born.active(4));
        assert!(born.active(5));
        let initial = EdgeClock::born(0);
        assert_eq!(initial.round, -1); // the legacy start-up slack
        let dead = EdgeClock { live: false, ..born };
        // Dead edges never gate or error, however stale.
        let clocks = [dead];
        assert!(staleness_gate(RoundPolicy::Sync, 100, &clocks));
        assert_eq!(
            check_staleness(RoundPolicy::Sync, 0, "dual", 100, &clocks)
                .unwrap(),
            0
        );
        // A live birth floor gates its own activation round under sync…
        let clocks = [born];
        assert!(staleness_gate(RoundPolicy::Sync, 4, &clocks));
        assert!(!staleness_gate(RoundPolicy::Sync, 5, &clocks));
        // …and unspoken floors are never reported as lag.
        let spoken = EdgeClock { round: 3, spoken: true, ..born };
        let lag = check_staleness(
            RoundPolicy::Async { max_staleness: 2 },
            0,
            "dual",
            5,
            &[spoken],
        )
        .unwrap();
        assert_eq!(lag, 2);
    }
}
