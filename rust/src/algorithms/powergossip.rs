//! PowerGossip (Vogels, Karimireddy, Jaggi 2020): the compressed Gossip
//! baseline of the paper's tables.
//!
//! Per round and per layer matrix, each edge approximates the model
//! *difference* `D = M_lo − M_hi` by rank-1 power iteration with a
//! warm-started direction `q̂` that both endpoints keep in lockstep (same
//! derived seed, same deterministic updates — the low-rank analogue of
//! the C-ECL shared-mask trick).  One “power iteration step” exchanges
//! `p = M q̂` (rows floats) and `s = Mᵀ p̂` (cols floats) in each
//! direction; after the configured number of steps the rank-1 correction
//! `±W_ij · p q̂ᵀ` is applied gossip-style.  Rank-1 tensors (biases, GN
//! scales) are exchanged dense — they are a rounding error of the byte
//! budget.
//!
//! ## Conversations: the per-edge clock
//!
//! The multi-phase exchange is organized as per-edge **conversations**.
//! Conversation `c` on an edge is the power-iteration exchange both
//! endpoints start at their own local round `c`; each endpoint starts
//! exactly one conversation per edge per round, so the conversation
//! counters agree at both ends by construction — no negotiation, no
//! extra wire traffic.  All per-conversation derived randomness (the
//! degenerate-collapse q̂ reseed) keys off the **conversation counter**,
//! never off a message's round stamp: under async rounds the two
//! endpoints may sit at different rounds while speaking, but the
//! conversation sequence — and therefore the warm-started q̂ lockstep —
//! is identical on both sides.
//!
//! * Under [`RoundPolicy::Sync`] conversation `c` runs entirely inside
//!   round `c` (the counter *equals* the round), every round completes
//!   every edge, and the trajectory is bit-identical to the classic
//!   lockstep schedule (pinned by the engine-equivalence tests).
//! * Under [`RoundPolicy::Async`] a slow edge's conversation may
//!   straddle local rounds: the node keeps stepping while the
//!   conversation is in flight, queues at most one pending start per
//!   elapsed round, and buffers an ahead-running peer's opening halves
//!   until it starts that conversation itself.  Completed conversations
//!   park their rank-1 corrections until the next `round_end`
//!   (**deferred application** — `w` is only rewritten at round
//!   boundaries, exactly like the sync schedule), and `round_end`
//!   enforces the staleness bound on the per-edge conversation clock
//!   the same way C-ECL/D-PSGD enforce it on their dual/parameter
//!   clocks.
//!
//! The blocking [`NodeAlgorithm::exchange`] drives the same machine
//! edge-by-edge (sync only — the threaded bus is bulk-synchronous).
//!
//! Wire cost per round per neighbor:
//! `iters · Σ_matrices (rows + cols) · 4  +  Σ_vectors len · 4` bytes,
//! which reproduces the paper's PowerGossip(1/10/20) ratio ladder and
//! is byte-identical to the `low_rank:R` edge codec at `R = iters`
//! (pinned by tests).

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::low_rank::{
    matvec_f32, matvec_t_f32, normalize, power_iteration_step, rank1_axpy,
    LowRankEdgeState,
};
use crate::graph::{Graph, TopologyView};
use crate::util::rng::{streams, Pcg};

use super::{BuildCtx, EdgeClock, NodeAlgorithm, NodeStateMachine,
            RoundPolicy};

/// Where one conversation stands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
enum PgPhase {
    /// Receiving the peer's `p = M q̂` halves (one per matrix view).
    #[default]
    P,
    /// Receiving the peer's `s = Mᵀ p̂` halves.
    S,
    /// Receiving the peer's dense rank-1-tensor payload.
    Vectors,
}

/// One in-flight conversation (multi-phase power-iteration exchange) on
/// one edge.  `conv` is the per-edge conversation counter both
/// endpoints agree on by construction (see the module docs).
#[derive(Debug, Default)]
struct PgConv {
    conv: usize,
    /// Power-iteration index within the conversation.
    it: usize,
    phase: PgPhase,
    /// Messages received so far in the current phase.
    recv_count: usize,
    /// Our halves for the current iteration, one per matrix view.
    p_self: Vec<Vec<f32>>,
    p_peer: Vec<Vec<f32>>,
    s_self: Vec<Vec<f32>>,
    /// `(p, q̂_used)` per view, captured on the last iteration, consumed
    /// at the applying `round_end`.
    finals: Vec<(Vec<f32>, Vec<f32>)>,
    /// Our rank-1-tensor snapshot, taken when the conversation started.
    vec_payload: Vec<f32>,
    vec_recv: Option<Vec<f32>>,
}

/// Per-edge machine state: the active conversation, queued starts,
/// completed-but-unapplied conversations, and the peer-ahead buffer —
/// plus the incarnation bookkeeping (`offset`/`epoch`/`live`) that maps
/// conversation numbers onto local rounds under dynamic topology.
#[derive(Debug)]
struct PgEdge {
    active: Option<PgConv>,
    /// Local rounds whose conversation could not start yet because the
    /// previous one is still in flight (async only; sync never queues).
    pending_starts: usize,
    /// Index of the next conversation to start locally (== local rounds
    /// begun on this edge this incarnation).
    next_conv: usize,
    /// Latest conversation COMPLETED on this edge this incarnation
    /// (−1 = none): the per-edge clock the staleness policy gates on.
    last_completed: i64,
    /// Completed conversations awaiting their applying `round_end`
    /// (deferred rank-1 application for round-straddling conversations).
    done: Vec<PgConv>,
    /// Peer payloads for a conversation we have not started ourselves
    /// yet (the peer ran ahead); drained the moment it starts.
    inbuf: VecDeque<Vec<f32>>,
    /// Round ↔ conversation offset of this incarnation: conversation
    /// `c` belongs to local round `offset + c`.  0 for the initial
    /// incarnation (conversation == round, the legacy schedule); a
    /// reborn edge starts counting at its activation round.
    offset: usize,
    /// Cached incarnation epoch (`EdgeLife::epoch`).
    epoch: u32,
    /// Whether the edge is currently in the topology.
    live: bool,
}

impl PgEdge {
    fn new(offset: usize, epoch: u32) -> PgEdge {
        PgEdge {
            active: None,
            pending_starts: 0,
            next_conv: 0,
            // −1: no conversation has completed yet — start-up slack,
            // exactly like C-ECL's per-edge dual clock.
            last_completed: -1,
            done: Vec::new(),
            inbuf: VecDeque::new(),
            offset,
            epoch,
            live: true,
        }
    }

    /// The staleness clock of this edge, in round units.
    fn clock(&self) -> EdgeClock {
        EdgeClock {
            round: if self.last_completed < 0 {
                self.offset as i64 - 1
            } else {
                self.offset as i64 + self.last_completed
            },
            activation: self.offset,
            live: self.live,
            spoken: self.last_completed >= 0,
        }
    }
}

pub struct PowerGossipNode {
    node: usize,
    graph: Arc<Graph>,
    iters: usize,
    /// MH weight row.
    weights: Vec<f64>,
    /// `(offset, rows, cols)` per layer matrix.
    views: Vec<(usize, usize, usize)>,
    /// `(offset, len)` per rank-1 tensor.
    vec_views: Vec<(usize, usize)>,
    /// Warm-started q̂ per (neighbor slot, view).
    states: Vec<Vec<LowRankEdgeState>>,
    seed: u64,
    policy: RoundPolicy,
    /// The node's own round clock (set by `round_begin`).
    cur_round: usize,
    edges: Vec<PgEdge>,
    /// Last `TopologyView::version` synced against.
    seen_view: u64,
    /// Cached static full view for the (epoch-constant) blocking
    /// engine — built once instead of per exchange round.
    full_view: Arc<TopologyView>,
    /// Largest conversation lag consumed at any `round_end`.
    max_lag_seen: usize,
}

impl PowerGossipNode {
    pub fn new(ctx: &BuildCtx, iters: usize) -> Result<PowerGossipNode> {
        ensure!(iters >= 1, "PowerGossip needs at least one iteration");
        let views: Vec<(usize, usize, usize)> = ctx
            .manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vec_views: Vec<(usize, usize)> = ctx
            .manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        let neighbors = ctx.graph.neighbors(ctx.node);
        // q̂ init must be identical at both edge endpoints: derive from
        // (seed, POWER, edge, view) — plus the incarnation epoch for
        // reborn edges (epoch 0 keeps the legacy stream).
        let states = neighbors
            .iter()
            .map(|&j| {
                let e = ctx.graph.edge_index(ctx.node, j).unwrap() as u64;
                Self::derive_states(ctx.seed, e, 0, &views)
            })
            .collect();
        let edges = neighbors.iter().map(|_| PgEdge::new(0, 0)).collect();
        Ok(PowerGossipNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            iters,
            weights: ctx.graph.mh_weights()[ctx.node].clone(),
            views,
            vec_views,
            states,
            seed: ctx.seed,
            policy: ctx.round_policy,
            cur_round: 0,
            edges,
            seen_view: 0,
            full_view: Arc::new(TopologyView::full(
                ctx.graph.edges().len(),
            )),
            max_lag_seen: 0,
        })
    }

    /// Shared-seed q̂ warm-start vectors for one edge incarnation —
    /// identical at both endpoints by construction.
    fn derive_states(seed: u64, edge: u64, epoch: u32,
                     views: &[(usize, usize, usize)])
                     -> Vec<LowRankEdgeState> {
        views
            .iter()
            .enumerate()
            .map(|(v, &(_, _, cols))| {
                let mut path = vec![streams::POWER, edge, v as u64];
                if epoch > 0 {
                    path.push(epoch as u64);
                }
                let mut rng = Pcg::derive(seed, &path);
                LowRankEdgeState::new(cols, &mut rng)
            })
            .collect()
    }

    /// Per-edge lifecycle sync: a fresh incarnation (view epoch ahead
    /// of the cached one) resets the whole per-edge machine — the
    /// in-flight conversation, its buffered halves, and the *unapplied*
    /// completed conversations are retired (typed teardown: nothing
    /// from an old epoch can be applied or resumed), the q̂ warm starts
    /// re-derive from the epoch-keyed shared stream, and the
    /// conversation counter restarts at the incarnation's activation
    /// round (`offset`).  A death without rebirth just tears down.
    fn sync_view(&mut self, view: &TopologyView) -> Result<()> {
        if view.version() == self.seen_view {
            return Ok(());
        }
        self.seen_view = view.version();
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            let e = self
                .graph
                .edge_index(self.node, j)
                .ok_or_else(|| anyhow!("({}, {j}) is not an edge", self.node))?;
            let life = view.edge_life(e);
            if life.epoch != self.edges[jj].epoch {
                // Rebirth: a wholly fresh conversation machine.
                let mut edge =
                    PgEdge::new(life.activation_round, life.epoch);
                edge.live = life.live;
                self.edges[jj] = edge;
                self.states[jj] = Self::derive_states(
                    self.seed, e as u64, life.epoch, &self.views,
                );
            } else if life.live != self.edges[jj].live {
                self.edges[jj].live = life.live;
                if !life.live {
                    // Teardown: drop the in-flight conversation, its
                    // buffered peer halves, and any completed-but-
                    // unapplied corrections.
                    self.edges[jj].active = None;
                    self.edges[jj].pending_starts = 0;
                    self.edges[jj].done.clear();
                    self.edges[jj].inbuf.clear();
                }
            }
        }
        Ok(())
    }

    fn clocks(&self) -> Vec<EdgeClock> {
        self.edges.iter().map(|e| e.clock()).collect()
    }

    /// Deterministic wire bytes per round (for accounting tests).
    pub fn bytes_per_round_per_neighbor(&self) -> usize {
        let mat: usize = self
            .views
            .iter()
            .map(|&(_, r, c)| (r + c) * 4)
            .sum::<usize>()
            * self.iters;
        let vecs: usize = self.vec_views.iter().map(|&(_, l)| l * 4).sum();
        mat + vecs
    }

    /// `p = M q̂` for every matrix view on edge slot `jj`.
    fn p_halves(&self, jj: usize, w: &[f32]) -> Vec<Vec<f32>> {
        self.views
            .iter()
            .enumerate()
            .map(|(v, &(off, rows, cols))| {
                matvec_f32(&w[off..off + rows * cols], rows, cols,
                           &self.states[jj][v].q_hat)
            })
            .collect()
    }

    fn neighbor_slot(&self, from: usize) -> Result<usize> {
        self.graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })
    }

    /// Start the next conversation on edge slot `jj` (neighbor `j`):
    /// snapshot the rank-1 tensors and queue the opening `p` halves.
    /// Degenerate models (no matrix views) go straight to the dense
    /// vector exchange, or complete instantly when there is nothing to
    /// exchange at all.
    fn start_conversation(&mut self, jj: usize, j: usize, w: &[f32],
                          out: &mut Outbox) {
        let nv = self.views.len();
        let conv = self.edges[jj].next_conv;
        self.edges[jj].next_conv += 1;
        let mut vec_payload = Vec::new();
        for &(off, len) in &self.vec_views {
            vec_payload.extend_from_slice(&w[off..off + len]);
        }
        let mut run = PgConv {
            conv,
            it: 0,
            phase: PgPhase::P,
            recv_count: 0,
            p_self: Vec::new(),
            p_peer: vec![Vec::new(); nv],
            s_self: Vec::new(),
            finals: Vec::with_capacity(nv),
            vec_payload,
            vec_recv: None,
        };
        if nv == 0 {
            if self.vec_views.is_empty() {
                // Nothing on the wire: the conversation completes on
                // the spot.
                self.edges[jj].last_completed = conv as i64;
                self.edges[jj].done.push(run);
                return;
            }
            out.send(j, Msg::Dense(run.vec_payload.clone()));
            run.phase = PgPhase::Vectors;
        } else {
            let ps = self.p_halves(jj, w);
            for p in &ps {
                out.send(j, Msg::Dense(p.clone()));
            }
            run.p_self = ps;
        }
        self.edges[jj].active = Some(run);
    }

    /// Pump edge slot `jj`: feed buffered peer payloads into the active
    /// conversation, start queued conversations as their predecessors
    /// complete, and hold payloads for conversations the peer started
    /// before we did.
    fn drain_edge(&mut self, jj: usize, j: usize, w: &mut [f32],
                  out: &mut Outbox) -> Result<()> {
        loop {
            if self.edges[jj].active.is_none() {
                if self.edges[jj].pending_starts > 0 {
                    self.edges[jj].pending_starts -= 1;
                    self.start_conversation(jj, j, w, out);
                    continue; // instant completions loop back here
                }
                // The peer ran ahead: its opening halves wait in
                // `inbuf` until our own round starts the conversation.
                return Ok(());
            }
            let Some(payload) = self.edges[jj].inbuf.pop_front() else {
                return Ok(());
            };
            self.feed(jj, j, payload, w, out)?;
        }
    }

    /// Deliver one peer payload to the active conversation on edge slot
    /// `jj`.
    fn feed(&mut self, jj: usize, from: usize, payload: Vec<f32>,
            w: &mut [f32], out: &mut Outbox) -> Result<()> {
        let nv = self.views.len();
        // Take the conversation out of the slot: everything below works
        // on a local value, so the phase logic can call `&self` helpers
        // without fighting the borrow of `self.edges`.
        let mut run = self.edges[jj]
            .active
            .take()
            .ok_or_else(|| {
                anyhow!(
                    "PowerGossip node {}: payload from {from} with no \
                     active conversation",
                    self.node
                )
            })?;
        let mut completed = false;
        match run.phase {
            PgPhase::P => {
                let v = run.recv_count;
                ensure!(v < nv, "p-phase overflow from {from}");
                ensure!(
                    payload.len() == self.views[v].1,
                    "p half for view {v}: len {} != rows {}",
                    payload.len(),
                    self.views[v].1
                );
                run.p_peer[v] = payload;
                run.recv_count += 1;
                if run.recv_count == nv {
                    // All p halves in: compute p̂ and answer with our s
                    // halves.
                    let lo_is_self = self.node < from;
                    let mut s_selfs = Vec::with_capacity(nv);
                    for (v, &(off, rows, cols)) in
                        self.views.iter().enumerate()
                    {
                        let (p_lo, p_hi) = if lo_is_self {
                            (&run.p_self[v], &run.p_peer[v])
                        } else {
                            (&run.p_peer[v], &run.p_self[v])
                        };
                        let mut p_hat: Vec<f32> = p_lo
                            .iter()
                            .zip(p_hi.iter())
                            .map(|(a, b)| a - b)
                            .collect();
                        normalize(&mut p_hat);
                        let m = &w[off..off + rows * cols];
                        let s = matvec_t_f32(m, rows, cols, &p_hat);
                        out.send(from, Msg::Dense(s.clone()));
                        s_selfs.push(s);
                    }
                    run.s_self = s_selfs;
                    run.phase = PgPhase::S;
                    run.recv_count = 0;
                }
            }
            PgPhase::S => {
                let v = run.recv_count;
                ensure!(v < nv, "s-phase overflow from {from}");
                let s_peer = payload;
                ensure!(
                    s_peer.len() == self.views[v].2,
                    "s half for view {v}: len {} != cols {}",
                    s_peer.len(),
                    self.views[v].2
                );
                let lo_is_self = self.node < from;
                let (p, q_next) = {
                    let (p_lo, p_hi) = if lo_is_self {
                        (&run.p_self[v], &run.p_peer[v])
                    } else {
                        (&run.p_peer[v], &run.p_self[v])
                    };
                    let (s_lo, s_hi) = if lo_is_self {
                        (&run.s_self[v], &s_peer)
                    } else {
                        (&s_peer, &run.s_self[v])
                    };
                    power_iteration_step(p_lo, p_hi, s_lo, s_hi)
                };
                let q_used =
                    std::mem::replace(&mut self.states[jj][v].q_hat, q_next);
                // Degenerate-collapse reseed: the stream is derived per
                // (edge, view, CONVERSATION, iteration) — the
                // conversation counter, never a round stamp, so both
                // endpoints draw the identical replacement q̂ even when
                // their round clocks have drifted apart under async
                // rounds (and the draw stays independent of message
                // delivery order — replay- and engine-stable).  Under
                // sync the counter equals the round, so the stream is
                // bit-identical to the legacy schedule.  A reborn
                // edge's incarnation epoch extends the path (epoch 0 =
                // the legacy derivation), so conversation 0 of epoch 2
                // never replays epoch 1's draws.
                let e = self
                    .graph
                    .edge_index(self.node, from)
                    .ok_or_else(|| anyhow!("({}, {from}) is not an edge",
                                           self.node))?;
                let mut path = vec![
                    streams::POWER,
                    u64::MAX,
                    e as u64,
                    v as u64,
                    run.conv as u64,
                    run.it as u64,
                ];
                if self.edges[jj].epoch > 0 {
                    path.push(self.edges[jj].epoch as u64);
                }
                let mut reseed_rng = Pcg::derive(self.seed, &path);
                self.states[jj][v].reseed_if_degenerate(&mut reseed_rng);
                if run.it + 1 == self.iters {
                    run.finals.push((p, q_used));
                }
                run.recv_count += 1;
                if run.recv_count == nv {
                    run.it += 1;
                    if run.it < self.iters {
                        // Next power iteration of this conversation.
                        let ps = self.p_halves(jj, w);
                        for p in &ps {
                            out.send(from, Msg::Dense(p.clone()));
                        }
                        run.p_self = ps;
                        // Reset the slots in place: the outer vec keeps
                        // its allocation across power iterations.
                        for slot in run.p_peer.iter_mut() {
                            *slot = Vec::new();
                        }
                        run.phase = PgPhase::P;
                        run.recv_count = 0;
                    } else if !self.vec_views.is_empty() {
                        out.send(from, Msg::Dense(run.vec_payload.clone()));
                        run.phase = PgPhase::Vectors;
                        run.recv_count = 0;
                    } else {
                        completed = true;
                    }
                }
            }
            PgPhase::Vectors => {
                ensure!(
                    run.vec_recv.is_none(),
                    "duplicate vector payload from {from}"
                );
                ensure!(
                    payload.len() == run.vec_payload.len(),
                    "vector payload len {} != {}",
                    payload.len(),
                    run.vec_payload.len()
                );
                run.vec_recv = Some(payload);
                completed = true;
            }
        }
        if completed {
            self.edges[jj].last_completed = run.conv as i64;
            self.edges[jj].done.push(run);
        } else {
            self.edges[jj].active = Some(run);
        }
        Ok(())
    }
}

impl NodeStateMachine for PowerGossipNode {
    fn name(&self) -> String {
        format!("PowerGossip ({})", self.iters)
    }

    fn round_begin(&mut self, round: usize, view: &TopologyView,
                   w: &mut [f32], out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        self.cur_round = round;
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            if !self.edges[jj].live || round < self.edges[jj].offset {
                // Dead or not-yet-activated incarnation: no
                // conversation this round.
                continue;
            }
            if self.edges[jj].active.is_some() {
                // Straddling conversation: queue this round's start.
                // Sync never gets here — round_end barriers on every
                // edge completing.
                ensure!(
                    self.policy.is_async(),
                    "PowerGossip node {}: round {round} began with an \
                     unfinished sync conversation to {j}",
                    self.node
                );
                self.edges[jj].pending_starts += 1;
            } else {
                self.start_conversation(jj, j, w, out);
                // An ahead-running peer may already have buffered this
                // conversation's halves.
                self.drain_edge(jj, j, w, out)?;
            }
        }
        Ok(())
    }

    // `msg_round` is ignored by the protocol itself: all derived
    // randomness keys off the per-edge conversation counter (see the
    // module docs), so a stale or ahead-of-us message is simply the
    // next payload of its edge's FIFO conversation stream.
    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  view: &TopologyView, w: &mut [f32],
                  out: &mut Outbox) -> Result<()> {
        self.sync_view(view)?;
        let jj = self.neighbor_slot(from)?;
        ensure!(
            self.edges[jj].live,
            "PowerGossip node {}: payload from {from} on a churned-out \
             edge (the engine should have dropped it)",
            self.node
        );
        if !self.policy.is_async() {
            ensure!(
                msg_round == self.cur_round,
                "PowerGossip node {}: sync round {} got a round-{msg_round} \
                 message from {from}",
                self.node,
                self.cur_round
            );
        }
        self.edges[jj].inbuf.push_back(msg.into_dense()?);
        self.drain_edge(jj, from, w, out)?;
        // Under sync every legitimate message is consumable the moment
        // it arrives (the conversation of the current round is active);
        // anything left buffered is a duplicate or stray frame — the
        // protocol violation the old phase machine bailed on.  Async
        // legitimately buffers an ahead-running peer's opening halves.
        ensure!(
            self.policy.is_async() || self.edges[jj].inbuf.is_empty(),
            "PowerGossip node {}: unexpected message from {from} in round \
             {} (conversation already complete)",
            self.node,
            self.cur_round
        );
        Ok(())
    }

    fn round_complete(&self) -> bool {
        super::staleness_gate(self.policy, self.cur_round, &self.clocks())
    }

    fn policy(&self) -> Option<RoundPolicy> {
        Some(self.policy)
    }

    fn on_topology(&mut self, view: &TopologyView, _w: &mut [f32],
                   _out: &mut Outbox) -> Result<()> {
        self.sync_view(view)
    }

    fn round_end(&mut self, round: usize, view: &TopologyView,
                 w: &mut [f32]) -> Result<()> {
        self.sync_view(view)?;
        // The staleness bound is a hard protocol invariant on the
        // per-edge conversation clock, exactly like C-ECL's dual clock:
        // finishing a round while an edge's newest completed
        // conversation is older than `max_staleness` is an error, not a
        // silent quality loss.  Dead edges are excluded; a reborn
        // edge's clock counts from its activation round.
        let lag = super::check_staleness(self.policy, self.node,
                                         "conversation", round,
                                         &self.clocks())?;
        self.max_lag_seen = self.max_lag_seen.max(lag);
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        // Deferred application: fold every conversation completed since
        // the last round_end, per edge in conversation order (exactly
        // one per edge under sync — the legacy schedule, bit-identical).
        let done: Vec<Vec<PgConv>> = self
            .edges
            .iter_mut()
            .map(|e| std::mem::take(&mut e.done))
            .collect();
        // Gossip step on matrices: w_i += W_ij (w_j − w_i) with
        // (w_j − w_i) ≈ ±(p q̂ᵀ), folded in sorted-neighbor order (the
        // same order the threaded engine used, for bit-identical f32).
        for (jj, &j) in neighbors.iter().enumerate() {
            let wij = self.weights[j] as f32;
            let sign = if self.node < j { -1.0f32 } else { 1.0 };
            for run in &done[jj] {
                ensure!(
                    run.finals.len() == self.views.len(),
                    "edge to {j}: {} finals for {} views",
                    run.finals.len(),
                    self.views.len()
                );
                for (v, &(off, rows, cols)) in self.views.iter().enumerate() {
                    let (p, q_used) = &run.finals[v];
                    rank1_axpy(
                        &mut w[off..off + rows * cols],
                        rows,
                        cols,
                        sign * wij,
                        p,
                        q_used,
                    );
                }
            }
        }
        // Rank-1 tensors: dense gossip averaging (vector views are
        // disjoint from matrix views, so the two passes commute).
        if !self.vec_views.is_empty() {
            for (jj, &j) in neighbors.iter().enumerate() {
                let wij = self.weights[j] as f32;
                for run in &done[jj] {
                    let theirs = run.vec_recv.as_ref().ok_or_else(|| {
                        anyhow!("missing vector payload from {j}")
                    })?;
                    let mut cursor = 0;
                    for &(off, len) in &self.vec_views {
                        for t in 0..len {
                            let diff = theirs[cursor + t] - w[off + t];
                            w[off + t] += wij * diff;
                        }
                        cursor += len;
                    }
                }
            }
        }
        Ok(())
    }

    fn max_staleness_seen(&self) -> usize {
        self.max_lag_seen
    }
}

impl NodeAlgorithm for PowerGossipNode {
    fn name(&self) -> String {
        format!("PowerGossip ({})", self.iters)
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        // Blocking driver over the per-edge conversations (the threaded
        // bus is bulk-synchronous and epoch-constant, so this is the
        // sync schedule over the static full view).  Every send of ours
        // is triggered by a receive from the SAME neighbor (after the
        // opening p halves), so draining one edge to completion before
        // the next cannot deadlock: the peer never needs traffic from a
        // third party to produce its next message.
        let view = Arc::clone(&self.full_view);
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(self, round, &view, w, &mut out)?;
        for (to, msg) in out.drain() {
            comm.send(to, msg)?;
        }
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            while self.edges[jj].last_completed < round as i64 {
                let msg = comm.recv(j)?;
                NodeStateMachine::on_message(self, round, j, msg, &view, w,
                                             &mut out)?;
                for (to, m) in out.drain() {
                    comm.send(to, m)?;
                }
            }
        }
        NodeStateMachine::round_end(self, round, &view, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;
    use std::collections::VecDeque;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 26\nd_pad 32\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer m1 4 5\nlayer b1 2\nlayer m2 2 2\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    fn build_policy(i: usize, graph: &Arc<Graph>, iters: usize,
                    round_policy: RoundPolicy) -> PowerGossipNode {
        let ctx = BuildCtx {
            node: i,
            graph: Arc::clone(graph),
            manifest: manifest(),
            seed: 5,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy,
        };
        PowerGossipNode::new(&ctx, iters).unwrap()
    }

    fn build(i: usize, graph: &Arc<Graph>, iters: usize) -> PowerGossipNode {
        build_policy(i, graph, iters, RoundPolicy::Sync)
    }

    fn full_view(graph: &Arc<Graph>) -> TopologyView {
        TopologyView::full(graph.edges().len())
    }

    #[test]
    fn async_policy_accepted_at_construction() {
        // PR 3 pinned a typed rejection here; conversation counters
        // lifted it — the machine now reports the policy it was built
        // with so the engine can assert agreement.
        let graph = Arc::new(Graph::ring(4));
        let policy = RoundPolicy::Async { max_staleness: 2 };
        let node = build_policy(0, &graph, 2, policy);
        assert_eq!(NodeStateMachine::policy(&node), Some(policy));
        let sync = build(0, &graph, 2);
        assert_eq!(NodeStateMachine::policy(&sync), Some(RoundPolicy::Sync));
    }

    #[test]
    fn byte_accounting_formula() {
        let graph = Arc::new(Graph::ring(4));
        let node = build(0, &graph, 3);
        // matrices: (4+5) + (2+2) = 13 floats x 3 iters x 4B = 156;
        // vectors: 2 floats x 4B = 8.
        assert_eq!(node.bytes_per_round_per_neighbor(), 156 + 8);
    }

    #[test]
    fn low_rank_codec_frames_match_powergossip_wire_accounting() {
        // The `low_rank:R` edge codec bound to the same model layout
        // must meter exactly PowerGossip's bytes per round per neighbor
        // at `R = iters` — the codec IS PowerGossip's compressor on the
        // C-ECL wire.
        use crate::compress::{EdgeCodec, EdgeCtx, LowRankCodec};
        let graph = Arc::new(Graph::ring(4));
        let ds = manifest();
        for iters in [1usize, 2, 10] {
            let node = build(0, &graph, iters);
            let mut codec = LowRankCodec::new(iters, 1);
            let mats: Vec<(usize, usize, usize)> = ds
                .matrix_views()
                .into_iter()
                .map(|(_, o, r, c)| (o, r, c))
                .collect();
            let vecs: Vec<(usize, usize)> = ds
                .vector_views()
                .into_iter()
                .map(|(_, o, l)| (o, l))
                .collect();
            codec.bind_layout(&mats, &vecs);
            let ctx = EdgeCtx {
                seed: 5,
                edge: 0,
                round: 0,
                receiver: 1,
                dim: ds.d_pad,
                epoch: 0,
            };
            let x: Vec<f32> = (0..ds.d_pad).map(|i| i as f32 * 0.1).collect();
            let frame = codec.encode(&x, &ctx);
            assert_eq!(
                frame.wire_bytes(),
                node.bytes_per_round_per_neighbor(),
                "rank {iters}: codec bytes != PowerGossip accounting"
            );
        }
    }

    #[test]
    fn exchange_reduces_disagreement_and_meters_expected_bytes() {
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| {
                let mut rng = Pcg::new(300 + i as u64);
                (0..32).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        let disagreement = |ws: &Vec<Vec<f32>>| -> f32 {
            let mut mean = vec![0.0f32; 32];
            for w in ws {
                for (m, &v) in mean.iter_mut().zip(w) {
                    *m += v / 4.0;
                }
            }
            ws.iter()
                .map(|w| {
                    w.iter()
                        .zip(&mean)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                })
                .sum()
        };
        let before = disagreement(&ws);
        let iters = 2;
        let rounds = 3;
        let expected_bytes =
            4 * 2 * build(0, &graph, iters).bytes_per_round_per_neighbor();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        // Warm-started node reused across rounds (the
                        // real usage pattern).
                        let mut node = build(i, &graph, iters);
                        for round in 0..rounds {
                            node.exchange(round, w, &comm).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        let after = disagreement(&ws);
        assert!(
            after < before * 0.8,
            "disagreement {before} -> {after} (should contract)"
        );
        assert_eq!(meter.total_bytes() as usize, 3 * expected_bytes);
    }

    #[test]
    fn warm_start_states_identical_across_endpoints() {
        let graph = Arc::new(Graph::ring(4));
        let n0 = build(0, &graph, 1);
        let n1 = build(1, &graph, 1);
        // Edge (0,1): node 0's slot for neighbor 1 and node 1's slot for
        // neighbor 0 must hold the same q̂.
        let jj0 = graph.neighbors(0).iter().position(|&x| x == 1).unwrap();
        let jj1 = graph.neighbors(1).iter().position(|&x| x == 0).unwrap();
        for v in 0..2 {
            assert_eq!(n0.states[jj0][v].q_hat, n1.states[jj1][v].q_hat);
        }
    }

    #[test]
    fn state_machine_matches_threaded_exchange() {
        // Drive the poll-driven form by hand on a 2-node chain and
        // compare bit-for-bit against the blocking form on the bus.
        let graph = Arc::new(Graph::chain(2));
        let init_w = |i: usize| -> Vec<f32> {
            let mut rng = Pcg::new(400 + i as u64);
            (0..32).map(|_| rng.normal_f32()).collect()
        };

        // Threaded reference.
        let (comms, _) = build_bus(&graph);
        let mut ws_t: Vec<Vec<f32>> = (0..2).map(init_w).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws_t.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        let mut node = build(i, &graph, 2);
                        node.exchange(0, w, &comm).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        // Poll-driven form, messages shuttled through queues.
        let view = full_view(&graph);
        let mut a = build(0, &graph, 2);
        let mut b = build(1, &graph, 2);
        let mut wa = init_w(0);
        let mut wb = init_w(1);
        let mut out = Outbox::new();
        let mut q_ab: VecDeque<Msg> = VecDeque::new();
        let mut q_ba: VecDeque<Msg> = VecDeque::new();
        NodeStateMachine::round_begin(&mut a, 0, &view, &mut wa, &mut out)
            .unwrap();
        for (to, m) in out.drain() {
            assert_eq!(to, 1);
            q_ab.push_back(m);
        }
        NodeStateMachine::round_begin(&mut b, 0, &view, &mut wb, &mut out)
            .unwrap();
        for (to, m) in out.drain() {
            assert_eq!(to, 0);
            q_ba.push_back(m);
        }
        while !(q_ab.is_empty() && q_ba.is_empty()) {
            if let Some(m) = q_ba.pop_front() {
                NodeStateMachine::on_message(&mut a, 0, 1, m, &view, &mut wa,
                                             &mut out)
                    .unwrap();
                for (to, m) in out.drain() {
                    assert_eq!(to, 1);
                    q_ab.push_back(m);
                }
            }
            if let Some(m) = q_ab.pop_front() {
                NodeStateMachine::on_message(&mut b, 0, 0, m, &view, &mut wb,
                                             &mut out)
                    .unwrap();
                for (to, m) in out.drain() {
                    assert_eq!(to, 0);
                    q_ba.push_back(m);
                }
            }
        }
        assert!(a.round_complete() && b.round_complete());
        NodeStateMachine::round_end(&mut a, 0, &view, &mut wa).unwrap();
        NodeStateMachine::round_end(&mut b, 0, &view, &mut wb).unwrap();
        assert_eq!(wa, ws_t[0], "node 0 diverged from threaded engine");
        assert_eq!(wb, ws_t[1], "node 1 diverged from threaded engine");
        // A stray frame after the round's conversation completed is a
        // typed protocol error under sync, not a silent buffer.
        let err = NodeStateMachine::on_message(
            &mut a, 0, 1, Msg::Dense(vec![0.0; 4]), &view, &mut wa, &mut out,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unexpected message"), "{err}");
    }

    #[test]
    fn async_conversation_straddles_rounds_and_defers_application() {
        // Two nodes, async:1.  Node A runs rounds 0 and 1 before B says
        // anything: conversation 0 straddles A's round boundary, round
        // 1's start is queued, and A's w is untouched until the
        // conversation completes and the NEXT round_end applies it.
        let graph = Arc::new(Graph::chain(2));
        let view = full_view(&graph);
        let policy = RoundPolicy::Async { max_staleness: 1 };
        let mut a = build_policy(0, &graph, 1, policy);
        let mut b = build_policy(1, &graph, 1, policy);
        let mut wa: Vec<f32> = {
            let mut rng = Pcg::new(500);
            (0..32).map(|_| rng.normal_f32()).collect()
        };
        let mut wb: Vec<f32> = {
            let mut rng = Pcg::new(501);
            (0..32).map(|_| rng.normal_f32()).collect()
        };
        let wa0 = wa.clone();
        let mut out = Outbox::new();
        let mut to_b: VecDeque<Msg> = VecDeque::new();

        // A: round 0 begins, sends its opening p halves, and — with
        // staleness 1 — may finish round 0 without hearing back.
        NodeStateMachine::round_begin(&mut a, 0, &view, &mut wa, &mut out)
            .unwrap();
        for (to, m) in out.drain() {
            assert_eq!(to, 1);
            to_b.push_back(m);
        }
        assert!(a.round_complete(), "async:1 must not block round 0");
        NodeStateMachine::round_end(&mut a, 0, &view, &mut wa).unwrap();
        assert_eq!(wa, wa0, "no conversation done: w must be untouched");

        // A: round 1 begins while conversation 0 is still in flight —
        // the round's conversation start is queued, not interleaved.
        NodeStateMachine::round_begin(&mut a, 1, &view, &mut wa, &mut out)
            .unwrap();
        assert!(out.is_empty(), "straddling edge queues its start");
        assert!(!a.round_complete(), "round 1 needs conversation 0");

        // B: round 0 begins; the two nodes now finish conversation 0.
        NodeStateMachine::round_begin(&mut b, 0, &view, &mut wb, &mut out)
            .unwrap();
        let mut to_a: VecDeque<Msg> = out.drain().map(|(_, m)| m).collect();
        loop {
            let mut progressed = false;
            if let Some(m) = to_a.pop_front() {
                // B's sends carry B's round stamp (0) while A sits at
                // round 1 — exactly the skew conversation counters absorb.
                NodeStateMachine::on_message(&mut a, 0, 1, m, &view, &mut wa,
                                             &mut out)
                    .unwrap();
                out.drain().for_each(|(_, m)| to_b.push_back(m));
                progressed = true;
            }
            if let Some(m) = to_b.pop_front() {
                NodeStateMachine::on_message(&mut b, 1, 0, m, &view, &mut wb,
                                             &mut out)
                    .unwrap();
                out.drain().for_each(|(_, m)| to_a.push_back(m));
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
        // Conversation 0 done everywhere; conversation 1 (A's queued
        // round-1 start) is now in flight, so A can finish round 1.
        assert_eq!(a.edges[0].last_completed, 0);
        assert_eq!(b.edges[0].last_completed, 0);
        assert!(a.round_complete());
        NodeStateMachine::round_end(&mut a, 1, &view, &mut wa).unwrap();
        assert_ne!(wa, wa0, "deferred correction must apply at round_end");
        assert_eq!(NodeStateMachine::max_staleness_seen(&a), 1);

        // Warm-start lockstep survived the round skew.
        for v in 0..2 {
            assert_eq!(a.states[0][v].q_hat, b.states[0][v].q_hat,
                       "view {v}: q̂ desynchronized");
        }
    }

    #[test]
    fn async_round_end_past_staleness_bound_is_typed_error() {
        let graph = Arc::new(Graph::ring(4));
        let view = full_view(&graph);
        let policy = RoundPolicy::Async { max_staleness: 1 };
        let mut node = build_policy(0, &graph, 1, policy);
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut node, 0, &view, &mut w, &mut out)
            .unwrap();
        NodeStateMachine::round_end(&mut node, 0, &view, &mut w).unwrap();
        NodeStateMachine::round_begin(&mut node, 1, &view, &mut w, &mut out)
            .unwrap();
        assert!(!node.round_complete(), "round 1 needs conversation 0");
        let err = NodeStateMachine::round_end(&mut node, 1, &view, &mut w)
            .unwrap_err();
        assert!(err.to_string().contains("would consume"), "{err}");
    }

    #[test]
    fn edge_rebirth_resets_conversations_and_reseeds_qhat() {
        // Kill edge (0, 1) mid-conversation, then revive it: the
        // in-flight conversation is torn down (typed teardown — nothing
        // from the old epoch can resume), the conversation counter
        // restarts at the activation round, and the q̂ warm start
        // re-derives from the epoch-keyed stream — different from epoch
        // 0's, but still identical at both endpoints.
        let graph = Arc::new(Graph::chain(2));
        let mut view = full_view(&graph);
        let mut a = build(0, &graph, 2);
        let mut b = build(1, &graph, 2);
        let q0 = a.states[0][0].q_hat.clone();
        let mut w = vec![0.5f32; 32];
        let mut out = Outbox::new();
        // Open a conversation (never completed: the peer stays silent).
        NodeStateMachine::round_begin(&mut a, 0, &view, &mut w, &mut out)
            .unwrap();
        assert!(a.edges[0].active.is_some());
        out.drain().for_each(drop);

        let e01 = graph.edge_index(0, 1).unwrap();
        view.kill_edge(e01);
        NodeStateMachine::on_topology(&mut a, &view, &mut w, &mut out)
            .unwrap();
        assert!(a.edges[0].active.is_none(), "conversation not torn down");
        assert!(!a.edges[0].live);
        // With its only edge dead, the sync gate is trivially open.
        assert!(a.round_complete());

        view.revive_edge(e01, 5);
        NodeStateMachine::on_topology(&mut a, &view, &mut w, &mut out)
            .unwrap();
        NodeStateMachine::on_topology(&mut b, &view, &mut w, &mut out)
            .unwrap();
        assert_eq!(a.edges[0].epoch, 1);
        assert_eq!(a.edges[0].offset, 5);
        assert_eq!(a.edges[0].next_conv, 0, "counter restarts per epoch");
        // Fresh-epoch q̂: not the epoch-0 stream, but lockstep across
        // the endpoints.
        assert_ne!(a.states[0][0].q_hat, q0, "epoch must reseed q̂");
        for v in 0..a.views.len() {
            assert_eq!(a.states[0][v].q_hat, b.states[0][v].q_hat,
                       "view {v}: endpoints desynchronized");
        }
        // Before activation the edge starts no conversation…
        NodeStateMachine::round_begin(&mut a, 4, &view, &mut w, &mut out)
            .unwrap();
        assert!(out.is_empty());
        assert!(a.edges[0].active.is_none());
        // …and at activation it opens conversation 0 of the new epoch.
        NodeStateMachine::round_begin(&mut a, 5, &view, &mut w, &mut out)
            .unwrap();
        assert!(a.edges[0].active.is_some());
        assert!(!out.is_empty());
    }
}
