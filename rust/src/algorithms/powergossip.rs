//! PowerGossip (Vogels, Karimireddy, Jaggi 2020): the compressed Gossip
//! baseline of the paper's tables.
//!
//! Per round and per layer matrix, each edge approximates the model
//! *difference* `D = M_lo − M_hi` by rank-1 power iteration with a
//! warm-started direction `q̂` that both endpoints keep in lockstep (same
//! derived seed, same deterministic updates — the low-rank analogue of
//! the C-ECL shared-mask trick).  One “power iteration step” exchanges
//! `p = M q̂` (rows floats) and `s = Mᵀ p̂` (cols floats) in each
//! direction; after the configured number of steps the rank-1 correction
//! `±W_ij · p q̂ᵀ` is applied gossip-style.  Rank-1 tensors (biases, GN
//! scales) are exchanged dense — they are a rounding error of the byte
//! budget.
//!
//! Wire cost per round per neighbor:
//! `iters · Σ_matrices (rows + cols) · 4  +  Σ_vectors len · 4` bytes,
//! which reproduces the paper's PowerGossip(1/10/20) ratio ladder.

use std::sync::Arc;

use crate::comm::{Msg, NodeComm};
use crate::compress::low_rank::{
    matvec_f32, matvec_t_f32, normalize, power_iteration_step, rank1_axpy,
    LowRankEdgeState,
};
use crate::graph::Graph;
use crate::util::rng::{streams, Pcg};

use super::{BuildCtx, NodeAlgorithm};

pub struct PowerGossipNode {
    node: usize,
    graph: Arc<Graph>,
    iters: usize,
    /// MH weight row.
    weights: Vec<f64>,
    /// `(offset, rows, cols)` per layer matrix.
    views: Vec<(usize, usize, usize)>,
    /// `(offset, len)` per rank-1 tensor.
    vec_views: Vec<(usize, usize)>,
    /// Warm-started q̂ per (neighbor slot, view).
    states: Vec<Vec<LowRankEdgeState>>,
    reseed_rng: Pcg,
}

impl PowerGossipNode {
    pub fn new(ctx: &BuildCtx, iters: usize) -> PowerGossipNode {
        assert!(iters >= 1);
        let views: Vec<(usize, usize, usize)> = ctx
            .manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vec_views: Vec<(usize, usize)> = ctx
            .manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        let neighbors = ctx.graph.neighbors(ctx.node);
        // q̂ init must be identical at both edge endpoints: derive from
        // (seed, POWER, edge, view).
        let states = neighbors
            .iter()
            .map(|&j| {
                let e = ctx.graph.edge_index(ctx.node, j).unwrap() as u64;
                views
                    .iter()
                    .enumerate()
                    .map(|(v, &(_, _, cols))| {
                        let mut rng = Pcg::derive(
                            ctx.seed,
                            &[streams::POWER, e, v as u64],
                        );
                        LowRankEdgeState::new(cols, &mut rng)
                    })
                    .collect()
            })
            .collect();
        PowerGossipNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            iters,
            weights: ctx.graph.mh_weights()[ctx.node].clone(),
            views,
            vec_views,
            states,
            reseed_rng: Pcg::derive(ctx.seed, &[streams::POWER, u64::MAX,
                                                ctx.node as u64]),
        }
    }

    /// Deterministic wire bytes per round (for accounting tests).
    pub fn bytes_per_round_per_neighbor(&self) -> usize {
        let mat: usize = self
            .views
            .iter()
            .map(|&(_, r, c)| (r + c) * 4)
            .sum::<usize>()
            * self.iters;
        let vecs: usize = self.vec_views.iter().map(|&(_, l)| l * 4).sum();
        mat + vecs
    }
}

impl NodeAlgorithm for PowerGossipNode {
    fn name(&self) -> String {
        format!("PowerGossip ({})", self.iters)
    }

    fn exchange(&mut self, _round: usize, w: &mut [f32], comm: &NodeComm) {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let nv = self.views.len();
        // Final (p, q̂) per (neighbor, view) for the rank-1 correction.
        let mut finals: Vec<Vec<(Vec<f32>, Vec<f32>)>> =
            vec![Vec::with_capacity(nv); neighbors.len()];

        for it in 0..self.iters {
            // --- p half: send all, then receive all (no deadlock). ----
            let mut p_self: Vec<Vec<Vec<f32>>> =
                vec![Vec::with_capacity(nv); neighbors.len()];
            for (jj, &j) in neighbors.iter().enumerate() {
                for (v, &(off, rows, cols)) in self.views.iter().enumerate() {
                    let m = &w[off..off + rows * cols];
                    let p = matvec_f32(m, rows, cols,
                                       &self.states[jj][v].q_hat);
                    comm.send(j, Msg::Dense(p.clone()));
                    p_self[jj].push(p);
                }
            }
            let mut p_peer: Vec<Vec<Vec<f32>>> =
                vec![Vec::with_capacity(nv); neighbors.len()];
            for (jj, &j) in neighbors.iter().enumerate() {
                for _ in 0..nv {
                    p_peer[jj].push(comm.recv(j).into_dense());
                }
            }
            // --- s half. ----------------------------------------------
            let mut s_self: Vec<Vec<Vec<f32>>> =
                vec![Vec::with_capacity(nv); neighbors.len()];
            let mut p_hat_all: Vec<Vec<Vec<f32>>> =
                vec![Vec::with_capacity(nv); neighbors.len()];
            for (jj, &j) in neighbors.iter().enumerate() {
                let lo_is_self = self.node < j;
                for (v, &(off, rows, cols)) in self.views.iter().enumerate() {
                    // Orientation: D = M_lo − M_hi.
                    let (p_lo, p_hi) = if lo_is_self {
                        (&p_self[jj][v], &p_peer[jj][v])
                    } else {
                        (&p_peer[jj][v], &p_self[jj][v])
                    };
                    let mut p_hat: Vec<f32> =
                        p_lo.iter().zip(p_hi).map(|(a, b)| a - b).collect();
                    normalize(&mut p_hat);
                    let m = &w[off..off + rows * cols];
                    let s = matvec_t_f32(m, rows, cols, &p_hat);
                    comm.send(j, Msg::Dense(s.clone()));
                    s_self[jj].push(s);
                    p_hat_all[jj].push(p_hat);
                }
            }
            for (jj, &j) in neighbors.iter().enumerate() {
                let lo_is_self = self.node < j;
                for v in 0..nv {
                    let s_peer = comm.recv(j).into_dense();
                    let (p_lo, p_hi) = if lo_is_self {
                        (&p_self[jj][v], &p_peer[jj][v])
                    } else {
                        (&p_peer[jj][v], &p_self[jj][v])
                    };
                    let (s_lo, s_hi) = if lo_is_self {
                        (&s_self[jj][v], &s_peer)
                    } else {
                        (&s_peer, &s_self[jj][v])
                    };
                    let (p, q_next) =
                        power_iteration_step(p_lo, p_hi, s_lo, s_hi);
                    let q_used = self.states[jj][v].q_hat.clone();
                    self.states[jj][v].q_hat = q_next;
                    self.states[jj][v].reseed_if_degenerate(&mut self.reseed_rng);
                    if it == self.iters - 1 {
                        finals[jj].push((p, q_used));
                    }
                }
            }
        }

        // --- Apply the gossip step on matrices: w_i += W_ij (w_j − w_i),
        // with (w_j − w_i) ≈ ±(p q̂ᵀ). --------------------------------
        for (jj, &j) in neighbors.iter().enumerate() {
            let wij = self.weights[j] as f32;
            let sign = if self.node < j { -1.0f32 } else { 1.0 };
            for (v, &(off, rows, cols)) in self.views.iter().enumerate() {
                let (p, q_used) = &finals[jj][v];
                rank1_axpy(
                    &mut w[off..off + rows * cols],
                    rows,
                    cols,
                    sign * wij,
                    p,
                    q_used,
                );
            }
        }

        // --- Rank-1 tensors: dense gossip averaging. ------------------
        if !self.vec_views.is_empty() {
            let total: usize = self.vec_views.iter().map(|&(_, l)| l).sum();
            let mut mine = Vec::with_capacity(total);
            for &(off, len) in &self.vec_views {
                mine.extend_from_slice(&w[off..off + len]);
            }
            for &j in &neighbors {
                comm.send(j, Msg::Dense(mine.clone()));
            }
            for &j in &neighbors {
                let theirs = comm.recv(j).into_dense();
                let wij = self.weights[j] as f32;
                let mut cursor = 0;
                for &(off, len) in &self.vec_views {
                    for t in 0..len {
                        let diff = theirs[cursor + t] - w[off + t];
                        w[off + t] += wij * diff;
                    }
                    cursor += len;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 26\nd_pad 32\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer m1 4 5\nlayer b1 2\nlayer m2 2 2\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    fn build(i: usize, graph: &Arc<Graph>, iters: usize) -> PowerGossipNode {
        let ctx = BuildCtx {
            node: i,
            graph: Arc::clone(graph),
            manifest: manifest(),
            seed: 5,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
        };
        PowerGossipNode::new(&ctx, iters)
    }

    #[test]
    fn byte_accounting_formula() {
        let graph = Arc::new(Graph::ring(4));
        let node = build(0, &graph, 3);
        // matrices: (4+5) + (2+2) = 13 floats x 3 iters x 4B = 156;
        // vectors: 2 floats x 4B = 8.
        assert_eq!(node.bytes_per_round_per_neighbor(), 156 + 8);
    }

    #[test]
    fn exchange_reduces_disagreement_and_meters_expected_bytes() {
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| {
                let mut rng = Pcg::new(300 + i as u64);
                (0..32).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        let disagreement = |ws: &Vec<Vec<f32>>| -> f32 {
            let mut mean = vec![0.0f32; 32];
            for w in ws {
                for (m, &v) in mean.iter_mut().zip(w) {
                    *m += v / 4.0;
                }
            }
            ws.iter()
                .map(|w| {
                    w.iter()
                        .zip(&mean)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                })
                .sum()
        };
        let before = disagreement(&ws);
        let iters = 2;
        let rounds = 3;
        let expected_bytes =
            4 * 2 * build(0, &graph, iters).bytes_per_round_per_neighbor();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        // Warm-started node reused across rounds (the
                        // real usage pattern).
                        let mut node = build(i, &graph, iters);
                        for round in 0..rounds {
                            node.exchange(round, w, &comm);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        let after = disagreement(&ws);
        assert!(
            after < before * 0.8,
            "disagreement {before} -> {after} (should contract)"
        );
        assert_eq!(meter.total_bytes() as usize, 3 * expected_bytes);
    }

    #[test]
    fn warm_start_states_identical_across_endpoints() {
        let graph = Arc::new(Graph::ring(4));
        let n0 = build(0, &graph, 1);
        let n1 = build(1, &graph, 1);
        // Edge (0,1): node 0's slot for neighbor 1 and node 1's slot for
        // neighbor 0 must hold the same q̂.
        let jj0 = graph.neighbors(0).iter().position(|&x| x == 1).unwrap();
        let jj1 = graph.neighbors(1).iter().position(|&x| x == 0).unwrap();
        for v in 0..2 {
            assert_eq!(n0.states[jj0][v].q_hat, n1.states[jj1][v].q_hat);
        }
    }
}
