//! PowerGossip (Vogels, Karimireddy, Jaggi 2020): the compressed Gossip
//! baseline of the paper's tables.
//!
//! Per round and per layer matrix, each edge approximates the model
//! *difference* `D = M_lo − M_hi` by rank-1 power iteration with a
//! warm-started direction `q̂` that both endpoints keep in lockstep (same
//! derived seed, same deterministic updates — the low-rank analogue of
//! the C-ECL shared-mask trick).  One “power iteration step” exchanges
//! `p = M q̂` (rows floats) and `s = Mᵀ p̂` (cols floats) in each
//! direction; after the configured number of steps the rank-1 correction
//! `±W_ij · p q̂ᵀ` is applied gossip-style.  Rank-1 tensors (biases, GN
//! scales) are exchanged dense — they are a rounding error of the byte
//! budget.
//!
//! The protocol is multi-phase, so the poll-driven
//! [`NodeStateMachine`] form runs an independent pipeline per edge
//! ([`PgEdgeRun`]): neighbor A can be two power iterations ahead of
//! neighbor B without any global barrier.  Each edge's conversation only
//! depends on its own traffic (w is frozen between `round_begin` and
//! `round_end`, q̂ is per-edge), so the per-edge pipelining computes
//! bit-identical results to the old lockstep schedule.  The blocking
//! [`NodeAlgorithm::exchange`] drives the same machine edge-by-edge.
//!
//! Wire cost per round per neighbor:
//! `iters · Σ_matrices (rows + cols) · 4  +  Σ_vectors len · 4` bytes,
//! which reproduces the paper's PowerGossip(1/10/20) ratio ladder.

use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::comm::{Msg, NodeComm, Outbox};
use crate::compress::low_rank::{
    matvec_f32, matvec_t_f32, normalize, power_iteration_step, rank1_axpy,
    LowRankEdgeState,
};
use crate::graph::Graph;
use crate::util::rng::{streams, Pcg};

use super::{BuildCtx, NodeAlgorithm, NodeStateMachine, RoundPolicy};

/// Where one edge's conversation stands within the current round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
enum PgPhase {
    /// Receiving the peer's `p = M q̂` halves (one per matrix view).
    #[default]
    P,
    /// Receiving the peer's `s = Mᵀ p̂` halves.
    S,
    /// Receiving the peer's dense rank-1-tensor payload.
    Vectors,
    Done,
}

/// Per-edge pipeline state for one exchange round.
#[derive(Debug, Default)]
struct PgEdgeRun {
    /// Power-iteration index within the round.
    it: usize,
    phase: PgPhase,
    /// Messages received so far in the current phase.
    recv_count: usize,
    /// Our halves for the current iteration, one per matrix view.
    p_self: Vec<Vec<f32>>,
    p_peer: Vec<Vec<f32>>,
    s_self: Vec<Vec<f32>>,
    /// `(p, q̂_used)` per view, captured on the last iteration, consumed
    /// by `round_end`.
    finals: Vec<(Vec<f32>, Vec<f32>)>,
    vec_recv: Option<Vec<f32>>,
}

impl PgEdgeRun {
    fn new(nv: usize) -> PgEdgeRun {
        PgEdgeRun {
            it: 0,
            phase: PgPhase::P,
            recv_count: 0,
            p_self: Vec::new(),
            p_peer: vec![Vec::new(); nv],
            s_self: Vec::new(),
            finals: Vec::with_capacity(nv),
            vec_recv: None,
        }
    }
}

pub struct PowerGossipNode {
    node: usize,
    graph: Arc<Graph>,
    iters: usize,
    /// MH weight row.
    weights: Vec<f64>,
    /// `(offset, rows, cols)` per layer matrix.
    views: Vec<(usize, usize, usize)>,
    /// `(offset, len)` per rank-1 tensor.
    vec_views: Vec<(usize, usize)>,
    /// Warm-started q̂ per (neighbor slot, view).
    states: Vec<Vec<LowRankEdgeState>>,
    seed: u64,
    /// Per-edge pipeline state for the round in flight.
    runs: Vec<PgEdgeRun>,
    /// Concatenated rank-1 tensors, snapshotted at `round_begin`.
    vec_payload: Vec<f32>,
    done_count: usize,
}

impl PowerGossipNode {
    pub fn new(ctx: &BuildCtx, iters: usize) -> Result<PowerGossipNode> {
        ensure!(iters >= 1, "PowerGossip needs at least one iteration");
        // The request-response power-iteration pipeline needs both
        // endpoints inside the same edge round; per-edge pipelining
        // already makes it non-blocking WITHIN a round, but bounded-
        // staleness rounds would desynchronize the warm-started q̂
        // lockstep.
        ensure!(
            ctx.round_policy == RoundPolicy::Sync,
            "PowerGossip supports only RoundPolicy::Sync (its multi-phase \
             per-edge pipeline requires matched rounds); requested {}",
            ctx.round_policy.name()
        );
        let views: Vec<(usize, usize, usize)> = ctx
            .manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vec_views: Vec<(usize, usize)> = ctx
            .manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        let neighbors = ctx.graph.neighbors(ctx.node);
        // q̂ init must be identical at both edge endpoints: derive from
        // (seed, POWER, edge, view).
        let states = neighbors
            .iter()
            .map(|&j| {
                let e = ctx.graph.edge_index(ctx.node, j).unwrap() as u64;
                views
                    .iter()
                    .enumerate()
                    .map(|(v, &(_, _, cols))| {
                        let mut rng = Pcg::derive(
                            ctx.seed,
                            &[streams::POWER, e, v as u64],
                        );
                        LowRankEdgeState::new(cols, &mut rng)
                    })
                    .collect()
            })
            .collect();
        Ok(PowerGossipNode {
            node: ctx.node,
            graph: Arc::clone(&ctx.graph),
            iters,
            weights: ctx.graph.mh_weights()[ctx.node].clone(),
            views,
            vec_views,
            states,
            seed: ctx.seed,
            runs: Vec::new(),
            vec_payload: Vec::new(),
            done_count: 0,
        })
    }

    /// Deterministic wire bytes per round (for accounting tests).
    pub fn bytes_per_round_per_neighbor(&self) -> usize {
        let mat: usize = self
            .views
            .iter()
            .map(|&(_, r, c)| (r + c) * 4)
            .sum::<usize>()
            * self.iters;
        let vecs: usize = self.vec_views.iter().map(|&(_, l)| l * 4).sum();
        mat + vecs
    }

    /// `p = M q̂` for every matrix view on edge slot `jj`.
    fn p_halves(&self, jj: usize, w: &[f32]) -> Vec<Vec<f32>> {
        self.views
            .iter()
            .enumerate()
            .map(|(v, &(off, rows, cols))| {
                matvec_f32(&w[off..off + rows * cols], rows, cols,
                           &self.states[jj][v].q_hat)
            })
            .collect()
    }

    fn neighbor_slot(&self, from: usize) -> Result<usize> {
        self.graph
            .neighbors(self.node)
            .iter()
            .position(|&x| x == from)
            .ok_or_else(|| {
                anyhow!("node {}: message from non-neighbor {from}", self.node)
            })
    }
}

impl NodeStateMachine for PowerGossipNode {
    fn name(&self) -> String {
        format!("PowerGossip ({})", self.iters)
    }

    fn round_begin(&mut self, _round: usize, w: &mut [f32],
                   out: &mut Outbox) -> Result<()> {
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        let nv = self.views.len();
        self.done_count = 0;
        // Snapshot the rank-1 tensors once.  Vector views are disjoint
        // from matrix views, so snapshotting before the round's rank-1
        // corrections is equivalent to the post-correction read.
        self.vec_payload.clear();
        for &(off, len) in &self.vec_views {
            self.vec_payload.extend_from_slice(&w[off..off + len]);
        }
        self.runs = neighbors.iter().map(|_| PgEdgeRun::new(nv)).collect();
        for (jj, &j) in neighbors.iter().enumerate() {
            if nv == 0 {
                // Degenerate model with no matrix layers: straight to the
                // dense vector gossip (or nothing at all).
                if self.vec_views.is_empty() {
                    self.runs[jj].phase = PgPhase::Done;
                    self.done_count += 1;
                } else {
                    out.send(j, Msg::Dense(self.vec_payload.clone()));
                    self.runs[jj].phase = PgPhase::Vectors;
                }
                continue;
            }
            let ps = self.p_halves(jj, w);
            for p in &ps {
                out.send(j, Msg::Dense(p.clone()));
            }
            self.runs[jj].p_self = ps;
        }
        Ok(())
    }

    // `msg_round` always equals this node's current round here: the
    // construction-time Sync pin means both engines only ever deliver
    // same-round traffic, so the reseed stream derivation below stays
    // identical at both edge endpoints.
    fn on_message(&mut self, msg_round: usize, from: usize, msg: Msg,
                  w: &mut [f32], out: &mut Outbox) -> Result<()> {
        let round = msg_round;
        let jj = self.neighbor_slot(from)?;
        ensure!(
            jj < self.runs.len(),
            "PowerGossip node {}: message before round_begin",
            self.node
        );
        let nv = self.views.len();
        let phase = self.runs[jj].phase;
        match phase {
            PgPhase::P => {
                let v = self.runs[jj].recv_count;
                ensure!(v < nv, "p-phase overflow from {from}");
                let p = msg.into_dense()?;
                ensure!(
                    p.len() == self.views[v].1,
                    "p half for view {v}: len {} != rows {}",
                    p.len(),
                    self.views[v].1
                );
                self.runs[jj].p_peer[v] = p;
                self.runs[jj].recv_count += 1;
                if self.runs[jj].recv_count == nv {
                    // All p halves in: compute p̂ and answer with our s
                    // halves.
                    let lo_is_self = self.node < from;
                    let mut s_selfs = Vec::with_capacity(nv);
                    for (v, &(off, rows, cols)) in
                        self.views.iter().enumerate()
                    {
                        let run = &self.runs[jj];
                        let (p_lo, p_hi) = if lo_is_self {
                            (&run.p_self[v], &run.p_peer[v])
                        } else {
                            (&run.p_peer[v], &run.p_self[v])
                        };
                        let mut p_hat: Vec<f32> = p_lo
                            .iter()
                            .zip(p_hi.iter())
                            .map(|(a, b)| a - b)
                            .collect();
                        normalize(&mut p_hat);
                        let m = &w[off..off + rows * cols];
                        let s = matvec_t_f32(m, rows, cols, &p_hat);
                        out.send(from, Msg::Dense(s.clone()));
                        s_selfs.push(s);
                    }
                    let run = &mut self.runs[jj];
                    run.s_self = s_selfs;
                    run.phase = PgPhase::S;
                    run.recv_count = 0;
                }
            }
            PgPhase::S => {
                let v = self.runs[jj].recv_count;
                ensure!(v < nv, "s-phase overflow from {from}");
                let s_peer = msg.into_dense()?;
                ensure!(
                    s_peer.len() == self.views[v].2,
                    "s half for view {v}: len {} != cols {}",
                    s_peer.len(),
                    self.views[v].2
                );
                let lo_is_self = self.node < from;
                let (p, q_next) = {
                    let run = &self.runs[jj];
                    let (p_lo, p_hi) = if lo_is_self {
                        (&run.p_self[v], &run.p_peer[v])
                    } else {
                        (&run.p_peer[v], &run.p_self[v])
                    };
                    let (s_lo, s_hi) = if lo_is_self {
                        (&run.s_self[v], &s_peer)
                    } else {
                        (&s_peer, &run.s_self[v])
                    };
                    power_iteration_step(p_lo, p_hi, s_lo, s_hi)
                };
                let q_used =
                    std::mem::replace(&mut self.states[jj][v].q_hat, q_next);
                // Degenerate-collapse reseed: the stream is derived per
                // (edge, view, round, iteration), so both endpoints
                // draw the identical replacement q̂ (the warm-start
                // lockstep survives) and the draw is independent of
                // message delivery order (replay- and engine-stable).
                let e = self
                    .graph
                    .edge_index(self.node, from)
                    .ok_or_else(|| anyhow!("({}, {from}) is not an edge",
                                           self.node))?;
                let mut reseed_rng = Pcg::derive(
                    self.seed,
                    &[
                        streams::POWER,
                        u64::MAX,
                        e as u64,
                        v as u64,
                        round as u64,
                        self.runs[jj].it as u64,
                    ],
                );
                self.states[jj][v].reseed_if_degenerate(&mut reseed_rng);
                if self.runs[jj].it + 1 == self.iters {
                    self.runs[jj].finals.push((p, q_used));
                }
                self.runs[jj].recv_count += 1;
                if self.runs[jj].recv_count == nv {
                    self.runs[jj].it += 1;
                    if self.runs[jj].it < self.iters {
                        // Next power iteration on this edge.
                        let ps = self.p_halves(jj, w);
                        for p in &ps {
                            out.send(from, Msg::Dense(p.clone()));
                        }
                        let run = &mut self.runs[jj];
                        run.p_self = ps;
                        run.p_peer = vec![Vec::new(); nv];
                        run.phase = PgPhase::P;
                        run.recv_count = 0;
                    } else if !self.vec_views.is_empty() {
                        out.send(from, Msg::Dense(self.vec_payload.clone()));
                        let run = &mut self.runs[jj];
                        run.phase = PgPhase::Vectors;
                        run.recv_count = 0;
                    } else {
                        self.runs[jj].phase = PgPhase::Done;
                        self.done_count += 1;
                    }
                }
            }
            PgPhase::Vectors => {
                ensure!(
                    self.runs[jj].vec_recv.is_none(),
                    "duplicate vector payload from {from}"
                );
                let theirs = msg.into_dense()?;
                ensure!(
                    theirs.len() == self.vec_payload.len(),
                    "vector payload len {} != {}",
                    theirs.len(),
                    self.vec_payload.len()
                );
                self.runs[jj].vec_recv = Some(theirs);
                self.runs[jj].phase = PgPhase::Done;
                self.done_count += 1;
            }
            PgPhase::Done => {
                bail!(
                    "PowerGossip node {}: unexpected message from {from} in \
                     round {round} (edge already done)",
                    self.node
                )
            }
        }
        Ok(())
    }

    fn round_complete(&self) -> bool {
        self.done_count == self.runs.len()
    }

    // Construction pins Sync (see `new`).
    fn policy(&self) -> Option<RoundPolicy> {
        Some(RoundPolicy::Sync)
    }

    fn round_end(&mut self, _round: usize, w: &mut [f32]) -> Result<()> {
        ensure!(
            self.round_complete(),
            "PowerGossip node {}: round_end with unfinished edges",
            self.node
        );
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        // Gossip step on matrices: w_i += W_ij (w_j − w_i) with
        // (w_j − w_i) ≈ ±(p q̂ᵀ), folded in sorted-neighbor order (the
        // same order the threaded engine used, for bit-identical f32).
        for (jj, &j) in neighbors.iter().enumerate() {
            ensure!(
                self.runs[jj].finals.len() == self.views.len(),
                "edge to {j}: {} finals for {} views",
                self.runs[jj].finals.len(),
                self.views.len()
            );
            let wij = self.weights[j] as f32;
            let sign = if self.node < j { -1.0f32 } else { 1.0 };
            for (v, &(off, rows, cols)) in self.views.iter().enumerate() {
                let (p, q_used) = &self.runs[jj].finals[v];
                rank1_axpy(
                    &mut w[off..off + rows * cols],
                    rows,
                    cols,
                    sign * wij,
                    p,
                    q_used,
                );
            }
        }
        // Rank-1 tensors: dense gossip averaging.
        if !self.vec_views.is_empty() {
            for (jj, &j) in neighbors.iter().enumerate() {
                let theirs = self.runs[jj]
                    .vec_recv
                    .take()
                    .ok_or_else(|| anyhow!("missing vector payload from {j}"))?;
                let wij = self.weights[j] as f32;
                let mut cursor = 0;
                for &(off, len) in &self.vec_views {
                    for t in 0..len {
                        let diff = theirs[cursor + t] - w[off + t];
                        w[off + t] += wij * diff;
                    }
                    cursor += len;
                }
            }
        }
        Ok(())
    }
}

impl NodeAlgorithm for PowerGossipNode {
    fn name(&self) -> String {
        format!("PowerGossip ({})", self.iters)
    }

    fn exchange(&mut self, round: usize, w: &mut [f32], comm: &NodeComm)
                -> Result<()> {
        // Blocking driver over the per-edge pipelines.  Every send of
        // ours is triggered by a receive from the SAME neighbor (after
        // the opening p halves), so draining one edge to completion
        // before the next cannot deadlock: the peer never needs traffic
        // from a third party to produce its next message.
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(self, round, w, &mut out)?;
        for (to, msg) in out.drain() {
            comm.send(to, msg)?;
        }
        let neighbors: Vec<usize> = self.graph.neighbors(self.node).to_vec();
        for (jj, &j) in neighbors.iter().enumerate() {
            while self.runs[jj].phase != PgPhase::Done {
                let msg = comm.recv(j)?;
                NodeStateMachine::on_message(self, round, j, msg, w, &mut out)?;
                for (to, m) in out.drain() {
                    comm.send(to, m)?;
                }
            }
        }
        NodeStateMachine::round_end(self, round, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::build_bus;
    use crate::model::Manifest;
    use std::collections::VecDeque;

    fn manifest() -> crate::model::DatasetManifest {
        Manifest::parse(
            "version 1\nsmoke s\ndataset t\nd 26\nd_pad 32\ninput 2 2 1\n\
             classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
             dual_update c\ninit_w d\nlayer m1 4 5\nlayer b1 2\nlayer m2 2 2\nend\n",
            std::path::Path::new("/x"),
        )
        .unwrap()
        .dataset("t")
        .unwrap()
        .clone()
    }

    fn build(i: usize, graph: &Arc<Graph>, iters: usize) -> PowerGossipNode {
        let ctx = BuildCtx {
            node: i,
            graph: Arc::clone(graph),
            manifest: manifest(),
            seed: 5,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Sync,
        };
        PowerGossipNode::new(&ctx, iters).unwrap()
    }

    #[test]
    fn async_policy_rejected_at_construction() {
        let graph = Arc::new(Graph::ring(4));
        let ctx = BuildCtx {
            node: 0,
            graph: Arc::clone(&graph),
            manifest: manifest(),
            seed: 5,
            eta: 0.1,
            local_steps: 1,
            rounds_per_epoch: 1,
            dual_path: crate::algorithms::DualPath::Native,
            runtime: None,
            round_policy: RoundPolicy::Async { max_staleness: 2 },
        };
        let err = PowerGossipNode::new(&ctx, 2).err().unwrap();
        assert!(err.to_string().contains("Sync"), "{err}");
    }

    #[test]
    fn byte_accounting_formula() {
        let graph = Arc::new(Graph::ring(4));
        let node = build(0, &graph, 3);
        // matrices: (4+5) + (2+2) = 13 floats x 3 iters x 4B = 156;
        // vectors: 2 floats x 4B = 8.
        assert_eq!(node.bytes_per_round_per_neighbor(), 156 + 8);
    }

    #[test]
    fn exchange_reduces_disagreement_and_meters_expected_bytes() {
        let graph = Arc::new(Graph::ring(4));
        let (comms, meter) = build_bus(&graph);
        let mut ws: Vec<Vec<f32>> = (0..4)
            .map(|i| {
                let mut rng = Pcg::new(300 + i as u64);
                (0..32).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        let disagreement = |ws: &Vec<Vec<f32>>| -> f32 {
            let mut mean = vec![0.0f32; 32];
            for w in ws {
                for (m, &v) in mean.iter_mut().zip(w) {
                    *m += v / 4.0;
                }
            }
            ws.iter()
                .map(|w| {
                    w.iter()
                        .zip(&mean)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                })
                .sum()
        };
        let before = disagreement(&ws);
        let iters = 2;
        let rounds = 3;
        let expected_bytes =
            4 * 2 * build(0, &graph, iters).bytes_per_round_per_neighbor();

        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        // Warm-started node reused across rounds (the
                        // real usage pattern).
                        let mut node = build(i, &graph, iters);
                        for round in 0..rounds {
                            node.exchange(round, w, &comm).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        let after = disagreement(&ws);
        assert!(
            after < before * 0.8,
            "disagreement {before} -> {after} (should contract)"
        );
        assert_eq!(meter.total_bytes() as usize, 3 * expected_bytes);
    }

    #[test]
    fn warm_start_states_identical_across_endpoints() {
        let graph = Arc::new(Graph::ring(4));
        let n0 = build(0, &graph, 1);
        let n1 = build(1, &graph, 1);
        // Edge (0,1): node 0's slot for neighbor 1 and node 1's slot for
        // neighbor 0 must hold the same q̂.
        let jj0 = graph.neighbors(0).iter().position(|&x| x == 1).unwrap();
        let jj1 = graph.neighbors(1).iter().position(|&x| x == 0).unwrap();
        for v in 0..2 {
            assert_eq!(n0.states[jj0][v].q_hat, n1.states[jj1][v].q_hat);
        }
    }

    #[test]
    fn state_machine_matches_threaded_exchange() {
        // Drive the poll-driven form by hand on a 2-node chain and
        // compare bit-for-bit against the blocking form on the bus.
        let graph = Arc::new(Graph::chain(2));
        let init_w = |i: usize| -> Vec<f32> {
            let mut rng = Pcg::new(400 + i as u64);
            (0..32).map(|_| rng.normal_f32()).collect()
        };

        // Threaded reference.
        let (comms, _) = build_bus(&graph);
        let mut ws_t: Vec<Vec<f32>> = (0..2).map(init_w).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .zip(ws_t.iter_mut())
                .enumerate()
                .map(|(i, (comm, w))| {
                    let graph = Arc::clone(&graph);
                    s.spawn(move || {
                        let mut node = build(i, &graph, 2);
                        node.exchange(0, w, &comm).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        // Poll-driven form, messages shuttled through queues.
        let mut a = build(0, &graph, 2);
        let mut b = build(1, &graph, 2);
        let mut wa = init_w(0);
        let mut wb = init_w(1);
        let mut out = Outbox::new();
        let mut q_ab: VecDeque<Msg> = VecDeque::new();
        let mut q_ba: VecDeque<Msg> = VecDeque::new();
        NodeStateMachine::round_begin(&mut a, 0, &mut wa, &mut out).unwrap();
        for (to, m) in out.drain() {
            assert_eq!(to, 1);
            q_ab.push_back(m);
        }
        NodeStateMachine::round_begin(&mut b, 0, &mut wb, &mut out).unwrap();
        for (to, m) in out.drain() {
            assert_eq!(to, 0);
            q_ba.push_back(m);
        }
        while !(q_ab.is_empty() && q_ba.is_empty()) {
            if let Some(m) = q_ba.pop_front() {
                NodeStateMachine::on_message(&mut a, 0, 1, m, &mut wa, &mut out)
                    .unwrap();
                for (to, m) in out.drain() {
                    assert_eq!(to, 1);
                    q_ab.push_back(m);
                }
            }
            if let Some(m) = q_ab.pop_front() {
                NodeStateMachine::on_message(&mut b, 0, 0, m, &mut wb, &mut out)
                    .unwrap();
                for (to, m) in out.drain() {
                    assert_eq!(to, 0);
                    q_ba.push_back(m);
                }
            }
        }
        assert!(a.round_complete() && b.round_complete());
        NodeStateMachine::round_end(&mut a, 0, &mut wa).unwrap();
        NodeStateMachine::round_end(&mut b, 0, &mut wb).unwrap();
        assert_eq!(wa, ws_t[0], "node 0 diverged from threaded engine");
        assert_eq!(wb, ws_t[1], "node 1 diverged from threaded engine");
    }
}
