//! Determinism static-analysis pass (`repro lint`).
//!
//! A dependency-free source walker that enforces the repo's determinism
//! invariants (see the "Determinism invariants" section in the crate
//! docs).  It is deliberately a lexer, not a full parser: it strips
//! strings and comments with a small state machine, tracks function
//! scopes by brace depth, and matches banned tokens as whole words.
//! That is enough to be exact on this codebase while adding zero
//! dependencies (the container has no registry access, so `syn` is not
//! an option).
//!
//! Rules (module-scoped):
//!
//! * `wall-clock` — `Instant` / `SystemTime` inside the deterministic
//!   modules (`sim`, `algorithms`, `compress`, `graph`).  Virtual time
//!   is the only clock those paths may observe.
//! * `unordered-container` — `HashMap` / `HashSet` in the same
//!   modules: iteration order would leak host hash seeds into replay.
//! * `ambient-rng` — `thread_rng` / `OsRng` there too: all randomness
//!   must flow from the seeded counter-mode `Pcg`.
//! * `panic-decode` — `.unwrap()` / `.expect(...)` / panic-family
//!   macros inside decode/parse-scope functions of the wire files
//!   (`compress/codec.rs`, `compress/coo.rs`, `compress/low_rank.rs`,
//!   `net/wire.rs`).  Peer bytes are untrusted; the contract is a
//!   typed `CodecError` / `CommError`.
//! * `index-decode` — direct slice indexing in those same functions,
//!   where a bad offset panics instead of erroring.
//! * `decode-alloc` — fresh `Vec` construction (`Vec::new`,
//!   `Vec::with_capacity`, `vec![...]`, `.to_vec()`, `.collect()`)
//!   inside `decode_into` implementations of the wire files.  The
//!   decode-into contract is zero steady-state allocation: scratch is
//!   reused across rounds, never rebuilt per message.
//! * `allow-justification` — a malformed suppression: unknown rule
//!   name, or a directive with no justification text.
//!
//! Suppressions are spelled as a comment of the form
//! "det:allow(rule[, rule...]): justification" — trailing on the
//! offending line, or standalone on the line(s) above, in which case
//! it applies to the next non-blank code line.  A directive without a
//! justification, or naming an unknown rule, is itself a violation
//! and suppresses nothing, so every exception stays visible and
//! explained in the diff.
//!
//! `#[cfg(test)]` modules are exempt from all scoped rules: tests may
//! unwrap and may time themselves.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Module prefixes (relative to `rust/src/`, `/`-separated) where the
/// deterministic-path rules apply.
const DET_PREFIXES: [&str; 4] = ["sim/", "algorithms/", "compress/", "graph/"];

/// Files whose decode/parse-scope functions carry the no-panic,
/// no-indexing contract on untrusted bytes.
const WIRE_FILES: [&str; 4] = [
    "compress/codec.rs",
    "compress/coo.rs",
    "compress/low_rank.rs",
    "net/wire.rs",
];

/// Every rule a directive may name.
const RULES: [&str; 7] = [
    "wall-clock",
    "unordered-container",
    "ambient-rng",
    "panic-decode",
    "index-decode",
    "decode-alloc",
    "allow-justification",
];

/// Banned whole-word tokens in deterministic modules, with the rule
/// each one trips.
const DET_TOKENS: [(&str, &str); 6] = [
    ("Instant", "wall-clock"),
    ("SystemTime", "wall-clock"),
    ("HashMap", "unordered-container"),
    ("HashSet", "unordered-container"),
    ("thread_rng", "ambient-rng"),
    ("OsRng", "ambient-rng"),
];

/// Allocation constructors banned inside `decode_into` implementations
/// of the wire files (`decode-alloc`): the decode-into contract is that
/// a steady-state round allocates nothing — scratch is reused, never
/// rebuilt.  `vec!` is matched separately as a macro (word + `!`).
const DECODE_ALLOC_TOKENS: [&str; 4] =
    ["Vec::new", "Vec::with_capacity", ".to_vec(", ".collect"];

/// Panic-family macro names flagged in decode scope (each must be
/// followed by `!` to count; `debug_assert*` is deliberately absent —
/// it compiles out of release builds).
const PANIC_MACROS: [&str; 7] = [
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
];

/// One lint finding.  `Display` renders the `file:line: [rule] msg`
/// form the CI gate greps.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule,
               self.message)
    }
}

#[inline]
fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

// ---------------------------------------------------------------------
// Source stripping
// ---------------------------------------------------------------------

/// Lexer state for [`strip_source`].
enum Strip {
    Code,
    LineComment,
    Str,
    RawStr,
    CharLit,
}

/// Blank out strings, char literals, and comments, preserving line
/// structure and column positions, and collect line comments as
/// `(1-based line, text)` pairs (directives live in comments).
///
/// Handles nested block comments, raw strings with any `#` count,
/// byte strings/chars, and the `'a` lifetime-vs-`'a'` char ambiguity
/// (a quote is a char literal only when escaped or closed two chars
/// later).
fn strip_source(src: &str) -> (Vec<String>, Vec<(usize, String)>) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = String::with_capacity(src.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut state = Strip::Code;
    let mut hashes = 0usize;
    let mut cur: Option<(usize, String)> = None;
    let mut prev_code = ' ';
    while i < n {
        let c = s[i];
        let nxt = if i + 1 < n { s[i + 1] } else { '\0' };
        match state {
            Strip::Code => {
                if c == '/' && nxt == '/' {
                    state = Strip::LineComment;
                    cur = Some((line, String::new()));
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    continue;
                }
                if c == '/' && nxt == '*' {
                    let mut depth = 1usize;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    while i < n && depth > 0 {
                        let c2 = s[i];
                        let n2 = if i + 1 < n { s[i + 1] } else { '\0' };
                        if c2 == '/' && n2 == '*' {
                            depth += 1;
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                            continue;
                        }
                        if c2 == '*' && n2 == '/' {
                            depth -= 1;
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                            continue;
                        }
                        if c2 == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        i += 1;
                    }
                    continue;
                }
                if c == '"' {
                    state = Strip::Str;
                    out.push(' ');
                    i += 1;
                    continue;
                }
                if c == 'r' && !is_ident(prev_code) {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && s[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && s[j] == '"' {
                        state = Strip::RawStr;
                        hashes = h;
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                }
                if c == 'b' && !is_ident(prev_code) {
                    if nxt == '"' {
                        state = Strip::Str;
                        out.push(' ');
                        out.push(' ');
                        i += 2;
                        continue;
                    }
                    if nxt == '\'' {
                        state = Strip::CharLit;
                        out.push(' ');
                        out.push(' ');
                        i += 2;
                        continue;
                    }
                    if nxt == 'r' {
                        let mut j = i + 2;
                        let mut h = 0usize;
                        while j < n && s[j] == '#' {
                            h += 1;
                            j += 1;
                        }
                        if j < n && s[j] == '"' {
                            state = Strip::RawStr;
                            hashes = h;
                            for _ in i..=j {
                                out.push(' ');
                            }
                            i = j + 1;
                            continue;
                        }
                    }
                }
                if c == '\'' {
                    if nxt == '\\' {
                        state = Strip::CharLit;
                        out.push(' ');
                        i += 1;
                        continue;
                    }
                    if i + 2 < n && s[i + 2] == '\'' && nxt != '\'' {
                        state = Strip::CharLit;
                        out.push(' ');
                        i += 1;
                        continue;
                    }
                    // Lifetime tick: blank it and move on.
                    out.push(' ');
                    prev_code = ' ';
                    i += 1;
                    continue;
                }
                if c == '\n' {
                    out.push('\n');
                    line += 1;
                    prev_code = ' ';
                } else {
                    out.push(c);
                    prev_code = c;
                }
                i += 1;
            }
            Strip::LineComment => {
                if c == '\n' {
                    if let Some(entry) = cur.take() {
                        comments.push(entry);
                    }
                    state = Strip::Code;
                    out.push('\n');
                    line += 1;
                    prev_code = ' ';
                } else {
                    if let Some((_, text)) = cur.as_mut() {
                        text.push(c);
                    }
                    out.push(' ');
                }
                i += 1;
            }
            Strip::Str => {
                if c == '\\' {
                    out.push(' ');
                    if nxt == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 2;
                    continue;
                }
                if c == '"' {
                    state = Strip::Code;
                    out.push(' ');
                } else if c == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            Strip::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && s[j] == '#' && h < hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        state = Strip::Code;
                        for _ in i..j {
                            out.push(' ');
                        }
                        i = j;
                        continue;
                    }
                }
                if c == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            Strip::CharLit => {
                if c == '\\' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    continue;
                }
                if c == '\'' {
                    state = Strip::Code;
                    out.push(' ');
                } else if c == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
        }
    }
    if let Some(entry) = cur.take() {
        comments.push(entry);
    }
    (out.split('\n').map(str::to_string).collect(), comments)
}

// ---------------------------------------------------------------------
// Token scanning
// ---------------------------------------------------------------------

/// Start offsets of whole-word occurrences of `word` in `line`.
fn find_word(line: &[char], word: &[char]) -> Vec<usize> {
    let mut hits = Vec::new();
    let (n, m) = (line.len(), word.len());
    if m == 0 || n < m {
        return hits;
    }
    let mut k = 0usize;
    while k + m <= n {
        if line[k..k + m] == *word {
            let before_ok = k == 0 || !is_ident(line[k - 1]);
            let after_ok = k + m >= n || !is_ident(line[k + m]);
            if before_ok && after_ok {
                hits.push(k);
            }
            k += m;
        } else {
            k += 1;
        }
    }
    hits
}

/// Is `name` a function whose body is decode/parse scope?
fn decode_scope_fn(name: &str) -> bool {
    name.contains("decode")
        || name.contains("parse")
        || name.starts_with("read")
        || name.starts_with("get_")
}

/// In-line scope event: a `fn name` sighting, a brace, or a `;` (which
/// cancels a pending `fn` from a trait-method declaration).
enum Event {
    Fn(String),
    Open,
    Close,
    Semi,
}

/// Position-ordered scope events on one stripped line.
fn line_events(chars: &[char]) -> Vec<(usize, Event)> {
    let mut events: Vec<(usize, Event)> = Vec::new();
    let fn_word: Vec<char> = vec!['f', 'n'];
    for k in find_word(chars, &fn_word) {
        let mut j = k + 2;
        let start_ws = j;
        while j < chars.len() && chars[j].is_whitespace() {
            j += 1;
        }
        if j == start_ws {
            continue; // `fn` not followed by whitespace: not a def
        }
        if j < chars.len()
            && (chars[j].is_ascii_alphabetic() || chars[j] == '_')
        {
            let st = j;
            while j < chars.len() && is_ident(chars[j]) {
                j += 1;
            }
            events.push((k, Event::Fn(chars[st..j].iter().collect())));
        }
    }
    for (k, &c) in chars.iter().enumerate() {
        match c {
            '{' => events.push((k, Event::Open)),
            '}' => events.push((k, Event::Close)),
            ';' => events.push((k, Event::Semi)),
            _ => {}
        }
    }
    events.sort_by_key(|e| e.0);
    events
}

// ---------------------------------------------------------------------
// The lint proper
// ---------------------------------------------------------------------

/// Lint one source file.  `label` is its path relative to the tree
/// root, `/`-separated — it selects which scoped rules apply.
pub fn lint_source(label: &str, src: &str) -> Vec<Violation> {
    let mut violations: Vec<Violation> = Vec::new();
    let (lines, comments) = strip_source(src);
    let line_chars: Vec<Vec<char>> =
        lines.iter().map(|l| l.chars().collect()).collect();

    // Pass 1: directives.  Map suppressed line -> rule set.
    let mut allows: Vec<(usize, Vec<String>)> = Vec::new();
    let directive = "det:allow";
    for (ln, text) in &comments {
        let t = text.trim_start_matches('/').trim_start_matches('!').trim();
        let Some(rest) = t.strip_prefix(directive) else {
            continue;
        };
        let mut ok_rules: Vec<String> = Vec::new();
        if let Some(body) = rest.strip_prefix('(') {
            if let Some(close) = body.find(')') {
                let rules: Vec<String> = body[..close]
                    .split(',')
                    .map(|r| r.trim().to_string())
                    .collect();
                let tail = body[close + 1..].trim();
                let just = tail.strip_prefix(':').map(str::trim)
                    .unwrap_or("");
                let known =
                    rules.iter().all(|r| RULES.contains(&r.as_str()));
                if !rules.is_empty() && known && !just.is_empty() {
                    ok_rules = rules;
                } else if !known {
                    violations.push(Violation {
                        file: label.to_string(),
                        line: *ln,
                        rule: "allow-justification",
                        message: format!("unknown rule in {directive}"),
                    });
                } else {
                    violations.push(Violation {
                        file: label.to_string(),
                        line: *ln,
                        rule: "allow-justification",
                        message: format!(
                            "{directive} needs `: <justification>`"
                        ),
                    });
                }
            } else {
                violations.push(Violation {
                    file: label.to_string(),
                    line: *ln,
                    rule: "allow-justification",
                    message: format!("unclosed {directive}("),
                });
            }
        } else {
            violations.push(Violation {
                file: label.to_string(),
                line: *ln,
                rule: "allow-justification",
                message: format!("malformed {directive}"),
            });
        }
        if ok_rules.is_empty() {
            continue;
        }
        let on_code =
            *ln <= lines.len() && !lines[*ln - 1].trim().is_empty();
        let target = if on_code {
            Some(*ln)
        } else {
            // Standalone: the next non-blank code line.
            (*ln..lines.len())
                .find(|&j| !lines[j].trim().is_empty())
                .map(|j| j + 1)
        };
        if let Some(t) = target {
            match allows.iter_mut().find(|(l, _)| *l == t) {
                Some((_, rs)) => rs.extend(ok_rules),
                None => allows.push((t, ok_rules)),
            }
        }
    }

    let det = DET_PREFIXES.iter().any(|p| label.starts_with(p));
    let wire = WIRE_FILES.contains(&label);

    // Pass 2: walk lines tracking brace depth, the enclosing-fn stack,
    // and `#[cfg(test)] mod` regions.
    let mut depth = 0i64;
    let mut fn_stack: Vec<(String, i64)> = Vec::new();
    let mut pending_fn: Option<String> = None;
    let mut pending_test = false;
    let mut in_test = false;
    let mut test_depth = 0i64;
    let mod_word: Vec<char> = vec!['m', 'o', 'd'];
    for (idx, chars) in line_chars.iter().enumerate() {
        let ln = idx + 1;
        let line = &lines[idx];
        if line.contains("#[cfg(test)]") {
            pending_test = true;
        }
        if pending_test
            && !find_word(chars, &mod_word).is_empty()
            && line.contains('{')
        {
            in_test = true;
            test_depth = depth;
            pending_test = false;
        }
        let mut pushed_this_line: Option<String> = None;
        for (_, ev) in line_events(chars) {
            match ev {
                Event::Fn(name) => pending_fn = Some(name),
                Event::Open => {
                    depth += 1;
                    if let Some(name) = pending_fn.take() {
                        pushed_this_line = Some(name.clone());
                        fn_stack.push((name, depth));
                    }
                }
                Event::Close => {
                    if fn_stack.last().is_some_and(|t| t.1 == depth) {
                        fn_stack.pop();
                    }
                    depth -= 1;
                }
                Event::Semi => pending_fn = None,
            }
        }
        if in_test && depth <= test_depth {
            // This line closes the test module; skip it too.
            in_test = false;
            continue;
        }
        if in_test {
            continue;
        }
        let ctx_fn: &str = pushed_this_line
            .as_deref()
            .or_else(|| fn_stack.last().map(|t| t.0.as_str()))
            .unwrap_or("");
        let line_allows: &[String] = allows
            .iter()
            .find(|(l, _)| *l == ln)
            .map(|(_, rs)| rs.as_slice())
            .unwrap_or(&[]);
        let mut report = |rule: &'static str, message: String| {
            if !line_allows.iter().any(|r| r == rule) {
                violations.push(Violation {
                    file: label.to_string(),
                    line: ln,
                    rule,
                    message,
                });
            }
        };
        if det {
            for (word, rule) in DET_TOKENS {
                let w: Vec<char> = word.chars().collect();
                for _ in find_word(chars, &w) {
                    report(rule,
                           format!("`{word}` in deterministic module"));
                }
            }
        }
        if wire && decode_scope_fn(ctx_fn) {
            if line.contains(".unwrap()") {
                report("panic-decode",
                       format!("`.unwrap()` in decode path fn `{ctx_fn}`"));
            }
            if line.contains(".expect(") {
                report(
                    "panic-decode",
                    format!("`.expect(...)` in decode path fn `{ctx_fn}`"),
                );
            }
            for mac in PANIC_MACROS {
                let w: Vec<char> = mac.chars().collect();
                for k in find_word(chars, &w) {
                    let bang = chars[k + mac.len()..]
                        .iter()
                        .find(|c| !c.is_whitespace());
                    if bang == Some(&'!') {
                        report(
                            "panic-decode",
                            format!(
                                "`{mac}!` in decode path fn `{ctx_fn}`"
                            ),
                        );
                    }
                }
            }
            let mut hits = 0usize;
            for (k, &c) in chars.iter().enumerate() {
                if c != '[' {
                    continue;
                }
                let mut j = k as i64 - 1;
                while j >= 0 && chars[j as usize] == ' ' {
                    j -= 1;
                }
                if j >= 0 {
                    let p = chars[j as usize];
                    if is_ident(p) || p == ')' || p == ']' {
                        hits += 1;
                    }
                }
            }
            if hits > 0 {
                report(
                    "index-decode",
                    format!(
                        "direct indexing in decode path fn `{ctx_fn}` \
                         ({hits}x)"
                    ),
                );
            }
        }
        if wire && ctx_fn.contains("decode_into") {
            for tok in DECODE_ALLOC_TOKENS {
                if line.contains(tok) {
                    report(
                        "decode-alloc",
                        format!(
                            "`{tok}` allocates in decode_into fn \
                             `{ctx_fn}`"
                        ),
                    );
                }
            }
            let vec_word: Vec<char> = vec!['v', 'e', 'c'];
            for k in find_word(chars, &vec_word) {
                let bang =
                    chars[k + 3..].iter().find(|c| !c.is_whitespace());
                if bang == Some(&'!') {
                    report(
                        "decode-alloc",
                        format!(
                            "`vec!` allocates in decode_into fn \
                             `{ctx_fn}`"
                        ),
                    );
                }
            }
        }
    }
    violations
}

/// Lint every `.rs` file under `root` (labels are `/`-relative paths).
/// Deterministic order: files before subdirectories, each sorted.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<Violation>)
        -> io::Result<()> {
    let mut files: Vec<PathBuf> = Vec::new();
    let mut dirs: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            dirs.push(path);
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
    files.sort();
    dirs.sort();
    for path in files {
        let src = fs::read_to_string(&path)?;
        let label = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(lint_source(&label, &src));
    }
    for sub in dirs {
        walk(root, &sub, out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_blanks_strings_and_comments_but_keeps_columns() {
        let src = "let a = \"x[0]\"; // c[1]\nlet b = a[2];\n";
        let (lines, comments) = strip_source(src);
        // Same width, string/comment chars blanked, `;` still at col 14.
        assert_eq!(lines[0].len(), "let a = \"x[0]\"; // c[1]".len());
        assert!(!lines[0].contains('"') && !lines[0].contains('c'));
        assert_eq!(lines[0].chars().nth(14), Some(';'));
        assert_eq!(lines[1], "let b = a[2];");
        assert_eq!(comments, vec![(1, " c[1]".to_string())]);
    }

    #[test]
    fn strip_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"un\"wrap()\"#; }";
        let (lines, _) = strip_source(src);
        assert!(!lines[0].contains("wrap"), "{}", lines[0]);
        assert!(lines[0].contains("fn f"), "{}", lines[0]);
    }

    #[test]
    fn strip_handles_nested_block_comments() {
        let src = "a /* x /* y */ z */ b";
        let (lines, _) = strip_source(src);
        assert_eq!(lines[0].len(), src.len());
        assert!(lines[0].starts_with('a') && lines[0].ends_with('b'));
        assert!(!lines[0].contains('x') && !lines[0].contains('z'));
    }

    #[test]
    fn find_word_is_whole_word() {
        let chars: Vec<char> = "Instant InstantX x_Instant".chars()
            .collect();
        let w: Vec<char> = "Instant".chars().collect();
        assert_eq!(find_word(&chars, &w), vec![0]);
    }

    #[test]
    fn decode_scope_names() {
        assert!(decode_scope_fn("decode"));
        assert!(decode_scope_fn("decode_sparse"));
        assert!(decode_scope_fn("read_message"));
        assert!(decode_scope_fn("get_u32"));
        assert!(decode_scope_fn("parse_header"));
        // `read*` is scope by prefix — `ready` rides along, by design:
        // over-approximating scope is safe (an allow fixes it).
        assert!(decode_scope_fn("ready"));
        assert!(!decode_scope_fn("encode"));
        assert!(!decode_scope_fn("write_message"));
    }
}
