//! Byte-metered in-process message bus.
//!
//! The paper's testbed is 8 GPU workers over gloo; here each node is a
//! thread and each undirected edge is a pair of unbounded channels.  The
//! meter counts exactly the bytes a network transport would carry for
//! each payload (dense f32 tensors, COO index+value pairs), which is the
//! quantity the paper's tables report (“amount of parameters sent per
//! epoch”).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::compress::CooVec;
use crate::graph::Graph;

/// What can cross an edge.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Dense f32 payload (model parameters, dual variables, PG halves).
    Dense(Vec<f32>),
    /// Sparse COO payload (compressed dual updates).
    Sparse(CooVec),
    /// Scalar control value (losses for aggregation etc.).
    Scalar(f64),
}

impl Msg {
    /// Bytes a real transport would carry (paper accounting; headers
    /// excluded on all payloads equally).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Msg::Dense(v) => 4 * v.len(),
            Msg::Sparse(c) => c.wire_bytes(),
            Msg::Scalar(_) => 8,
        }
    }

    pub fn into_dense(self) -> Vec<f32> {
        match self {
            Msg::Dense(v) => v,
            Msg::Sparse(c) => c.to_dense(),
            Msg::Scalar(_) => panic!("expected tensor payload, got scalar"),
        }
    }

    pub fn into_sparse(self) -> CooVec {
        match self {
            Msg::Sparse(c) => c,
            _ => panic!("expected sparse payload"),
        }
    }
}

/// Per-node byte counters, shared with the coordinator for reporting.
#[derive(Debug, Default)]
pub struct Meter {
    /// Total bytes sent by each node.
    sent: Vec<AtomicU64>,
    /// Number of messages sent by each node.
    msgs: Vec<AtomicU64>,
}

impl Meter {
    pub fn new(n: usize) -> Arc<Meter> {
        Arc::new(Meter {
            sent: (0..n).map(|_| AtomicU64::new(0)).collect(),
            msgs: (0..n).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    pub fn record_send(&self, node: usize, bytes: usize) {
        self.sent[node].fetch_add(bytes as u64, Ordering::Relaxed);
        self.msgs[node].fetch_add(1, Ordering::Relaxed);
    }

    pub fn bytes_sent(&self, node: usize) -> u64 {
        self.sent[node].load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        self.sent.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Mean bytes sent per node.
    pub fn mean_bytes_per_node(&self) -> f64 {
        self.total_bytes() as f64 / self.sent.len() as f64
    }

    pub fn reset(&self) {
        for a in self.sent.iter().chain(self.msgs.iter()) {
            a.store(0, Ordering::Relaxed);
        }
    }
}

/// One node's endpoint: senders/receivers keyed by neighbor id.
pub struct NodeComm {
    pub node: usize,
    senders: BTreeMap<usize, Sender<Msg>>,
    receivers: BTreeMap<usize, Receiver<Msg>>,
    meter: Arc<Meter>,
}

impl NodeComm {
    /// Send to a neighbor, metering the payload.
    pub fn send(&self, to: usize, msg: Msg) {
        self.meter.record_send(self.node, msg.wire_bytes());
        self.senders
            .get(&to)
            .unwrap_or_else(|| panic!("node {} has no edge to {to}", self.node))
            .send(msg)
            .expect("peer hung up");
    }

    /// Blocking receive from a neighbor.
    pub fn recv(&self, from: usize) -> Msg {
        self.receivers
            .get(&from)
            .unwrap_or_else(|| panic!("node {} has no edge to {from}", self.node))
            .recv()
            .expect("peer hung up")
    }

    pub fn neighbors(&self) -> Vec<usize> {
        self.senders.keys().copied().collect()
    }
}

/// Build the full bus for a graph: one `NodeComm` per node plus the
/// shared meter.
pub fn build_bus(graph: &Graph) -> (Vec<NodeComm>, Arc<Meter>) {
    let n = graph.n();
    let meter = Meter::new(n);
    let mut senders: Vec<BTreeMap<usize, Sender<Msg>>> =
        (0..n).map(|_| BTreeMap::new()).collect();
    let mut receivers: Vec<BTreeMap<usize, Receiver<Msg>>> =
        (0..n).map(|_| BTreeMap::new()).collect();
    for &(i, j) in graph.edges() {
        let (tx_ij, rx_ij) = channel();
        let (tx_ji, rx_ji) = channel();
        senders[i].insert(j, tx_ij);
        receivers[j].insert(i, rx_ij);
        senders[j].insert(i, tx_ji);
        receivers[i].insert(j, rx_ji);
    }
    let comms = senders
        .into_iter()
        .zip(receivers)
        .enumerate()
        .map(|(node, (s, r))| NodeComm {
            node,
            senders: s,
            receivers: r,
            meter: Arc::clone(&meter),
        })
        .collect();
    (comms, meter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn messages_route_and_meter() {
        let g = Graph::ring(4);
        let (mut comms, meter) = build_bus(&g);
        let c3 = comms.pop().unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();

        c0.send(1, Msg::Dense(vec![1.0, 2.0, 3.0]));
        let got = c1.recv(0).into_dense();
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
        assert_eq!(meter.bytes_sent(0), 12);
        assert_eq!(meter.bytes_sent(1), 0);

        let coo = CooVec::gather(&[5.0, 6.0, 7.0], &[0, 2]);
        c2.send(3, Msg::Sparse(coo.clone()));
        let got = c3.recv(2).into_sparse();
        assert_eq!(got, coo);
        assert_eq!(meter.bytes_sent(2), 16);
        assert_eq!(meter.total_bytes(), 28);
        assert_eq!(meter.total_msgs(), 2);

        meter.reset();
        assert_eq!(meter.total_bytes(), 0);
    }

    #[test]
    fn full_duplex_per_edge() {
        let g = Graph::chain(2);
        let (mut comms, _meter) = build_bus(&g);
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        // Both directions can be in flight simultaneously (the exchange
        // pattern in every algorithm: send to all neighbors, then recv).
        c0.send(1, Msg::Scalar(1.0));
        c1.send(0, Msg::Scalar(2.0));
        assert!(matches!(c0.recv(1), Msg::Scalar(v) if v == 2.0));
        assert!(matches!(c1.recv(0), Msg::Scalar(v) if v == 1.0));
    }

    #[test]
    fn neighbors_match_graph() {
        let g = Graph::star(5);
        let (comms, _) = build_bus(&g);
        assert_eq!(comms[0].neighbors(), vec![1, 2, 3, 4]);
        assert_eq!(comms[3].neighbors(), vec![0]);
    }

    #[test]
    #[should_panic(expected = "no edge")]
    fn non_edge_send_panics() {
        let g = Graph::chain(3);
        let (comms, _) = build_bus(&g);
        comms[0].send(2, Msg::Scalar(0.0));
    }

    #[test]
    fn threaded_exchange() {
        // The real usage pattern: one thread per node, synchronized
        // exchange rounds.
        let g = Graph::ring(8);
        let (comms, meter) = build_bus(&g);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    for &j in &c.neighbors() {
                        c.send(j, Msg::Dense(vec![c.node as f32; 10]));
                    }
                    let mut sum = 0.0;
                    for &j in &c.neighbors() {
                        sum += c.recv(j).into_dense()[0];
                    }
                    sum
                })
            })
            .collect();
        let sums: Vec<f64> = handles
            .into_iter()
            .map(|h| h.join().unwrap() as f64)
            .collect();
        // Node i receives from ring neighbors (i±1 mod 8).
        for (i, s) in sums.iter().enumerate() {
            let want = ((i + 1) % 8 + (i + 8 - 1) % 8) as f64;
            assert_eq!(*s, want);
        }
        // 8 nodes x 2 neighbors x 40 bytes.
        assert_eq!(meter.total_bytes(), 8 * 2 * 40);
    }
}
