//! Byte-metered communication substrate shared by both execution
//! engines.
//!
//! The paper's testbed is 8 GPU workers over gloo; here the same wire
//! protocol runs over two interchangeable transports:
//!
//! * the **threaded bus** ([`build_bus`]): one OS thread per node, each
//!   undirected edge a pair of unbounded channels ([`NodeComm`]);
//! * the **virtual-time engine** (`crate::sim`): single-threaded,
//!   event-driven delivery of [`Envelope`]s collected through an
//!   [`Outbox`].
//!
//! The shared [`Meter`] counts exactly the bytes a network transport
//! would carry for each payload (dense f32 tensors, COO index+value
//! pairs) — the quantity the paper's tables report (“amount of
//! parameters sent per epoch”) — plus, under the simulator, retransmit
//! bytes and the virtual clock.
//!
//! All fallible operations return typed [`CommError`]s (convertible into
//! `anyhow::Error`), never panic.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::compress::{CooVec, Frame};
use crate::graph::Graph;

/// What can cross an edge.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Dense f32 payload (model parameters, dual variables, PG halves).
    Dense(Vec<f32>),
    /// Sparse COO payload (PJRT interop; the codec wire uses `Frame`).
    Sparse(CooVec),
    /// Encoded codec frame (compressed dual updates): an owned byte
    /// buffer whose length *is* the metered wire size — decoded by the
    /// per-edge `EdgeCodec` at the receiver.
    Frame(Frame),
    /// Scalar control value (losses for aggregation etc.).
    Scalar(f64),
}

/// Typed communication failure (satisfies `std::error::Error`, so `?`
/// lifts it into `anyhow::Result` at every call site).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommError {
    /// A payload of the wrong variant arrived (protocol bug).
    WrongPayload {
        expected: &'static str,
        got: &'static str,
    },
    /// Send/recv on a pair that is not an edge of the graph.
    NoEdge { node: usize, peer: usize },
    /// The peer's endpoint was dropped (its thread exited or panicked).
    Disconnected { node: usize, peer: usize },
    /// A payload failed validation while decoding (corrupt indices,
    /// truncated frame) — carries the codec layer's rendered error.
    Corrupt { detail: String },
    /// The frame was in flight when its edge churned out of the
    /// topology (or the edge was reborn into a new epoch before
    /// delivery): the virtual-time engine drains it as a typed drop
    /// instead of delivering cross-incarnation state.
    ChurnDropped { src: usize, dst: usize, edge: usize },
    /// The socket layer failed mid-stream (reset, refused dial, short
    /// write).  The net engine maps this onto the churn lifecycle —
    /// the same per-edge teardown as `DownKind::Churn` — instead of
    /// panicking or deadlocking.
    Io { detail: String },
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::WrongPayload { expected, got } => {
                write!(f, "expected {expected} payload, got {got}")
            }
            CommError::NoEdge { node, peer } => {
                write!(f, "node {node} has no edge to {peer}")
            }
            CommError::Disconnected { node, peer } => {
                write!(f, "node {node}: peer {peer} hung up")
            }
            CommError::Corrupt { detail } => {
                write!(f, "corrupt payload: {detail}")
            }
            CommError::ChurnDropped { src, dst, edge } => {
                write!(
                    f,
                    "frame {src}->{dst} dropped: edge {edge} churned \
                     out of the topology in flight"
                )
            }
            CommError::Io { detail } => {
                write!(f, "socket error: {detail}")
            }
        }
    }
}

impl std::error::Error for CommError {}

impl Msg {
    /// Bytes a real transport would carry (paper accounting; headers
    /// excluded on all payloads equally).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Msg::Dense(v) => 4 * v.len(),
            Msg::Sparse(c) => c.wire_bytes(),
            Msg::Frame(f) => f.wire_bytes(),
            Msg::Scalar(_) => 8,
        }
    }

    /// Variant name for error reporting.
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Dense(_) => "dense",
            Msg::Sparse(_) => "sparse",
            Msg::Frame(_) => "frame",
            Msg::Scalar(_) => "scalar",
        }
    }

    /// Tensor payload as a dense vector (sparse payloads materialize
    /// after index validation — a corrupt index is a typed error, never
    /// a panic).  Frames need their edge codec and cannot densify here.
    pub fn into_dense(self) -> Result<Vec<f32>, CommError> {
        match self {
            Msg::Dense(v) => Ok(v),
            Msg::Sparse(c) => c.try_to_dense().map_err(|e| CommError::Corrupt {
                detail: e.to_string(),
            }),
            other => Err(CommError::WrongPayload {
                expected: "tensor",
                got: other.kind(),
            }),
        }
    }

    /// Sparse payload, or a typed error for any other variant.
    pub fn into_sparse(self) -> Result<CooVec, CommError> {
        match self {
            Msg::Sparse(c) => Ok(c),
            other => Err(CommError::WrongPayload {
                expected: "sparse",
                got: other.kind(),
            }),
        }
    }

    /// Codec frame, or a typed error for any other variant.
    pub fn into_frame(self) -> Result<Frame, CommError> {
        match self {
            Msg::Frame(f) => Ok(f),
            other => Err(CommError::WrongPayload {
                expected: "frame",
                got: other.kind(),
            }),
        }
    }
}

/// Delivery envelope used by the virtual-time engine: the payload plus
/// the routing and round metadata the scheduler needs to buffer and
/// order messages.
#[derive(Debug, Clone)]
pub struct Envelope {
    pub src: usize,
    pub dst: usize,
    /// The **sender's** round clock when the message was queued — the
    /// per-edge round stamp.  Under `RoundPolicy::Sync` the engine
    /// delivers only stamps matching the receiver's round (buffering
    /// the rest); under `Async` the stamp is handed to the machine
    /// as-is, which uses it to key shared-seed codec state.
    pub round: usize,
    /// The edge incarnation (`EdgeLife::epoch`) at send time.  A frame
    /// whose epoch no longer matches the edge at delivery time was in
    /// flight across a churn event and drains as a typed drop — stale
    /// incarnation state can never be delivered.
    pub epoch: u32,
    pub payload: Msg,
}

/// Outbound message queue filled by the poll-driven state machines
/// (`algorithms::NodeStateMachine`); drained by whichever engine is
/// driving the node.
#[derive(Debug, Default)]
pub struct Outbox {
    queued: Vec<(usize, Msg)>,
}

impl Outbox {
    pub fn new() -> Outbox {
        Outbox::default()
    }

    /// Queue a message for neighbor `to`.
    pub fn send(&mut self, to: usize, msg: Msg) {
        self.queued.push((to, msg));
    }

    pub fn len(&self) -> usize {
        self.queued.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queued.is_empty()
    }

    /// Drain all queued `(dest, payload)` pairs in send order.
    pub fn drain(&mut self) -> std::vec::Drain<'_, (usize, Msg)> {
        self.queued.drain(..)
    }
}

/// Per-node communication counters, shared with the coordinator for
/// reporting.  Under the virtual-time engine the meter additionally
/// tracks retransmitted bytes (lossy links) and the virtual clock.
#[derive(Debug, Default)]
pub struct Meter {
    /// Total payload bytes sent by each node (first-transmission only).
    sent: Vec<AtomicU64>,
    /// Number of messages sent by each node.
    msgs: Vec<AtomicU64>,
    /// Extra bytes burned on retransmissions by each node (lossy links).
    retrans: Vec<AtomicU64>,
    /// High-water mark of the virtual clock, in nanoseconds (0 under the
    /// threaded engine).
    vtime_ns: AtomicU64,
    /// Frames dropped by topology churn (in flight on a removed edge or
    /// across an epoch change).  Their payload bytes stay in `sent` —
    /// the transmission happened; the delivery did not.
    churn_dropped_frames: AtomicU64,
    /// Payload bytes of those dropped frames.
    churn_dropped_bytes: AtomicU64,
    /// Edge lifecycle transitions (kills + revivals) applied by the
    /// engine.
    edges_churned: AtomicU64,
    /// Framing overhead bytes per node (wire headers on the net engine;
    /// always 0 under the in-process engines, whose channels carry no
    /// framing).  Kept apart from `sent` so payload accounting — the
    /// quantity the paper reports and the byte-identity tests pin —
    /// stays comparable across all three engines.
    header: Vec<AtomicU64>,
    /// Payload bytes per *directed* edge, indexed by
    /// [`directed_edge_index`].  Empty unless the meter was built with
    /// [`Meter::with_edges`]; the sim and net engines enable it so the
    /// net engine's measured per-edge bytes can be checked against the
    /// sim's prediction.
    edge_sent: Vec<AtomicU64>,
}

/// Index of the directed slot for canonical edge `edge = (i, j)`,
/// `i < j`: slot `2*edge` carries `i -> j` traffic, slot `2*edge + 1`
/// carries `j -> i`.
pub fn directed_edge_index(edge: usize, src: usize, dst: usize) -> usize {
    2 * edge + usize::from(src > dst)
}

impl Meter {
    pub fn new(n: usize) -> Arc<Meter> {
        Meter::with_edges(n, 0)
    }

    /// A meter that additionally tracks payload bytes per directed edge
    /// (`2 * edge_count` slots).  `new` leaves that tracking disabled.
    pub fn with_edges(n: usize, edge_count: usize) -> Arc<Meter> {
        Arc::new(Meter {
            sent: (0..n).map(|_| AtomicU64::new(0)).collect(),
            msgs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            retrans: (0..n).map(|_| AtomicU64::new(0)).collect(),
            vtime_ns: AtomicU64::new(0),
            churn_dropped_frames: AtomicU64::new(0),
            churn_dropped_bytes: AtomicU64::new(0),
            edges_churned: AtomicU64::new(0),
            header: (0..n).map(|_| AtomicU64::new(0)).collect(),
            edge_sent: (0..2 * edge_count).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    pub fn record_send(&self, node: usize, bytes: usize) {
        self.sent[node].fetch_add(bytes as u64, Ordering::Relaxed);
        self.msgs[node].fetch_add(1, Ordering::Relaxed);
    }

    /// Account bytes burned on retransmissions (beyond the first copy).
    pub fn record_retransmit(&self, node: usize, bytes: u64) {
        self.retrans[node].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Account framing overhead (wire headers) for `node`, separate from
    /// payload bytes.
    pub fn record_header_overhead(&self, node: usize, bytes: u64) {
        self.header[node].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Account payload bytes on a directed edge slot (see
    /// [`directed_edge_index`]).  A no-op unless the meter was built
    /// with [`Meter::with_edges`].
    pub fn record_edge_send(&self, dir_edge: usize, bytes: u64) {
        if let Some(slot) = self.edge_sent.get(dir_edge) {
            slot.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    pub fn header_overhead_bytes(&self, node: usize) -> u64 {
        self.header[node].load(Ordering::Relaxed)
    }

    pub fn total_header_overhead_bytes(&self) -> u64 {
        self.header.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Payload bytes per directed edge, or `None` if the meter was not
    /// built with per-edge tracking.
    pub fn edge_payload_bytes(&self) -> Option<Vec<u64>> {
        if self.edge_sent.is_empty() {
            return None;
        }
        Some(
            self.edge_sent
                .iter()
                .map(|a| a.load(Ordering::Relaxed))
                .collect(),
        )
    }

    /// Account a frame drained by topology churn (typed drop, not an
    /// error): sent-byte accounting is untouched, only the loss is
    /// counted.
    pub fn record_churn_drop(&self, bytes: u64) {
        self.churn_dropped_frames.fetch_add(1, Ordering::Relaxed);
        self.churn_dropped_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Account one edge lifecycle transition (kill or revival).
    pub fn record_edge_churn(&self) {
        self.edges_churned.fetch_add(1, Ordering::Relaxed);
    }

    pub fn churn_dropped_frames(&self) -> u64 {
        self.churn_dropped_frames.load(Ordering::Relaxed)
    }

    pub fn churn_dropped_bytes(&self) -> u64 {
        self.churn_dropped_bytes.load(Ordering::Relaxed)
    }

    pub fn edges_churned(&self) -> u64 {
        self.edges_churned.load(Ordering::Relaxed)
    }

    /// Advance the virtual clock high-water mark.
    pub fn advance_vtime_ns(&self, t_ns: u64) {
        self.vtime_ns.fetch_max(t_ns, Ordering::Relaxed);
    }

    pub fn vtime_ns(&self) -> u64 {
        self.vtime_ns.load(Ordering::Relaxed)
    }

    pub fn bytes_sent(&self, node: usize) -> u64 {
        self.sent[node].load(Ordering::Relaxed)
    }

    pub fn retransmit_bytes(&self, node: usize) -> u64 {
        self.retrans[node].load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        self.sent.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn total_retransmit_bytes(&self) -> u64 {
        self.retrans.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Mean payload bytes sent per node.
    pub fn mean_bytes_per_node(&self) -> f64 {
        self.total_bytes() as f64 / self.sent.len() as f64
    }

    pub fn reset(&self) {
        for a in self
            .sent
            .iter()
            .chain(self.msgs.iter())
            .chain(self.retrans.iter())
            .chain(self.header.iter())
            .chain(self.edge_sent.iter())
        {
            a.store(0, Ordering::Relaxed);
        }
        self.vtime_ns.store(0, Ordering::Relaxed);
        self.churn_dropped_frames.store(0, Ordering::Relaxed);
        self.churn_dropped_bytes.store(0, Ordering::Relaxed);
        self.edges_churned.store(0, Ordering::Relaxed);
    }
}

/// One node's endpoint on the threaded bus: senders/receivers keyed by
/// neighbor id.
pub struct NodeComm {
    pub node: usize,
    senders: BTreeMap<usize, Sender<Msg>>,
    receivers: BTreeMap<usize, Receiver<Msg>>,
    meter: Arc<Meter>,
}

impl NodeComm {
    /// Send to a neighbor, metering the payload.  Failed sends are not
    /// metered.
    pub fn send(&self, to: usize, msg: Msg) -> Result<(), CommError> {
        let tx = self.senders.get(&to).ok_or(CommError::NoEdge {
            node: self.node,
            peer: to,
        })?;
        let bytes = msg.wire_bytes();
        tx.send(msg).map_err(|_| CommError::Disconnected {
            node: self.node,
            peer: to,
        })?;
        self.meter.record_send(self.node, bytes);
        Ok(())
    }

    /// Blocking receive from a neighbor.
    pub fn recv(&self, from: usize) -> Result<Msg, CommError> {
        self.receivers
            .get(&from)
            .ok_or(CommError::NoEdge {
                node: self.node,
                peer: from,
            })?
            .recv()
            .map_err(|_| CommError::Disconnected {
                node: self.node,
                peer: from,
            })
    }

    pub fn neighbors(&self) -> Vec<usize> {
        self.senders.keys().copied().collect()
    }
}

/// Build the full bus for a graph: one `NodeComm` per node plus the
/// shared meter.
pub fn build_bus(graph: &Graph) -> (Vec<NodeComm>, Arc<Meter>) {
    let n = graph.n();
    let meter = Meter::new(n);
    let mut senders: Vec<BTreeMap<usize, Sender<Msg>>> =
        (0..n).map(|_| BTreeMap::new()).collect();
    let mut receivers: Vec<BTreeMap<usize, Receiver<Msg>>> =
        (0..n).map(|_| BTreeMap::new()).collect();
    for &(i, j) in graph.edges() {
        let (tx_ij, rx_ij) = channel();
        let (tx_ji, rx_ji) = channel();
        senders[i].insert(j, tx_ij);
        receivers[j].insert(i, rx_ij);
        senders[j].insert(i, tx_ji);
        receivers[i].insert(j, rx_ji);
    }
    let comms = senders
        .into_iter()
        .zip(receivers)
        .enumerate()
        .map(|(node, (s, r))| NodeComm {
            node,
            senders: s,
            receivers: r,
            meter: Arc::clone(&meter),
        })
        .collect();
    (comms, meter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn messages_route_and_meter() {
        let g = Graph::ring(4);
        let (mut comms, meter) = build_bus(&g);
        let c3 = comms.pop().unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();

        c0.send(1, Msg::Dense(vec![1.0, 2.0, 3.0])).unwrap();
        let got = c1.recv(0).unwrap().into_dense().unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
        assert_eq!(meter.bytes_sent(0), 12);
        assert_eq!(meter.bytes_sent(1), 0);

        let coo = CooVec::gather(&[5.0, 6.0, 7.0], &[0, 2]);
        c2.send(3, Msg::Sparse(coo.clone())).unwrap();
        let got = c3.recv(2).unwrap().into_sparse().unwrap();
        assert_eq!(got, coo);
        assert_eq!(meter.bytes_sent(2), 16);
        assert_eq!(meter.total_bytes(), 28);
        assert_eq!(meter.total_msgs(), 2);

        meter.reset();
        assert_eq!(meter.total_bytes(), 0);
    }

    #[test]
    fn full_duplex_per_edge() {
        let g = Graph::chain(2);
        let (mut comms, _meter) = build_bus(&g);
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        // Both directions can be in flight simultaneously (the exchange
        // pattern in every algorithm: send to all neighbors, then recv).
        c0.send(1, Msg::Scalar(1.0)).unwrap();
        c1.send(0, Msg::Scalar(2.0)).unwrap();
        assert!(matches!(c0.recv(1), Ok(Msg::Scalar(v)) if v == 2.0));
        assert!(matches!(c1.recv(0), Ok(Msg::Scalar(v)) if v == 1.0));
    }

    #[test]
    fn neighbors_match_graph() {
        let g = Graph::star(5);
        let (comms, _) = build_bus(&g);
        assert_eq!(comms[0].neighbors(), vec![1, 2, 3, 4]);
        assert_eq!(comms[3].neighbors(), vec![0]);
    }

    #[test]
    fn non_edge_send_and_recv_error() {
        let g = Graph::chain(3);
        let (comms, meter) = build_bus(&g);
        let err = comms[0].send(2, Msg::Scalar(0.0)).unwrap_err();
        assert_eq!(err, CommError::NoEdge { node: 0, peer: 2 });
        let err = comms[0].recv(2).unwrap_err();
        assert_eq!(err, CommError::NoEdge { node: 0, peer: 2 });
        // Failed sends must not be metered.
        assert_eq!(meter.total_bytes(), 0);
        assert_eq!(meter.total_msgs(), 0);
    }

    #[test]
    fn hung_up_peer_errors() {
        let g = Graph::chain(2);
        let (mut comms, _) = build_bus(&g);
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c1); // peer thread "exits"
        let err = c0.send(1, Msg::Scalar(1.0)).unwrap_err();
        assert_eq!(err, CommError::Disconnected { node: 0, peer: 1 });
        let err = c0.recv(1).unwrap_err();
        assert_eq!(err, CommError::Disconnected { node: 0, peer: 1 });
    }

    #[test]
    fn wrong_payload_errors() {
        let err = Msg::Scalar(1.0).into_dense().unwrap_err();
        assert_eq!(
            err,
            CommError::WrongPayload { expected: "tensor", got: "scalar" }
        );
        let err = Msg::Dense(vec![1.0]).into_sparse().unwrap_err();
        assert_eq!(
            err,
            CommError::WrongPayload { expected: "sparse", got: "dense" }
        );
        // Errors interop with anyhow (the coordinator's error channel).
        let any: anyhow::Error = err.into();
        assert!(any.to_string().contains("sparse"));
    }

    #[test]
    fn comm_errors_display() {
        assert_eq!(
            CommError::NoEdge { node: 3, peer: 7 }.to_string(),
            "node 3 has no edge to 7"
        );
        assert_eq!(
            CommError::Disconnected { node: 1, peer: 2 }.to_string(),
            "node 1: peer 2 hung up"
        );
    }

    #[test]
    fn outbox_queues_in_order() {
        let mut out = Outbox::new();
        assert!(out.is_empty());
        out.send(3, Msg::Scalar(1.0));
        out.send(1, Msg::Scalar(2.0));
        assert_eq!(out.len(), 2);
        let drained: Vec<(usize, Msg)> = out.drain().collect();
        assert_eq!(drained[0].0, 3);
        assert_eq!(drained[1].0, 1);
        assert!(out.is_empty());
    }

    #[test]
    fn frames_route_and_meter_by_buffer_length() {
        use crate::compress::{CodecSpec, EdgeCtx, WireMode};
        let g = Graph::chain(2);
        let (mut comms, meter) = build_bus(&g);
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let spec = CodecSpec::RandK { k_frac: 0.5, mode: WireMode::Explicit };
        let mut codec = spec.build();
        let ctx = EdgeCtx {
            seed: 1,
            edge: 0,
            round: 0,
            receiver: 1,
            dim: 64,
            epoch: 0,
        };
        let x: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let frame = codec.encode(&x, &ctx);
        let want_bytes = frame.wire_bytes();
        assert!(want_bytes > 0 && want_bytes % 8 == 0);
        c0.send(1, Msg::Frame(frame)).unwrap();
        // Metered size is the serialized buffer length, nothing inferred.
        assert_eq!(meter.bytes_sent(0) as usize, want_bytes);
        let got = c1.recv(0).unwrap().into_frame().unwrap();
        assert_eq!(got.wire_bytes(), want_bytes);
        assert_eq!(codec.decode(&got, &ctx).unwrap().len(), 64);
        // Frames are not densifiable without their codec.
        let err = Msg::Frame(got).into_dense().unwrap_err();
        assert_eq!(
            err,
            CommError::WrongPayload { expected: "tensor", got: "frame" }
        );
    }

    #[test]
    fn corrupt_sparse_payload_is_typed_error() {
        let mut coo = CooVec::gather(&[1.0, 2.0, 3.0], &[0, 2]);
        coo.idx[1] = 999; // corruption past the trust boundary
        let err = Msg::Sparse(coo).into_dense().unwrap_err();
        assert!(matches!(err, CommError::Corrupt { .. }), "{err}");
        assert!(err.to_string().contains("out of range"));
    }

    #[test]
    fn meter_retransmit_and_vtime() {
        let m = Meter::new(2);
        m.record_send(0, 100);
        m.record_retransmit(0, 40);
        m.record_retransmit(1, 10);
        assert_eq!(m.retransmit_bytes(0), 40);
        assert_eq!(m.total_retransmit_bytes(), 50);
        // Payload accounting stays first-copy-only.
        assert_eq!(m.total_bytes(), 100);
        m.advance_vtime_ns(500);
        m.advance_vtime_ns(200); // high-water mark, never regresses
        assert_eq!(m.vtime_ns(), 500);
        m.reset();
        assert_eq!(m.total_retransmit_bytes(), 0);
        assert_eq!(m.vtime_ns(), 0);
    }

    #[test]
    fn meter_churn_counters_are_byte_exact_and_resettable() {
        let m = Meter::new(2);
        m.record_send(0, 64);
        // The frame drops in flight: the send stays metered (the bytes
        // left the NIC), the loss is counted separately.
        m.record_churn_drop(64);
        m.record_edge_churn();
        m.record_edge_churn();
        assert_eq!(m.total_bytes(), 64);
        assert_eq!(m.churn_dropped_frames(), 1);
        assert_eq!(m.churn_dropped_bytes(), 64);
        assert_eq!(m.edges_churned(), 2);
        m.reset();
        assert_eq!(m.churn_dropped_frames(), 0);
        assert_eq!(m.churn_dropped_bytes(), 0);
        assert_eq!(m.edges_churned(), 0);
        // The typed drop renders with its route.
        let e = CommError::ChurnDropped { src: 1, dst: 0, edge: 3 };
        assert!(e.to_string().contains("edge 3"), "{e}");
    }

    #[test]
    fn meter_splits_header_overhead_from_payload() {
        let m = Meter::new(2);
        m.record_send(0, 100);
        m.record_header_overhead(0, 24);
        m.record_header_overhead(1, 24);
        // Payload accounting — what the byte-identity tests pin — is
        // untouched by framing overhead.
        assert_eq!(m.total_bytes(), 100);
        assert_eq!(m.header_overhead_bytes(0), 24);
        assert_eq!(m.total_header_overhead_bytes(), 48);
        m.reset();
        assert_eq!(m.total_header_overhead_bytes(), 0);
    }

    #[test]
    fn meter_per_edge_tracking_is_opt_in() {
        // Default meter: per-edge slots disabled, recording is a no-op.
        let plain = Meter::new(2);
        plain.record_edge_send(0, 99);
        assert!(plain.edge_payload_bytes().is_none());

        // Edge-tracking meter: directed slots, byte-exact.
        let m = Meter::with_edges(3, 2);
        // Canonical edge 1 = (i, j); i -> j lands in slot 2, j -> i in 3.
        assert_eq!(directed_edge_index(1, 0, 2), 2);
        assert_eq!(directed_edge_index(1, 2, 0), 3);
        m.record_edge_send(directed_edge_index(1, 0, 2), 40);
        m.record_edge_send(directed_edge_index(1, 2, 0), 8);
        m.record_edge_send(directed_edge_index(0, 1, 0), 16);
        assert_eq!(m.edge_payload_bytes(), Some(vec![0, 16, 40, 8]));
        m.reset();
        assert_eq!(m.edge_payload_bytes(), Some(vec![0, 0, 0, 0]));
    }

    #[test]
    fn threaded_exchange() {
        // The real usage pattern: one thread per node, synchronized
        // exchange rounds.
        let g = Graph::ring(8);
        let (comms, meter) = build_bus(&g);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    for &j in &c.neighbors() {
                        c.send(j, Msg::Dense(vec![c.node as f32; 10])).unwrap();
                    }
                    let mut sum = 0.0;
                    for &j in &c.neighbors() {
                        sum += c.recv(j).unwrap().into_dense().unwrap()[0];
                    }
                    sum
                })
            })
            .collect();
        let sums: Vec<f64> = handles
            .into_iter()
            .map(|h| h.join().unwrap() as f64)
            .collect();
        // Node i receives from ring neighbors (i±1 mod 8).
        for (i, s) in sums.iter().enumerate() {
            let want = ((i + 1) % 8 + (i + 8 - 1) % 8) as f64;
            assert_eq!(*s, want);
        }
        // 8 nodes x 2 neighbors x 40 bytes.
        assert_eq!(meter.total_bytes(), 8 * 2 * 40);
    }
}
