//! Edge codecs: stateful, byte-exact compression of per-edge messages.
//!
//! The paper treats the compression operator `comp` (Assumption 1) as a
//! black box; the old `Compressor` trait materialized it as an f32
//! `CooVec` and left the wire size to be *inferred* from the payload
//! enum.  This module replaces that with a first-class codec API:
//!
//! * [`Frame`] — an owned, serialized byte buffer.  `Frame::wire_bytes()`
//!   *is* the metered wire size; nothing is inferred.
//! * [`EdgeCtx`] — everything both endpoints of an edge share for one
//!   message: edge id, round, receiving direction, dimension, and the
//!   shared-seed RNG derivation (`Pcg::derive(seed, [EDGE_MASK, edge,
//!   round, receiver])`, identical at both ends — Alg. 1 lines 5–6
//!   "can be omitted").
//! * [`EdgeCodec`] — `encode(&mut self, x, ctx) -> Frame` /
//!   `decode(&mut self, frame, ctx) -> Result<Vec<f32>>`.  Codecs are
//!   `&mut self` so they can carry per-edge state (error-feedback
//!   residuals); decoding validates every byte and surfaces typed
//!   [`CodecError`]s instead of panicking on corrupt frames.
//! * [`CodecSpec`] — the parseable, `Clone + PartialEq` description
//!   (`rand_k:0.1`, `rand_k:0.1:values`, `top_k:0.01`, `qsgd:4`,
//!   `sign`, `ef+top_k:0.01`, `identity`) that the CLI, experiment
//!   drivers, and both execution engines thread around; `build()` turns
//!   it into a fresh per-edge codec instance.
//!
//! ## Codec families
//!
//! | spec | wire bytes (dim d, nnz m) | fixed-ω linear (Eq. 8) | Eq. 13? |
//! |---|---|---|---|
//! | `identity` | `4d` | yes | yes (it *is* ECL) |
//! | `rand_k:K` | `8m` (explicit u32 idx + f32 val) | yes | yes |
//! | `rand_k:K:values` | `4m` (mask re-derived from the shared seed) | yes | yes |
//! | `top_k:K` | `8m` | **no** (value-dependent ω) | Eq. 11 only |
//! | `qsgd:B` | `4⌈d/512⌉ + ⌈dB/8⌉` (bucket norms + B-bit codes) | **no** | Eq. 11 only |
//! | `sign` | `4 + ⌈d/8⌉` (scale + sign bits) | **no** | Eq. 11 only |
//! | `low_rank:R[:it]` | `4R·Σ(rows+cols) + 4·Σvec` per bound layout | **no** (value-dependent) | Eq. 11 only |
//! | `ef+<c>` | inner | **no** (stateful) | Eq. 11 only |
//!
//! Codecs that are linear for fixed ω and whose support is derivable
//! from the shared seed ([`EdgeCodec::sparse_support`]) license the
//! Eq. (13) rewrite `comp(y − z) = comp(y) − comp(z)`; everything else
//! runs the C-ECL dual update under the naive Eq. (11) rule.

use std::cell::RefCell;
use std::fmt;

use super::RandK;
use crate::util::rng::{streams, Pcg};

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Typed decode/spec failure.  Decoding a corrupt or truncated frame
/// must *never* panic (a retransmitted frame in a 512-node simulation
/// would abort the whole run) — every malformed input maps here.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Frame length differs from what the codec requires on this edge.
    Length { expected: usize, got: usize },
    /// Frame length is not a whole number of records.
    Ragged { got: usize, record: usize },
    /// A decoded index falls outside the vector dimension.
    IndexOutOfRange { idx: u32, dim: usize },
    /// Indices are not strictly increasing (duplicate or reordered).
    UnsortedIndex { pos: usize },
    /// A decoded scalar field (norm/scale) is NaN or infinite — the
    /// whole vector would be poisoned.
    NonFiniteScalar,
    /// The frame's index set does not match the shared-seed derived
    /// mask (e.g. a frame truncated by a whole record, or an in-range
    /// index flip): counts plus the first diverging position.
    SupportMismatch { expect: usize, got: usize, pos: usize },
    /// Parallel index/value arrays have different lengths.
    ArityMismatch { idx: usize, vals: usize },
    /// Codec spec string / parameter validation failure.
    BadSpec(String),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Length { expected, got } => {
                write!(f, "frame length {got} B, codec expects {expected} B")
            }
            CodecError::Ragged { got, record } => {
                write!(f, "frame length {got} B is not a multiple of {record} B")
            }
            CodecError::IndexOutOfRange { idx, dim } => {
                write!(f, "index {idx} out of range for dim {dim}")
            }
            CodecError::UnsortedIndex { pos } => {
                write!(f, "index list not strictly increasing at position {pos}")
            }
            CodecError::NonFiniteScalar => {
                write!(f, "scalar field (norm/scale) is not finite")
            }
            CodecError::SupportMismatch { expect, got, pos } => {
                write!(
                    f,
                    "frame support ({got} coords) does not match the \
                     shared-seed mask ({expect} coords); first \
                     divergence at position {pos}"
                )
            }
            CodecError::ArityMismatch { idx, vals } => {
                write!(f, "{idx} indices vs {vals} values")
            }
            CodecError::BadSpec(s) => write!(f, "bad codec spec: {s}"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---------------------------------------------------------------------
// Frame + EdgeCtx
// ---------------------------------------------------------------------

/// An encoded message: an owned byte buffer.  Its length is exactly the
/// number of payload bytes a real transport would carry — the quantity
/// the [`Meter`](crate::comm::Meter) records.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Frame {
    bytes: Vec<u8>,
}

impl Frame {
    pub fn new(bytes: Vec<u8>) -> Frame {
        Frame { bytes }
    }

    /// Metered wire size: the buffer length, nothing inferred.
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Mutable access (tests corrupt frames through this).
    pub fn bytes_mut(&mut self) -> &mut Vec<u8> {
        &mut self.bytes
    }
}

// ---------------------------------------------------------------------
// Frame buffer pool
// ---------------------------------------------------------------------

/// Retained buffers per thread.  Steady state needs roughly
/// (in-flight frames per node × nodes per partition worker); beyond
/// the cap, buffers are simply freed — the pool is an allocation-rate
/// optimization, never a correctness dependency.
const POOL_MAX: usize = 1024;

thread_local! {
    /// Recycled frame payload buffers.  Thread-local (not a shared
    /// freelist) so the parallel sim's partition workers never contend
    /// on a lock in the encode hot path.
    static FRAME_POOL: RefCell<Vec<Vec<u8>>> = RefCell::new(Vec::new());
}

/// Take a cleared buffer with at least `cap` capacity from the
/// thread-local pool, or allocate one.  Every codec encode path builds
/// its frame into a pooled buffer; [`Frame`]'s `Drop` returns it, so a
/// steady-state simulation recycles the same handful of allocations
/// per thread instead of malloc/free per message.
pub(crate) fn pooled_buf(cap: usize) -> Vec<u8> {
    FRAME_POOL.with(|p| match p.borrow_mut().pop() {
        Some(mut b) => {
            b.clear();
            b.reserve(cap);
            b
        }
        None => {
            POOL_MISSES.with(|c| c.set(c.get() + 1));
            Vec::with_capacity(cap)
        }
    })
}

thread_local! {
    /// Times `pooled_buf` fell through to a fresh allocation.
    static POOL_MISSES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    /// Times a codec materialized a fresh dense `Vec<f32>` through the
    /// allocating [`EdgeCodec::decode`] path (native `decode_into`
    /// overrides never bump this).
    static DECODE_ALLOCS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Bumped at the top of every allocating dense `decode` implementation.
#[inline]
pub(crate) fn note_decode_alloc() {
    DECODE_ALLOCS.with(|c| c.set(c.get() + 1));
}

/// This thread's hot-path counters as `(pool_misses, decode_allocs)`.
/// Both are cumulative per thread; the steady-state allocation test
/// resets them, runs a warmed-up simulation, and asserts neither grew —
/// i.e. every frame buffer was recycled and every received frame was
/// decoded through a native `decode_into` into reusable scratch.
pub fn hotpath_counters() -> (u64, u64) {
    (
        POOL_MISSES.with(|c| c.get()),
        DECODE_ALLOCS.with(|c| c.get()),
    )
}

/// Zero this thread's hot-path counters.
pub fn reset_hotpath_counters() {
    POOL_MISSES.with(|c| c.set(0));
    DECODE_ALLOCS.with(|c| c.set(0));
}

impl Drop for Frame {
    fn drop(&mut self) {
        let bytes = std::mem::take(&mut self.bytes);
        if bytes.capacity() == 0 {
            return;
        }
        FRAME_POOL.with(|p| {
            let mut pool = p.borrow_mut();
            if pool.len() < POOL_MAX {
                pool.push(bytes);
            }
        });
    }
}

/// Shared per-message context: both endpoints of an edge construct an
/// identical `EdgeCtx` for a given `(edge, round, receiver)` triple, so
/// shared-seed codecs (rand-k values-only, QSGD's stochastic rounding)
/// can derive identical randomness without shipping it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeCtx {
    /// Experiment seed.
    pub seed: u64,
    /// Undirected edge id (`Graph::edge_index`).
    pub edge: usize,
    /// Exchange round.
    pub round: usize,
    /// The *receiving* node id — the direction tag, so ω_{i|j} (what i
    /// receives from j) differs from ω_{j|i}.
    pub receiver: usize,
    /// Dense dimension of the vectors on this edge.
    pub dim: usize,
    /// Edge incarnation (`TopologyView`'s `EdgeLife::epoch`): 0 for the
    /// edge as constructed, bumped on every churn re-add.  Both
    /// endpoints observe the same epoch for a given message (the engine
    /// drops cross-epoch frames in flight), so including it in the
    /// shared-seed derivation keeps the RNG streams in lockstep across
    /// a remove/re-add — and distinct from the previous incarnation's.
    pub epoch: u32,
}

impl EdgeCtx {
    /// The shared-seed RNG for this message (same derivation both
    /// ends).  Epoch 0 keeps the legacy 4-element derivation path so
    /// static schedules replay the exact pre-churn streams
    /// bit-identically; later incarnations fold the epoch in.
    pub fn mask_rng(&self) -> Pcg {
        if self.epoch == 0 {
            Pcg::derive(
                self.seed,
                &[
                    streams::EDGE_MASK,
                    self.edge as u64,
                    self.round as u64,
                    self.receiver as u64,
                ],
            )
        } else {
            Pcg::derive(
                self.seed,
                &[
                    streams::EDGE_MASK,
                    self.edge as u64,
                    self.round as u64,
                    self.receiver as u64,
                    self.epoch as u64,
                ],
            )
        }
    }
}

// ---------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------

/// A stateful per-edge compression codec.
///
/// One instance lives at each endpoint of each directed edge; `&mut
/// self` lets implementations keep per-edge memory (the error-feedback
/// residual).  Both endpoints must construct codecs from the same
/// [`CodecSpec`] and feed them identical [`EdgeCtx`]s for the protocol
/// to round-trip.
pub trait EdgeCodec: Send {
    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// Whether `comp(x + y; ω) = comp(x; ω) + comp(y; ω)` holds exactly
    /// for fixed ω (Eqs. 8–9) — required by the Eq. (13) dual rule.
    fn is_linear_for_fixed_omega(&self) -> bool;

    /// Serialize `comp(x; ω_ctx)` into an owned byte frame.
    /// `x.len()` must equal `ctx.dim`.
    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame;

    /// Sparse-input encode fast path: `src(i)` yields coordinate `i` of
    /// the input on demand.  Codecs with a seed-derivable support
    /// (rand-k) evaluate it on the `|ω|` kept coordinates only, so the
    /// Eq. (13) send hot path never materializes a dense vector.
    /// `None` ⇒ the caller stages a dense input and calls [`encode`].
    /// Must produce byte-identical frames to `encode` on the densified
    /// input (pinned by tests).
    fn encode_from(&mut self, _src: &dyn Fn(usize) -> f32,
                   _ctx: &EdgeCtx) -> Option<Frame> {
        None
    }

    /// Reconstruct the dense `comp(x; ω_ctx)` from a frame, validating
    /// every byte.  Corrupt input returns a typed error, never panics.
    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError>;

    /// Decode into a caller-provided dense buffer of `ctx.dim`
    /// elements instead of materializing a fresh `Vec<f32>` — the
    /// receive hot path decodes every frame into reusable per-edge
    /// scratch through this.  On success every element of `out` is
    /// overwritten (coordinates outside the support are zeroed) and the
    /// result is bit-identical to [`EdgeCodec::decode`] (pinned by the
    /// codec-matrix test); on error `out` is unspecified.  The default
    /// routes through `decode`; the shipping codecs override it
    /// natively, and the `decode-alloc` lint rule bans fresh `Vec`
    /// construction inside those overrides.
    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        let v = self.decode(frame, ctx)?;
        if v.len() != out.len() {
            return Err(CodecError::Length {
                expected: out.len(),
                got: v.len(),
            });
        }
        out.copy_from_slice(&v);
        Ok(())
    }

    /// Sparse fast path for codecs whose output is supported on `≪ d`
    /// coordinates: decode a frame to `(sorted idx, vals)` without
    /// materializing (or zero-filling) a dense vector.  `Ok(None)`
    /// means "use [`EdgeCodec::decode`]".  The Eq. (13) receive hot
    /// path relies on this to stay O(k·d) per message.
    fn decode_sparse(
        &mut self,
        _frame: &Frame,
        _ctx: &EdgeCtx,
    ) -> Result<Option<(Vec<u32>, Vec<f32>)>, CodecError> {
        Ok(None)
    }

    /// The sorted coordinate support of the decoded output, when it is
    /// derivable from the shared seed alone (projection codecs: rand-k,
    /// identity).  `None` for value-dependent codecs.  Licenses the
    /// Eq. (13) rule together with fixed-ω linearity.
    fn sparse_support(&self, _ctx: &EdgeCtx) -> Option<Vec<u32>> {
        None
    }

    /// Whether the decoded output always covers every coordinate
    /// (identity): the Eq. (13) receive path then runs the fused dense
    /// update directly instead of materializing a 0..d support list.
    fn is_full_support(&self) -> bool {
        false
    }

    /// Optional model-layout hint: the layer-matrix views
    /// `(offset, rows, cols)` and rank-1-tensor views `(offset, len)`
    /// of the vectors this codec will see.  Structure-aware codecs
    /// (`low_rank`) compress each layer matrix separately — exactly
    /// PowerGossip's per-layer wire accounting; everything else ignores
    /// the hint.  Callers bind at most once, before the first
    /// encode/decode (C-ECL binds its manifest views at construction).
    fn bind_layout(&mut self, _matrices: &[(usize, usize, usize)],
                   _vectors: &[(usize, usize)]) {
    }
}

// ---------------------------------------------------------------------
// Byte helpers (little-endian, bounds pre-checked by callers)
// ---------------------------------------------------------------------

#[inline]
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn get_u32(b: &[u8], off: usize) -> u32 {
    // det:allow(index-decode): every caller validates `bytes.len()`
    // before reading fields, per this section's bounds-pre-checked
    // contract; an out-of-range offset here is a codec bug, not a
    // malformed frame.
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

#[inline]
fn get_f32(b: &[u8], off: usize) -> f32 {
    // det:allow(index-decode): same bounds-pre-checked contract as
    // `get_u32` above.
    f32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// LSB-first bit packer for the sub-byte codecs (QSGD levels, sign bits).
struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    #[inline]
    fn push(&mut self, code: u32, bits: u32) {
        debug_assert!(bits <= 32 && (bits == 32 || code < (1 << bits)));
        self.acc |= (code as u64) << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// LSB-first bit reader over a byte slice.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> BitReader<'a> {
        BitReader {
            bytes,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn read(&mut self, bits: u32) -> u32 {
        while self.nbits < bits {
            let byte = if self.pos < self.bytes.len() {
                // det:allow(index-decode): guarded by the branch
                // condition on the line above.
                self.bytes[self.pos]
            } else {
                0 // length pre-validated; only tail padding lands here
            };
            self.pos += 1;
            self.acc |= (byte as u64) << self.nbits;
            self.nbits += 8;
        }
        let v = (self.acc & ((1u64 << bits) - 1)) as u32;
        self.acc >>= bits;
        self.nbits -= bits;
        v
    }
}

/// Shared sparse decoder for the explicit `[u32 idx]*m ++ [f32 val]*m`
/// layout (rand-k explicit mode and top-k): validates record alignment,
/// index range, and strict ordering before touching any memory.
fn decode_explicit_sparse(
    bytes: &[u8],
    dim: usize,
) -> Result<(Vec<u32>, Vec<f32>), CodecError> {
    if bytes.len() % 8 != 0 {
        return Err(CodecError::Ragged {
            got: bytes.len(),
            record: 8,
        });
    }
    let m = bytes.len() / 8;
    if m > dim {
        return Err(CodecError::Length {
            expected: 8 * dim,
            got: bytes.len(),
        });
    }
    let mut idxs = Vec::with_capacity(m);
    let mut vals = Vec::with_capacity(m);
    let mut prev: i64 = -1;
    for k in 0..m {
        let idx = get_u32(bytes, 4 * k);
        if (idx as usize) >= dim {
            return Err(CodecError::IndexOutOfRange { idx, dim });
        }
        if (idx as i64) <= prev {
            return Err(CodecError::UnsortedIndex { pos: k });
        }
        prev = idx as i64;
        idxs.push(idx);
        vals.push(get_f32(bytes, 4 * (m + k)));
    }
    Ok((idxs, vals))
}

/// Dense form of [`decode_explicit_sparse`].
fn decode_explicit(bytes: &[u8], dim: usize) -> Result<Vec<f32>, CodecError> {
    let (idxs, vals) = decode_explicit_sparse(bytes, dim)?;
    let mut out = vec![0.0f32; dim];
    for (&i, &v) in idxs.iter().zip(&vals) {
        // det:allow(index-decode): `decode_explicit_sparse` rejects any
        // index >= dim before returning, so the scatter is in bounds.
        out[i as usize] = v;
    }
    Ok(out)
}

/// Zero-allocation twin of [`decode_explicit_sparse`]: validate the
/// explicit `[u32 idx]*m ++ [f32 val]*m` layout and scatter it straight
/// into `out` (zeroing untouched coordinates).  Same validation order
/// and errors as the allocating path.
fn scatter_explicit(
    bytes: &[u8],
    dim: usize,
    out: &mut [f32],
) -> Result<(), CodecError> {
    if bytes.len() % 8 != 0 {
        return Err(CodecError::Ragged {
            got: bytes.len(),
            record: 8,
        });
    }
    let m = bytes.len() / 8;
    if m > dim {
        return Err(CodecError::Length {
            expected: 8 * dim,
            got: bytes.len(),
        });
    }
    out.fill(0.0);
    let mut prev: i64 = -1;
    for k in 0..m {
        let idx = get_u32(bytes, 4 * k);
        if (idx as usize) >= dim {
            return Err(CodecError::IndexOutOfRange { idx, dim });
        }
        if (idx as i64) <= prev {
            return Err(CodecError::UnsortedIndex { pos: k });
        }
        prev = idx as i64;
        out[idx as usize] = get_f32(bytes, 4 * (m + k));
    }
    Ok(())
}

/// Caller-contract check shared by the native `decode_into` overrides:
/// the output scratch must span exactly the edge dimension.
#[inline]
fn check_out_dim(out: &[f32], dim: usize) -> Result<(), CodecError> {
    if out.len() == dim {
        Ok(())
    } else {
        Err(CodecError::Length {
            expected: dim,
            got: out.len(),
        })
    }
}

/// Shared encoder for the explicit layout (indices must be sorted).
fn encode_explicit(x: &[f32], idx: &[u32]) -> Frame {
    let mut buf = pooled_buf(8 * idx.len());
    for &i in idx {
        put_u32(&mut buf, i);
    }
    for &i in idx {
        put_f32(&mut buf, x[i as usize]);
    }
    Frame::new(buf)
}

// ---------------------------------------------------------------------
// Concrete codecs
// ---------------------------------------------------------------------

/// Wire mode for the shared-seed mask codecs: ship `(idx, val)` pairs
/// (the paper's COO accounting, 8 B/coord) or values only (4 B/coord,
/// mask regenerated from the shared seed at both endpoints).  The old
/// `wire_bytes_values_only` ablation split is exactly this enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireMode {
    Explicit,
    ValuesOnly,
}

/// Identity: dense f32 frames, byte-identical to the uncompressed ECL
/// wire (4 B/coord).  τ = 1 — C-ECL with this codec *is* ECL
/// (Corollary 1).
#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityCodec;

impl EdgeCodec for IdentityCodec {
    fn name(&self) -> String {
        "identity".to_string()
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        true
    }

    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        debug_assert_eq!(x.len(), ctx.dim);
        let mut buf = pooled_buf(4 * x.len());
        for &v in x {
            put_f32(&mut buf, v);
        }
        Frame::new(buf)
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        note_decode_alloc();
        let b = frame.bytes();
        if b.len() != 4 * ctx.dim {
            return Err(CodecError::Length {
                expected: 4 * ctx.dim,
                got: b.len(),
            });
        }
        Ok((0..ctx.dim).map(|i| get_f32(b, 4 * i)).collect())
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        check_out_dim(out, ctx.dim)?;
        let b = frame.bytes();
        if b.len() != 4 * ctx.dim {
            return Err(CodecError::Length {
                expected: 4 * ctx.dim,
                got: b.len(),
            });
        }
        for (i, o) in out.iter_mut().enumerate() {
            *o = get_f32(b, 4 * i);
        }
        Ok(())
    }

    fn sparse_support(&self, ctx: &EdgeCtx) -> Option<Vec<u32>> {
        Some((0..ctx.dim as u32).collect())
    }

    fn is_full_support(&self) -> bool {
        true
    }
}

/// The paper's Example 1 (`rand_k%`) as a codec: keep each coordinate
/// with probability k, ω derived from the shared seed.  Linear for
/// fixed ω (Eqs. 8–9); τ = k.
#[derive(Debug, Clone, Copy)]
pub struct RandKCodec {
    pub k_frac: f64,
    pub mode: WireMode,
}

impl RandKCodec {
    fn mask(&self, ctx: &EdgeCtx) -> Vec<u32> {
        // Struct literal on purpose: k was validated by `CodecSpec`.
        let op = RandK { k_frac: self.k_frac };
        op.sample_mask(ctx.dim, &mut ctx.mask_rng())
    }
}

impl EdgeCodec for RandKCodec {
    fn name(&self) -> String {
        let pct = (self.k_frac * 100.0).round() as u32;
        match self.mode {
            WireMode::Explicit => format!("rand_k {pct}%"),
            WireMode::ValuesOnly => format!("rand_k {pct}% vo"),
        }
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        true
    }

    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        debug_assert_eq!(x.len(), ctx.dim);
        let mask = self.mask(ctx);
        match self.mode {
            WireMode::Explicit => encode_explicit(x, &mask),
            WireMode::ValuesOnly => {
                let mut buf = pooled_buf(4 * mask.len());
                for &i in &mask {
                    put_f32(&mut buf, x[i as usize]);
                }
                Frame::new(buf)
            }
        }
    }

    fn encode_from(&mut self, src: &dyn Fn(usize) -> f32,
                   ctx: &EdgeCtx) -> Option<Frame> {
        let mask = self.mask(ctx);
        let record = match self.mode {
            WireMode::Explicit => 8,
            WireMode::ValuesOnly => 4,
        };
        let mut buf = pooled_buf(record * mask.len());
        if self.mode == WireMode::Explicit {
            for &i in &mask {
                put_u32(&mut buf, i);
            }
        }
        for &i in &mask {
            put_f32(&mut buf, src(i as usize));
        }
        Some(Frame::new(buf))
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        note_decode_alloc();
        let decoded = self.decode_sparse(frame, ctx)?;
        let Some((mask, vals)) = decoded else {
            return Err(CodecError::BadSpec(
                "rand-k sparse decode unavailable".into(),
            ));
        };
        let mut out = vec![0.0f32; ctx.dim];
        for (&i, &v) in mask.iter().zip(&vals) {
            // det:allow(index-decode): `decode_sparse` validates every
            // index against `ctx.dim` before returning the mask.
            out[i as usize] = v;
        }
        Ok(out)
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        check_out_dim(out, ctx.dim)?;
        // The O(k) mask/value pair from `decode_sparse` is inherent to
        // the shared-seed support validation; only the O(d) dense
        // materialization is skipped here.
        let decoded = self.decode_sparse(frame, ctx)?;
        let Some((mask, vals)) = decoded else {
            return Err(CodecError::BadSpec(
                "rand-k sparse decode unavailable".into(),
            ));
        };
        out.fill(0.0);
        for (&i, &v) in mask.iter().zip(&vals) {
            // det:allow(index-decode): `decode_sparse` validates every
            // index against `ctx.dim` before returning the mask.
            out[i as usize] = v;
        }
        Ok(())
    }

    fn decode_sparse(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
    ) -> Result<Option<(Vec<u32>, Vec<f32>)>, CodecError> {
        match self.mode {
            WireMode::Explicit => {
                let (idxs, vals) =
                    decode_explicit_sparse(frame.bytes(), ctx.dim)?;
                // The index set must equal the shared-seed mask — this
                // catches whole-record truncation (which stays 8-byte
                // aligned and would otherwise shift the value block).
                let mask = self.mask(ctx);
                if idxs != mask {
                    let pos = idxs
                        .iter()
                        .zip(&mask)
                        .position(|(a, b)| a != b)
                        .unwrap_or_else(|| idxs.len().min(mask.len()));
                    return Err(CodecError::SupportMismatch {
                        expect: mask.len(),
                        got: idxs.len(),
                        pos,
                    });
                }
                Ok(Some((idxs, vals)))
            }
            WireMode::ValuesOnly => {
                let mask = self.mask(ctx);
                let b = frame.bytes();
                if b.len() != 4 * mask.len() {
                    return Err(CodecError::Length {
                        expected: 4 * mask.len(),
                        got: b.len(),
                    });
                }
                let vals = (0..mask.len()).map(|k| get_f32(b, 4 * k)).collect();
                Ok(Some((mask, vals)))
            }
        }
    }

    fn sparse_support(&self, ctx: &EdgeCtx) -> Option<Vec<u32>> {
        Some(self.mask(ctx))
    }
}

/// Deterministic top-k by magnitude, explicit-index wire.  ω depends on
/// the values, so it is NOT linear for fixed ω — Eq. (11) rule only.
#[derive(Debug, Clone, Copy)]
pub struct TopKCodec {
    pub k_frac: f64,
}

impl TopKCodec {
    fn k_of(&self, dim: usize) -> usize {
        (((dim as f64) * self.k_frac).round() as usize).clamp(1, dim)
    }
}

impl EdgeCodec for TopKCodec {
    fn name(&self) -> String {
        format!("top_k {}%", (self.k_frac * 100.0).round() as u32)
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        false
    }

    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        debug_assert_eq!(x.len(), ctx.dim);
        let k = self.k_of(x.len());
        let mut order: Vec<u32> = (0..x.len() as u32).collect();
        // Total order, descending |x| with the index as the explicit
        // tie-break: `total_cmp` ranks NaN magnitudes above +inf (a
        // NaN coordinate is always kept — it must reach the receiver,
        // not be silently dropped by a comparator that calls it Equal
        // to everything), and equal magnitudes keep the lowest indices.
        // A partial_cmp-with-Equal-fallback here made the selected set
        // depend on the selection algorithm's visit order.
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            x[b as usize]
                .abs()
                .total_cmp(&x[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        encode_explicit(x, &idx)
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        note_decode_alloc();
        // Top-k frames carry exactly k_of(d) records — pinning the
        // count catches whole-record truncation, which would otherwise
        // stay 8-byte aligned and shift the value block.
        let expected = 8 * self.k_of(ctx.dim);
        if frame.bytes().len() != expected {
            return Err(CodecError::Length {
                expected,
                got: frame.bytes().len(),
            });
        }
        decode_explicit(frame.bytes(), ctx.dim)
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        check_out_dim(out, ctx.dim)?;
        let expected = 8 * self.k_of(ctx.dim);
        if frame.bytes().len() != expected {
            return Err(CodecError::Length {
                expected,
                got: frame.bytes().len(),
            });
        }
        scatter_explicit(frame.bytes(), ctx.dim, out)
    }
}

/// QSGD-style b-bit stochastic quantization (Alistarh et al. 2017),
/// **bucketed**: the vector is split into buckets of
/// [`QsgdCodec::BUCKET`] coordinates, each quantized against its own
/// L2 norm — `comp(x)_i = ‖x_b‖₂ · sign(x_i) · ξ_i/s` with `ξ_i` the
/// stochastic rounding of `|x_i|/‖x_b‖₂ · s` and `s = 2^{b−1} − 1`
/// levels.  Without bucketing the variance grows like `√d/s` and the
/// operator stops being a contraction at realistic d; per-bucket norms
/// keep it dimension-independent.  Wire: one f32 norm per bucket +
/// d sign-magnitude codes of `bits` bits.  Unbiased but not linear for
/// fixed ω — Eq. (11) rule only.  The rounding draws come from the
/// shared-seed RNG, so encode is deterministic per
/// `(seed, edge, round, receiver)`.
#[derive(Debug, Clone, Copy)]
pub struct QsgdCodec {
    pub bits: u8,
}

impl QsgdCodec {
    /// Coordinates per quantization bucket (one transmitted norm each).
    pub const BUCKET: usize = 512;

    fn levels(&self) -> u32 {
        (1u32 << (self.bits - 1)) - 1
    }

    fn n_buckets(dim: usize) -> usize {
        (dim + Self::BUCKET - 1) / Self::BUCKET
    }

    /// The original scalar encode loop, kept as the byte-exact oracle
    /// for the branch-free kernel (the `qsgd_branch_free_matches_
    /// reference` test and the `micro_hotpath` A/B rows).  Not part of
    /// the codec API.
    #[doc(hidden)]
    pub fn encode_reference(&self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        let s = self.levels();
        let bits = self.bits as u32;
        let mut rng = ctx.mask_rng();
        let norms: Vec<f32> = x
            .chunks(Self::BUCKET)
            .map(|c| {
                c.iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum::<f64>()
                    .sqrt() as f32
            })
            .collect();
        let mut buf = pooled_buf(
            4 * norms.len() + (x.len() * bits as usize + 7) / 8,
        );
        for &n in &norms {
            put_f32(&mut buf, n);
        }
        let mut w = BitWriter { buf, acc: 0, nbits: 0 };
        for (i, &v) in x.iter().enumerate() {
            let norm = norms[i / Self::BUCKET];
            let code = if norm > 0.0 {
                let a = (v.abs() as f64 / norm as f64) * s as f64;
                let lo = a.floor();
                let mut level = lo as u32;
                if rng.f64() < a - lo {
                    level += 1;
                }
                let level = level.min(s);
                let sign = if v < 0.0 { 1u32 } else { 0u32 };
                (sign << (bits - 1)) | level
            } else {
                0
            };
            w.push(code, bits);
        }
        Frame::new(w.finish())
    }
}

impl EdgeCodec for QsgdCodec {
    fn name(&self) -> String {
        format!("qsgd {}b", self.bits)
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        false
    }

    /// Branch-free bucketed kernel.  Per bucket, the `norm > 0` test is
    /// hoisted out of the coordinate loop (it is constant within a
    /// bucket), and the per-coordinate stochastic rounding is a
    /// straight-line `floor → compare → add → min` with no
    /// data-dependent branch — the shape auto-vectorizers like.  Byte
    /// output and RNG draw pattern are identical to
    /// [`QsgdCodec::encode_reference`] (zero-norm buckets draw nothing),
    /// pinned by a test.
    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        debug_assert_eq!(x.len(), ctx.dim);
        let s = self.levels();
        let sf = s as f64;
        let bits = self.bits as u32;
        let sign_shift = bits - 1;
        let mut rng = ctx.mask_rng();
        let norms: Vec<f32> = x
            .chunks(Self::BUCKET)
            .map(|c| {
                c.iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum::<f64>()
                    .sqrt() as f32
            })
            .collect();
        let mut buf = pooled_buf(
            4 * norms.len() + (x.len() * bits as usize + 7) / 8,
        );
        for &n in &norms {
            put_f32(&mut buf, n);
        }
        let mut w = BitWriter { buf, acc: 0, nbits: 0 };
        for (chunk, &norm) in x.chunks(Self::BUCKET).zip(&norms) {
            if norm > 0.0 {
                let nf = norm as f64;
                for &v in chunk {
                    // Same expression tree as the reference — the
                    // divide stays per-coordinate so `a` is
                    // bit-identical (x/n·s ≠ x·(s/n) in f64).
                    let a = (v.abs() as f64 / nf) * sf;
                    let lo = a.floor();
                    let level =
                        ((lo as u32) + u32::from(rng.f64() < a - lo)).min(s);
                    // `v < 0.0`, not the sign bit: -0.0 must encode as
                    // +0 exactly like the reference.
                    let code = (u32::from(v < 0.0) << sign_shift) | level;
                    w.push(code, bits);
                }
            } else {
                // Zero (or NaN) norm: all-zero codes, and — critically
                // for draw-pattern identity — no RNG consumption.
                for _ in chunk {
                    w.push(0, bits);
                }
            }
        }
        Frame::new(w.finish())
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        note_decode_alloc();
        let bits = self.bits as u32;
        let nb = Self::n_buckets(ctx.dim);
        let expected = 4 * nb + (ctx.dim * bits as usize + 7) / 8;
        let b = frame.bytes();
        if b.len() != expected {
            return Err(CodecError::Length {
                expected,
                got: b.len(),
            });
        }
        let mut norms = Vec::with_capacity(nb);
        for k in 0..nb {
            let n = get_f32(b, 4 * k);
            if !n.is_finite() {
                return Err(CodecError::NonFiniteScalar);
            }
            norms.push(n);
        }
        let s = self.levels() as f32;
        // det:allow(index-decode): the exact-length check above
        // guarantees `b.len() >= 4 * nb`, so the slice start is valid.
        let mut r = BitReader::new(&b[4 * nb..]);
        let mut out = Vec::with_capacity(ctx.dim);
        for i in 0..ctx.dim {
            let code = r.read(bits);
            let level = code & ((1 << (bits - 1)) - 1);
            let sign = if code >> (bits - 1) == 1 { -1.0f32 } else { 1.0 };
            // det:allow(index-decode): `norms` holds `n_buckets(dim)`
            // entries, so `i / BUCKET` is in bounds for `i < dim`.
            out.push(sign * (level as f32 / s) * norms[i / Self::BUCKET]);
        }
        Ok(out)
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        check_out_dim(out, ctx.dim)?;
        let bits = self.bits as u32;
        let nb = Self::n_buckets(ctx.dim);
        let expected = 4 * nb + (ctx.dim * bits as usize + 7) / 8;
        let b = frame.bytes();
        if b.len() != expected {
            return Err(CodecError::Length {
                expected,
                got: b.len(),
            });
        }
        // Validate every bucket norm up front, then re-read them from
        // the frame during the scatter — no norms staging vector.
        for k in 0..nb {
            if !get_f32(b, 4 * k).is_finite() {
                return Err(CodecError::NonFiniteScalar);
            }
        }
        let s = self.levels() as f32;
        // det:allow(index-decode): the exact-length check above
        // guarantees `b.len() >= 4 * nb`, so the slice start is valid.
        let mut r = BitReader::new(&b[4 * nb..]);
        for (i, o) in out.iter_mut().enumerate() {
            let code = r.read(bits);
            let level = code & ((1 << (bits - 1)) - 1);
            let sign = if code >> (bits - 1) == 1 { -1.0f32 } else { 1.0 };
            // Same expression tree as `decode`; the norm comes back
            // bit-identical from the frame bytes.
            *o = sign * (level as f32 / s) * get_f32(b, 4 * (i / Self::BUCKET));
        }
        Ok(())
    }
}

/// Sign + norm (signSGD with majority-scale, Bernstein et al. 2018):
/// `comp(x) = (‖x‖₁/d) · sign(x)`.  Wire: one f32 scale + d sign bits.
/// τ = ‖x‖₁²/(d‖x‖²) — ≈ 2/π on Gaussian inputs.  Not linear — Eq. (11)
/// rule only.
#[derive(Debug, Clone, Copy, Default)]
pub struct SignNormCodec;

impl EdgeCodec for SignNormCodec {
    fn name(&self) -> String {
        "sign".to_string()
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        false
    }

    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        debug_assert_eq!(x.len(), ctx.dim);
        let scale = (x.iter().map(|&v| v.abs() as f64).sum::<f64>()
            / x.len().max(1) as f64) as f32;
        let mut buf = pooled_buf(4 + (x.len() + 7) / 8);
        put_f32(&mut buf, scale);
        let mut w = BitWriter { buf, acc: 0, nbits: 0 };
        for &v in x {
            w.push(u32::from(v < 0.0), 1);
        }
        Frame::new(w.finish())
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        note_decode_alloc();
        let expected = 4 + (ctx.dim + 7) / 8;
        let b = frame.bytes();
        if b.len() != expected {
            return Err(CodecError::Length {
                expected,
                got: b.len(),
            });
        }
        let scale = get_f32(b, 0);
        if !scale.is_finite() {
            return Err(CodecError::NonFiniteScalar);
        }
        // det:allow(index-decode): the exact-length check above
        // guarantees `b.len() >= 4`, so the slice start is valid.
        let mut r = BitReader::new(&b[4..]);
        Ok((0..ctx.dim)
            .map(|_| if r.read(1) == 1 { -scale } else { scale })
            .collect())
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        check_out_dim(out, ctx.dim)?;
        let expected = 4 + (ctx.dim + 7) / 8;
        let b = frame.bytes();
        if b.len() != expected {
            return Err(CodecError::Length {
                expected,
                got: b.len(),
            });
        }
        let scale = get_f32(b, 0);
        if !scale.is_finite() {
            return Err(CodecError::NonFiniteScalar);
        }
        // det:allow(index-decode): the exact-length check above
        // guarantees `b.len() >= 4`, so the slice start is valid.
        let mut r = BitReader::new(&b[4..]);
        for o in out.iter_mut() {
            *o = if r.read(1) == 1 { -scale } else { scale };
        }
        Ok(())
    }
}

/// Error-feedback combinator (EF-SGD / LEAD lineage): keeps the
/// residual `e ← v − comp(v)` of each encode and folds it into the next
/// (`v = x + e`), so the compression error is re-injected instead of
/// lost.  Per-edge state lives here — one instance per directed edge.
/// Stateful ⇒ not linear for fixed ω — Eq. (11) rule only.
pub struct ErrorFeedback {
    inner: Box<dyn EdgeCodec>,
    residual: Vec<f32>,
    carry: Vec<f32>,
    /// Scratch for the self-decode inside `encode` — the receiver-side
    /// estimate, reconstructed via `decode_into` so a steady-state
    /// encode never allocates.
    est: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(inner: Box<dyn EdgeCodec>) -> ErrorFeedback {
        ErrorFeedback {
            inner,
            residual: Vec::new(),
            carry: Vec::new(),
            est: Vec::new(),
        }
    }

    /// Current residual memory (tests inspect convergence).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }
}

impl EdgeCodec for ErrorFeedback {
    fn name(&self) -> String {
        format!("ef+{}", self.inner.name())
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        false
    }

    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        if self.residual.len() != x.len() {
            self.residual = vec![0.0; x.len()];
        }
        self.carry.clear();
        self.carry
            .extend(x.iter().zip(&self.residual).map(|(&a, &b)| a + b));
        let frame = self.inner.encode(&self.carry, ctx);
        // What the receiver will reconstruct — decode our own frame
        // into the retained scratch (allocation-free at steady state).
        self.est.resize(self.carry.len(), 0.0);
        match self.inner.decode_into(&frame, ctx, &mut self.est) {
            Ok(()) => {
                for ((r, &v), &e) in
                    self.residual.iter_mut().zip(&self.carry).zip(&self.est)
                {
                    *r = v - e;
                }
            }
            Err(_) => self.residual.iter_mut().for_each(|r| *r = 0.0),
        }
        frame
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        self.inner.decode(frame, ctx)
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        self.inner.decode_into(frame, ctx, out)
    }

    fn bind_layout(&mut self, matrices: &[(usize, usize, usize)],
                   vectors: &[(usize, usize)]) {
        self.inner.bind_layout(matrices, vectors);
    }
}

// ---------------------------------------------------------------------
// CodecSpec: the parseable description
// ---------------------------------------------------------------------

/// Declarative codec selection, threaded from the CLI (`--codec ...`)
/// through `ExperimentSpec` into per-edge codec instances on both
/// execution engines.
///
/// Grammar: `identity` | `rand_k:K[:values]` | `top_k:K` | `qsgd:B` |
/// `sign` | `low_rank:R[:iters]` | `ef+<codec>` — with `K ∈ (0, 1]` a
/// fraction, `B ∈ [2, 8]` bits, `R ∈ [1, 128]` and `iters ∈ [1, 16]`.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecSpec {
    Identity,
    RandK { k_frac: f64, mode: WireMode },
    TopK { k_frac: f64 },
    Qsgd { bits: u8 },
    SignNorm,
    /// PowerGossip as a codec: rank-R power-iteration factors per layer
    /// matrix, rank-1 tensors dense (`compress::low_rank::LowRankCodec`).
    LowRank { rank: usize, iters: usize },
    ErrorFeedback(Box<CodecSpec>),
}

/// The full `--codec` grammar, restated verbatim in every parse error.
pub const CODEC_GRAMMAR: &str =
    "identity | rand_k:K[:values|:explicit] | top_k:K | qsgd:B | sign \
     | low_rank:R[:iters] | ef+<codec>, with K a fraction in (0, 1], \
     B bits in [2, 8], R a rank in [1, 128], and iters in [1, 16]";

impl CodecSpec {
    /// Parse the CLI codec grammar (see [`CODEC_GRAMMAR`]).  Every
    /// error names the offending token and restates the grammar, so a
    /// typo in a long `--codec` list is findable without source-diving.
    pub fn parse(s: &str) -> Result<CodecSpec, CodecError> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("ef+") {
            let inner = CodecSpec::parse(rest)?;
            if matches!(inner, CodecSpec::ErrorFeedback(_)) {
                return Err(CodecError::BadSpec(format!(
                    "`{s}`: ef+ wraps a base codec, not another ef+ \
                     (grammar: {CODEC_GRAMMAR})"
                )));
            }
            let spec = CodecSpec::ErrorFeedback(Box::new(inner));
            spec.validate()?;
            return Ok(spec);
        }
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("");
        let args: Vec<&str> = parts.collect();
        let frac = |a: &str| -> Result<f64, CodecError> {
            a.parse::<f64>().map_err(|_| {
                CodecError::BadSpec(format!(
                    "`{s}`: `{a}` is not a fraction \
                     (grammar: {CODEC_GRAMMAR})"
                ))
            })
        };
        let int = |a: &str, what: &str| -> Result<usize, CodecError> {
            a.parse::<usize>().map_err(|_| {
                CodecError::BadSpec(format!(
                    "`{s}`: `{a}` is not {what} \
                     (grammar: {CODEC_GRAMMAR})"
                ))
            })
        };
        let spec = match (head, args.as_slice()) {
            ("identity" | "dense", []) => CodecSpec::Identity,
            ("rand_k" | "randk", [k]) => CodecSpec::RandK {
                k_frac: frac(k)?,
                mode: WireMode::Explicit,
            },
            ("rand_k" | "randk", [k, m]) => {
                let mode = match *m {
                    "values" | "vo" => WireMode::ValuesOnly,
                    "explicit" | "coo" => WireMode::Explicit,
                    other => {
                        return Err(CodecError::BadSpec(format!(
                            "`{s}`: unknown wire mode `{other}` — use \
                             values|explicit (grammar: {CODEC_GRAMMAR})"
                        )))
                    }
                };
                CodecSpec::RandK { k_frac: frac(k)?, mode }
            }
            ("top_k" | "topk", [k]) => CodecSpec::TopK { k_frac: frac(k)? },
            ("qsgd", [b]) => CodecSpec::Qsgd {
                bits: b.parse::<u8>().map_err(|_| {
                    CodecError::BadSpec(format!(
                        "`{s}`: `{b}` is not a bit width \
                         (grammar: {CODEC_GRAMMAR})"
                    ))
                })?,
            },
            ("sign", []) => CodecSpec::SignNorm,
            ("low_rank" | "lowrank", [r]) => CodecSpec::LowRank {
                rank: int(r, "a rank")?,
                iters: 1,
            },
            ("low_rank" | "lowrank", [r, i]) => CodecSpec::LowRank {
                rank: int(r, "a rank")?,
                iters: int(i, "an iteration count")?,
            },
            (head, args) => {
                // Name the token that broke the parse: a known codec
                // with the wrong arity points at its argument list, an
                // unknown head at itself.
                let known = matches!(
                    head,
                    "identity" | "dense" | "rand_k" | "randk" | "top_k"
                        | "topk" | "qsgd" | "sign" | "low_rank" | "lowrank"
                );
                return Err(CodecError::BadSpec(if known {
                    format!(
                        "`{s}`: `{head}` takes a different argument count \
                         than the {} given (grammar: {CODEC_GRAMMAR})",
                        args.len()
                    )
                } else {
                    format!(
                        "unknown codec `{head}` in `{s}` \
                         (grammar: {CODEC_GRAMMAR})"
                    )
                }));
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Validate a bare rand-k fraction against the same (0, 1] domain
    /// the grammar enforces — the single source of truth for the
    /// numeric `cecl:K` / `naive-cecl:K` spellings (parser and CLI
    /// diagnostics alike).
    pub fn validate_k_fraction(k: f64) -> Result<(), CodecError> {
        CodecSpec::RandK {
            k_frac: k,
            mode: WireMode::Explicit,
        }
        .validate()
    }

    /// Parameter validation (k ranges, bit widths).
    pub fn validate(&self) -> Result<(), CodecError> {
        match self {
            CodecSpec::Identity | CodecSpec::SignNorm => Ok(()),
            CodecSpec::RandK { k_frac, .. } | CodecSpec::TopK { k_frac } => {
                if *k_frac > 0.0 && *k_frac <= 1.0 {
                    Ok(())
                } else {
                    Err(CodecError::BadSpec(format!(
                        "k must be in (0, 1], got `{k_frac}` \
                         (grammar: {CODEC_GRAMMAR})"
                    )))
                }
            }
            CodecSpec::Qsgd { bits } => {
                if (2..=8).contains(bits) {
                    Ok(())
                } else {
                    Err(CodecError::BadSpec(format!(
                        "qsgd bits must be in [2, 8], got `{bits}` \
                         (grammar: {CODEC_GRAMMAR})"
                    )))
                }
            }
            CodecSpec::LowRank { rank, iters } => {
                if !(1..=128).contains(rank) {
                    Err(CodecError::BadSpec(format!(
                        "low_rank rank must be in [1, 128], got `{rank}` \
                         (grammar: {CODEC_GRAMMAR})"
                    )))
                } else if !(1..=16).contains(iters) {
                    Err(CodecError::BadSpec(format!(
                        "low_rank iters must be in [1, 16], got `{iters}` \
                         (grammar: {CODEC_GRAMMAR})"
                    )))
                } else {
                    Ok(())
                }
            }
            CodecSpec::ErrorFeedback(inner) => inner.validate(),
        }
    }

    /// Build a fresh per-edge codec instance.
    pub fn build(&self) -> Box<dyn EdgeCodec> {
        match self {
            CodecSpec::Identity => Box::new(IdentityCodec),
            CodecSpec::RandK { k_frac, mode } => Box::new(RandKCodec {
                k_frac: *k_frac,
                mode: *mode,
            }),
            CodecSpec::TopK { k_frac } => Box::new(TopKCodec { k_frac: *k_frac }),
            CodecSpec::Qsgd { bits } => Box::new(QsgdCodec { bits: *bits }),
            CodecSpec::SignNorm => Box::new(SignNormCodec),
            CodecSpec::LowRank { rank, iters } => {
                Box::new(crate::compress::LowRankCodec::new(*rank, *iters))
            }
            CodecSpec::ErrorFeedback(inner) => {
                Box::new(ErrorFeedback::new(inner.build()))
            }
        }
    }

    /// Display name (identical to `EdgeCodec::name` of the built
    /// instance, without constructing one).
    pub fn name(&self) -> String {
        match self {
            CodecSpec::Identity => "identity".to_string(),
            CodecSpec::RandK { k_frac, mode } => {
                let pct = (k_frac * 100.0).round() as u32;
                match mode {
                    WireMode::Explicit => format!("rand_k {pct}%"),
                    WireMode::ValuesOnly => format!("rand_k {pct}% vo"),
                }
            }
            CodecSpec::TopK { k_frac } => {
                format!("top_k {}%", (k_frac * 100.0).round() as u32)
            }
            CodecSpec::Qsgd { bits } => format!("qsgd {bits}b"),
            CodecSpec::SignNorm => "sign".to_string(),
            CodecSpec::LowRank { rank, iters } => {
                if *iters == 1 {
                    format!("low_rank r{rank}")
                } else {
                    format!("low_rank r{rank}x{iters}")
                }
            }
            CodecSpec::ErrorFeedback(inner) => format!("ef+{}", inner.name()),
        }
    }

    /// The contraction parameter τ of Eq. (7), `E‖comp(x) − x‖² ≤
    /// (1 − τ)‖x‖²`.  Exact for rand-k (τ = k) and identity (τ = 1);
    /// a worst-case lower bound for top-k; the QSGD variance bound
    /// rescaled to contraction form; the Gaussian-typical 2/π for sign.
    /// Feeds the Eq. (47) α schedule.
    pub fn tau(&self, dim: usize) -> f64 {
        match self {
            CodecSpec::Identity => 1.0,
            CodecSpec::RandK { k_frac, .. } | CodecSpec::TopK { k_frac } => *k_frac,
            CodecSpec::Qsgd { bits } => {
                // Bucketed QSGD variance bound: min(B/s², √B/s) with B
                // the bucket size — dimension-independent for d ≥ B.
                // Eq. (7) reads E‖comp(x)−x‖² ≤ (1−τ)‖x‖², and the
                // unscaled decode has error var·‖x‖², so τ = 1 − var.
                // Low-bit QSGD (var ≥ 1) is NOT a contraction at all;
                // it gets a conservative floor so the α schedule treats
                // it as extreme compression instead of a mild one.
                let s = ((1u32 << (bits - 1)) - 1) as f64;
                let b = dim.clamp(1, QsgdCodec::BUCKET) as f64;
                let var = (b / (s * s)).min(b.sqrt() / s);
                (1.0 - var).max(0.01)
            }
            CodecSpec::SignNorm => 2.0 / std::f64::consts::PI,
            CodecSpec::LowRank { rank, .. } => {
                // Heuristic: the energy a rank-R factorization of a
                // near-square reshape can retain is value-dependent;
                // use the wire compression ratio R(rows+cols)/(rows·
                // cols) — exact for uniformly-spread spectra, a lower
                // bound once the warm start locks onto the top
                // directions — clamped into the α schedule's domain.
                let (rows, cols) = super::low_rank::near_square_shape(dim);
                (*rank as f64 * (rows + cols) as f64
                    / (rows * cols) as f64)
                    .clamp(0.01, 1.0)
            }
            CodecSpec::ErrorFeedback(inner) => inner.tau(dim),
        }
    }

    /// Whether Eq. (8) additivity holds for fixed ω — the license for
    /// the Eq. (13) dual rule.  Everything else runs under Eq. (11).
    pub fn is_linear_for_fixed_omega(&self) -> bool {
        matches!(self, CodecSpec::Identity | CodecSpec::RandK { .. })
    }

    /// Whether the codec is a full-rate mask (rand-k at k = 1): the
    /// protocol then uses the cheaper dense wire (4 B/coord, no index
    /// overhead), exactly like the uncompressed ECL.
    pub fn is_effectively_dense(&self) -> bool {
        matches!(self, CodecSpec::RandK { k_frac, .. } if *k_frac >= 1.0)
    }

    /// Analytic frame size at the *expected* support size — the wire
    /// ablation's accounting (`nnz = round(k·d)`, no sampling noise).
    pub fn nominal_frame_bytes(&self, dim: usize) -> usize {
        match self {
            CodecSpec::Identity => 4 * dim,
            CodecSpec::RandK { k_frac, mode } => {
                let nnz = ((dim as f64) * k_frac).round() as usize;
                match mode {
                    WireMode::Explicit => 8 * nnz,
                    WireMode::ValuesOnly => 4 * nnz,
                }
            }
            CodecSpec::TopK { k_frac } => {
                let nnz = (((dim as f64) * k_frac).round() as usize).clamp(1, dim);
                8 * nnz
            }
            CodecSpec::Qsgd { bits } => {
                4 * QsgdCodec::n_buckets(dim) + (dim * *bits as usize + 7) / 8
            }
            CodecSpec::SignNorm => 4 + (dim + 7) / 8,
            CodecSpec::LowRank { rank, .. } => {
                // Unbound (near-square reshape) accounting; a bound
                // model layout meters per layer matrix instead — equal
                // to PowerGossip's wire formula, pinned by tests.
                let (rows, cols) = super::low_rank::near_square_shape(dim);
                4 * rank * (rows + cols)
            }
            CodecSpec::ErrorFeedback(inner) => inner.nominal_frame_bytes(dim),
        }
    }
}

impl fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Empirically measure Eq. (7) for a codec: mean of
/// `‖decode(encode(x)) − x‖² / ‖x‖²` over `trials` rounds (ω varies
/// with the round through the shared-seed derivation).
pub fn measure_codec_contraction(
    spec: &CodecSpec,
    x: &[f32],
    trials: usize,
    seed: u64,
) -> f64 {
    let norm: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
    if norm == 0.0 {
        return 0.0;
    }
    let mut codec = spec.build();
    let mut acc = 0.0;
    for t in 0..trials.max(1) {
        let ctx = EdgeCtx {
            seed,
            edge: 0,
            round: t,
            receiver: 0,
            dim: x.len(),
            epoch: 0,
        };
        let frame = codec.encode(x, &ctx);
        let dense = codec.decode(&frame, &ctx).expect("self-decode");
        let err: f64 = x
            .iter()
            .zip(&dense)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum();
        acc += err / norm;
    }
    acc / trials.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn ctx(dim: usize, round: usize) -> EdgeCtx {
        EdgeCtx {
            seed: 42,
            edge: 3,
            round,
            receiver: 1,
            dim,
            epoch: 0,
        }
    }

    fn all_specs() -> Vec<CodecSpec> {
        vec![
            CodecSpec::Identity,
            CodecSpec::RandK { k_frac: 0.1, mode: WireMode::Explicit },
            CodecSpec::RandK { k_frac: 0.1, mode: WireMode::ValuesOnly },
            CodecSpec::TopK { k_frac: 0.05 },
            CodecSpec::Qsgd { bits: 4 },
            CodecSpec::SignNorm,
            CodecSpec::LowRank { rank: 2, iters: 1 },
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK { k_frac: 0.1 })),
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::LowRank {
                rank: 2,
                iters: 1,
            })),
        ]
    }

    #[test]
    fn every_codec_roundtrips_deterministically_from_shared_seed() {
        let d = 777;
        let x = randn(d, 1);
        for spec in all_specs() {
            // Two independent codec instances (the two edge endpoints)
            // must produce/consume identical frames from the shared ctx.
            let mut enc = spec.build();
            let mut enc2 = spec.build();
            let mut dec = spec.build();
            let c = ctx(d, 5);
            let f1 = enc.encode(&x, &c);
            let f2 = enc2.encode(&x, &c);
            assert_eq!(f1, f2, "{}: encode not deterministic", spec.name());
            assert_eq!(spec.name(), enc.name(), "spec/codec name drift");
            let y1 = dec.decode(&f1, &c).unwrap();
            let y2 = spec.build().decode(&f1, &c).unwrap();
            assert_eq!(y1, y2, "{}: decode not deterministic", spec.name());
            assert_eq!(y1.len(), d, "{}: wrong dim", spec.name());
            // Metered size is the actual buffer length.
            assert_eq!(f1.wire_bytes(), f1.bytes().len());
        }
    }

    #[test]
    fn identity_is_bit_exact_and_dense_sized() {
        let d = 513;
        let x = randn(d, 2);
        let mut c = CodecSpec::Identity.build();
        let e = ctx(d, 0);
        let f = c.encode(&x, &e);
        assert_eq!(f.wire_bytes(), 4 * d); // today's ECL dense accounting
        let y = c.decode(&f, &e).unwrap();
        for i in 0..d {
            assert_eq!(x[i].to_bits(), y[i].to_bits(), "coord {i}");
        }
    }

    #[test]
    fn qsgd_branch_free_matches_reference_bytes() {
        // Three buckets (two full + a tail), with the middle bucket
        // forced to zero norm (the RNG-skip path) and a -0.0 planted in
        // the tail (sign must come from `v < 0.0`, not the sign bit).
        let d = 2 * QsgdCodec::BUCKET + 176;
        for bits in [2u8, 4, 8] {
            for seed in 0..8u64 {
                let mut x = randn(d, 100 + seed);
                for v in
                    &mut x[QsgdCodec::BUCKET..2 * QsgdCodec::BUCKET]
                {
                    *v = 0.0;
                }
                x[2 * QsgdCodec::BUCKET + 3] = -0.0;
                let mut codec = QsgdCodec { bits };
                let c = ctx(d, seed as usize);
                let fast = codec.encode(&x, &c);
                let slow = codec.encode_reference(&x, &c);
                assert_eq!(
                    fast.bytes(),
                    slow.bytes(),
                    "qsgd:{bits} branch-free kernel diverged (seed {seed})"
                );
            }
        }
    }

    #[test]
    fn decode_into_matches_decode_for_every_spec() {
        // The zero-allocation receive path must be bit-identical to the
        // allocating one, for every codec in the CLI ladder, across
        // rounds (ω varies with the round) — including when the scratch
        // buffer arrives dirty from a previous message.
        let d = 777;
        for spec in all_specs() {
            let mut enc = spec.build();
            let mut dec_a = spec.build();
            let mut dec_b = spec.build();
            let mut out = vec![f32::NAN; d]; // dirty scratch
            for round in 0..5 {
                let x = randn(d, 50 + round as u64);
                let c = ctx(d, round);
                let f = enc.encode(&x, &c);
                let y = dec_a.decode(&f, &c).unwrap();
                dec_b.decode_into(&f, &c, &mut out).unwrap();
                for i in 0..d {
                    assert_eq!(
                        y[i].to_bits(),
                        out[i].to_bits(),
                        "{}: round {round} coord {i}",
                        spec.name()
                    );
                }
            }
        }
    }

    #[test]
    fn decode_into_rejects_wrong_scratch_length() {
        let d = 64;
        let x = randn(d, 33);
        let c = ctx(d, 0);
        for spec in all_specs() {
            let mut codec = spec.build();
            let f = codec.encode(&x, &c);
            let mut short = vec![0.0f32; d - 1];
            assert!(
                matches!(
                    codec.decode_into(&f, &c, &mut short),
                    Err(CodecError::Length { .. })
                ),
                "{}: undersized scratch not rejected",
                spec.name()
            );
        }
    }

    #[test]
    fn ef_residual_state_matches_decode_oracle_after_rounds() {
        // EF's encode self-decodes through `decode_into`; replay the
        // same math through the plain allocating `decode` and pin the
        // residual trajectory bit-for-bit after N rounds.
        let d = 512;
        let mut ef = ErrorFeedback::new(Box::new(TopKCodec { k_frac: 0.1 }));
        let mut oracle = TopKCodec { k_frac: 0.1 };
        let mut residual = vec![0.0f32; d];
        for round in 0..8 {
            let x = randn(d, 70 + round as u64);
            let c = ctx(d, round);
            let f = ef.encode(&x, &c);
            let carry: Vec<f32> =
                x.iter().zip(&residual).map(|(&a, &b)| a + b).collect();
            let f2 = oracle.encode(&carry, &c);
            assert_eq!(f.bytes(), f2.bytes(), "round {round}: frame drift");
            let est = oracle.decode(&f2, &c).unwrap();
            for ((rv, &cv), &ev) in
                residual.iter_mut().zip(&carry).zip(&est)
            {
                *rv = cv - ev;
            }
            for (i, (a, b)) in ef.residual().iter().zip(&residual).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "round {round} coord {i}: residual drift"
                );
            }
        }
    }

    #[test]
    fn topk_total_order_pins_nan_and_ties() {
        // NaN magnitudes rank above everything (always kept), and equal
        // magnitudes tie-break toward the lowest index — the selected
        // support must not depend on select_nth's visit order.
        let d = 8;
        let x = [1.0f32, -1.0, f32::NAN, 0.5, 1.0, 0.0, -0.5, 0.25];
        let mut tk = TopKCodec { k_frac: 0.375 }; // k = 3 of 8
        let f = tk.encode(&x, &ctx(d, 0));
        assert_eq!(f.wire_bytes(), 8 * 3);
        // NaN at idx 2 is kept; the |1.0| tie {0, 1, 4} resolves to the
        // two lowest indices 0 and 1.  Sorted support: [0, 1, 2].
        let idx: Vec<u32> =
            (0..3).map(|k| get_u32(f.bytes(), 4 * k)).collect();
        assert_eq!(idx, vec![0, 1, 2]);
        let vals: Vec<f32> =
            (0..3).map(|k| get_f32(f.bytes(), 4 * (3 + k))).collect();
        assert_eq!(vals[0].to_bits(), 1.0f32.to_bits());
        assert_eq!(vals[1].to_bits(), (-1.0f32).to_bits());
        assert!(vals[2].is_nan());

        // All-equal magnitudes: the support is exactly the first k
        // indices, whatever the signs.
        let y = [2.0f32, -2.0, 2.0, -2.0, 2.0, -2.0];
        let mut tk = TopKCodec { k_frac: 0.5 }; // k = 3 of 6
        let f = tk.encode(&y, &ctx(6, 0));
        let idx: Vec<u32> =
            (0..3).map(|k| get_u32(f.bytes(), 4 * k)).collect();
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn hotpath_counters_track_pool_misses_and_decode_allocs() {
        FRAME_POOL.with(|p| p.borrow_mut().clear());
        reset_hotpath_counters();
        let d = 64;
        let e = ctx(d, 0);
        let mut c = IdentityCodec;
        let x = randn(d, 21);
        let f = c.encode(&x, &e); // empty pool: one miss
        assert_eq!(hotpath_counters(), (1, 0));
        let mut out = vec![0.0f32; d];
        c.decode_into(&f, &e, &mut out).unwrap(); // native: no alloc
        assert_eq!(hotpath_counters(), (1, 0));
        let _ = c.decode(&f, &e).unwrap(); // dense path: counted
        assert_eq!(hotpath_counters(), (1, 1));
        drop(f);
        let f2 = c.encode(&x, &e); // recycled buffer: no new miss
        assert_eq!(hotpath_counters().0, 1);
        drop(f2);
        FRAME_POOL.with(|p| p.borrow_mut().clear());
        reset_hotpath_counters();
    }

    #[test]
    fn frame_pool_recycles_buffers_without_aliasing() {
        // Two live frames never share a buffer; dropping one and
        // encoding again reuses its capacity but not its contents.
        let d = 64;
        let e = ctx(d, 0);
        let mut c = IdentityCodec;
        let x = randn(d, 9);
        let y = randn(d, 10);
        let fx = c.encode(&x, &e);
        let fy = c.encode(&y, &e);
        assert_ne!(fx.bytes(), fy.bytes());
        let fx_copy = fx.bytes().to_vec();
        drop(fy);
        let fz = c.encode(&x, &e); // likely reuses fy's buffer
        assert_eq!(fz.bytes(), &fx_copy[..], "recycled buffer was dirty");
        assert_eq!(fx.bytes(), &fx_copy[..], "live frame clobbered");
    }

    #[test]
    fn randk_wire_modes_one_mask_two_sizes() {
        let d = 4096;
        let x = randn(d, 3);
        let e = ctx(d, 7);
        let mut ex = CodecSpec::RandK { k_frac: 0.1, mode: WireMode::Explicit }
            .build();
        let mut vo = CodecSpec::RandK { k_frac: 0.1, mode: WireMode::ValuesOnly }
            .build();
        let fe = ex.encode(&x, &e);
        let fv = vo.encode(&x, &e);
        // Same shared-seed mask ⇒ values-only is exactly half the bytes.
        assert_eq!(fe.wire_bytes(), 2 * fv.wire_bytes());
        // Both decode to the same dense vector.
        let ye = ex.decode(&fe, &e).unwrap();
        let yv = vo.decode(&fv, &e).unwrap();
        assert_eq!(ye, yv);
        // Support matches the decoded nonzeros.
        let support = ex.sparse_support(&e).unwrap();
        assert_eq!(support, vo.sparse_support(&e).unwrap());
        assert_eq!(fe.wire_bytes(), 8 * support.len());
        for (i, &v) in ye.iter().enumerate() {
            if support.binary_search(&(i as u32)).is_ok() {
                assert_eq!(v.to_bits(), x[i].to_bits());
            } else {
                assert_eq!(v, 0.0);
            }
        }
    }

    #[test]
    fn linear_codecs_satisfy_eq8_additivity_post_decode() {
        // decode(enc(x+y)) == decode(enc(x)) + decode(enc(y)) exactly,
        // for fixed ω (same ctx) — the Eq. (13) license, checked at the
        // byte level rather than on an in-memory operator.
        let d = 2048;
        let x = randn(d, 4);
        let y = randn(d, 5);
        let sum: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        for spec in [
            CodecSpec::Identity,
            CodecSpec::RandK { k_frac: 0.3, mode: WireMode::Explicit },
            CodecSpec::RandK { k_frac: 0.3, mode: WireMode::ValuesOnly },
        ] {
            assert!(spec.is_linear_for_fixed_omega());
            let mut c = spec.build();
            let e = ctx(d, 11);
            let fx = c.encode(&x, &e);
            let fy = c.encode(&y, &e);
            let fs = c.encode(&sum, &e);
            let dx = c.decode(&fx, &e).unwrap();
            let dy = c.decode(&fy, &e).unwrap();
            let ds = c.decode(&fs, &e).unwrap();
            for i in 0..d {
                assert_eq!(
                    ds[i].to_bits(),
                    (dx[i] + dy[i]).to_bits(),
                    "{}: Eq.8 violated at {i}",
                    spec.name()
                );
            }
        }
        // And the quantizers genuinely violate it (sanity of the flag).
        assert!(!CodecSpec::Qsgd { bits: 4 }.is_linear_for_fixed_omega());
        assert!(!CodecSpec::SignNorm.is_linear_for_fixed_omega());
        assert!(!CodecSpec::TopK { k_frac: 0.3 }.is_linear_for_fixed_omega());
    }

    #[test]
    fn measured_contraction_confirms_eq7_tau() {
        let d = 4096;
        let x = randn(d, 6);
        // rand-k: E‖comp(x) − x‖² = (1 − k)‖x‖² exactly in expectation.
        for mode in [WireMode::Explicit, WireMode::ValuesOnly] {
            let spec = CodecSpec::RandK { k_frac: 0.25, mode };
            let m = measure_codec_contraction(&spec, &x, 50, 9);
            assert!(
                (m - (1.0 - spec.tau(d))).abs() < 0.03,
                "rand_k: measured {m}"
            );
        }
        // top-k: at least as contractive as its τ = k lower bound.
        let spec = CodecSpec::TopK { k_frac: 0.25 };
        let m = measure_codec_contraction(&spec, &x, 1, 9);
        assert!(m <= 1.0 - spec.tau(d) + 1e-9, "top_k: measured {m}");
        // qsgd: within the variance-bound contraction.
        let spec = CodecSpec::Qsgd { bits: 8 };
        let m = measure_codec_contraction(&spec, &x, 10, 9);
        assert!(m <= 1.0 - spec.tau(d) + 0.02, "qsgd: measured {m}");
        assert!(m < 0.1, "qsgd 8-bit should be a fine quantizer: {m}");
        // sign: ‖comp(x) − x‖²/‖x‖² = 1 − ‖x‖₁²/(d‖x‖²) ≈ 1 − 2/π on
        // Gaussian input.
        let spec = CodecSpec::SignNorm;
        let m = measure_codec_contraction(&spec, &x, 1, 9);
        assert!(
            (m - (1.0 - 2.0 / std::f64::consts::PI)).abs() < 0.05,
            "sign: measured {m}"
        );
    }

    #[test]
    fn error_feedback_residual_reinjects_lost_energy() {
        // Repeatedly encoding the SAME vector, EF's emitted frames must
        // carry the lost coordinates eventually: the cumulative decoded
        // sum approaches r·x, which plain top-k never does for the
        // coordinates it always drops.
        let d = 512;
        let x = randn(d, 7);
        let spec =
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK { k_frac: 0.1 }));
        let mut ef = spec.build();
        let mut acc = vec![0.0f64; d];
        let rounds = 30;
        for r in 0..rounds {
            let e = ctx(d, r);
            let f = ef.encode(&x, &e);
            let y = ef.decode(&f, &e).unwrap();
            for (a, &v) in acc.iter_mut().zip(&y) {
                *a += v as f64;
            }
        }
        // Mean emitted value per round ≈ x everywhere (EF is unbiased in
        // the long run), including coordinates top-k alone would starve.
        let mut worst = 0.0f64;
        for i in 0..d {
            let mean = acc[i] / rounds as f64;
            worst = worst.max((mean - x[i] as f64).abs());
        }
        assert!(worst < 0.35, "EF starved a coordinate: worst gap {worst}");
    }

    #[test]
    fn corrupt_frames_yield_typed_errors_never_panic() {
        let d = 256;
        let x = randn(d, 8);
        let e = ctx(d, 1);

        // Explicit sparse: out-of-range index.
        let mut rk = CodecSpec::RandK { k_frac: 0.2, mode: WireMode::Explicit }
            .build();
        let mut f = rk.encode(&x, &e);
        f.bytes_mut()[0..4].copy_from_slice(&(d as u32 + 99).to_le_bytes());
        assert!(matches!(
            rk.decode(&f, &e),
            Err(CodecError::IndexOutOfRange { .. })
        ));

        // Explicit sparse: truncated to a ragged length.
        let mut f = rk.encode(&x, &e);
        f.bytes_mut().pop();
        assert!(matches!(rk.decode(&f, &e), Err(CodecError::Ragged { .. })));

        // Explicit sparse: duplicate index breaks strict ordering.
        let mut f = rk.encode(&x, &e);
        let first = f.bytes()[0..4].to_vec();
        f.bytes_mut()[4..8].copy_from_slice(&first);
        assert!(matches!(
            rk.decode(&f, &e),
            Err(CodecError::UnsortedIndex { .. })
        ));

        // Values-only: wrong payload length for the derived mask.
        let mut vo = CodecSpec::RandK { k_frac: 0.2, mode: WireMode::ValuesOnly }
            .build();
        let mut f = vo.encode(&x, &e);
        f.bytes_mut().extend_from_slice(&[0; 4]);
        assert!(matches!(vo.decode(&f, &e), Err(CodecError::Length { .. })));

        // Dense / bit-packed codecs: length mismatch.
        for spec in [CodecSpec::Identity, CodecSpec::Qsgd { bits: 4 },
                     CodecSpec::SignNorm] {
            let mut c = spec.build();
            let mut f = c.encode(&x, &e);
            f.bytes_mut().pop();
            assert!(
                matches!(c.decode(&f, &e), Err(CodecError::Length { .. })),
                "{}: truncation not caught",
                spec.name()
            );
        }

        // Scalar-prefixed codecs: a corrupted NaN/Inf norm must not
        // silently poison the decoded vector.
        for spec in [CodecSpec::Qsgd { bits: 4 }, CodecSpec::SignNorm] {
            let mut c = spec.build();
            let mut f = c.encode(&x, &e);
            f.bytes_mut()[0..4].copy_from_slice(&f32::NAN.to_le_bytes());
            assert!(
                matches!(c.decode(&f, &e), Err(CodecError::NonFiniteScalar)),
                "{}: NaN norm not caught",
                spec.name()
            );
        }
    }

    #[test]
    fn whole_record_truncation_is_caught() {
        // Dropping a trailing 8-byte record keeps the frame 8-aligned
        // but shifts the idx/val block boundary — the decoded values
        // would be garbage.  Explicit rand-k pins the support against
        // the shared-seed mask; top-k pins the record count.
        let d = 256;
        let x = randn(d, 12);
        let e = ctx(d, 2);
        let mut rk = CodecSpec::RandK { k_frac: 0.2, mode: WireMode::Explicit }
            .build();
        let mut f = rk.encode(&x, &e);
        f.bytes_mut().truncate(f.wire_bytes() - 8);
        assert!(
            matches!(rk.decode(&f, &e), Err(CodecError::SupportMismatch { .. })),
            "rand-k: record truncation not caught"
        );
        let mut tk = CodecSpec::TopK { k_frac: 0.2 }.build();
        let mut f = tk.encode(&x, &e);
        f.bytes_mut().truncate(f.wire_bytes() - 8);
        assert!(
            matches!(tk.decode(&f, &e), Err(CodecError::Length { .. })),
            "top-k: record truncation not caught"
        );
    }

    #[test]
    fn encode_from_matches_dense_encode_byte_for_byte() {
        // The sparse-input send fast path must serialize exactly what
        // the dense encode would.
        let d = 2048;
        let x = randn(d, 13);
        let e = ctx(d, 4);
        for mode in [WireMode::Explicit, WireMode::ValuesOnly] {
            let spec = CodecSpec::RandK { k_frac: 0.2, mode };
            let mut dense = spec.build();
            let mut sparse = spec.build();
            let fd = dense.encode(&x, &e);
            let fs = sparse
                .encode_from(&|i| x[i], &e)
                .expect("rand-k has the fast path");
            assert_eq!(fd, fs, "{}: encode_from drifted", spec.name());
        }
        // Dense-input codecs opt out of the fast path.
        for spec in [CodecSpec::Identity, CodecSpec::Qsgd { bits: 4 },
                     CodecSpec::SignNorm, CodecSpec::TopK { k_frac: 0.2 }] {
            assert!(
                spec.build().encode_from(&|_: usize| 0.0f32, &e).is_none(),
                "{}: unexpected fast path",
                spec.name()
            );
        }
    }

    #[test]
    fn spec_parse_grammar_and_names() {
        assert_eq!(CodecSpec::parse("identity").unwrap(), CodecSpec::Identity);
        assert_eq!(
            CodecSpec::parse("rand_k:0.1").unwrap(),
            CodecSpec::RandK { k_frac: 0.1, mode: WireMode::Explicit }
        );
        assert_eq!(
            CodecSpec::parse("rand_k:0.1:values").unwrap(),
            CodecSpec::RandK { k_frac: 0.1, mode: WireMode::ValuesOnly }
        );
        assert_eq!(
            CodecSpec::parse("top_k:0.01").unwrap(),
            CodecSpec::TopK { k_frac: 0.01 }
        );
        assert_eq!(
            CodecSpec::parse("qsgd:4").unwrap(),
            CodecSpec::Qsgd { bits: 4 }
        );
        assert_eq!(CodecSpec::parse("sign").unwrap(), CodecSpec::SignNorm);
        assert_eq!(
            CodecSpec::parse("low_rank:2").unwrap(),
            CodecSpec::LowRank { rank: 2, iters: 1 }
        );
        assert_eq!(
            CodecSpec::parse("low_rank:2:3").unwrap(),
            CodecSpec::LowRank { rank: 2, iters: 3 }
        );
        assert_eq!(
            CodecSpec::parse("ef+top_k:0.01").unwrap(),
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK { k_frac: 0.01 }))
        );
        assert_eq!(
            CodecSpec::parse("ef+low_rank:2").unwrap(),
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::LowRank {
                rank: 2,
                iters: 1,
            }))
        );
        // Broken specs fail loudly with a typed error that names the
        // offending token AND restates the grammar — degenerate
        // parameters (zero ranks/fractions/bit widths, over-full
        // fractions) are caught HERE, not deep inside encode.
        for (bad, token) in [
            ("", ""),
            ("bogus", "`bogus`"),
            ("rand_k", "argument count"),
            ("rand_k:0", "`0`"),
            ("rand_k:0.0", "`0`"),
            ("rand_k:1.5", "`1.5`"),
            ("rand_k:-0.1", "`-0.1`"),
            ("rand_k:0.1:weird", "`weird`"),
            ("top_k:0", "`0`"),
            ("top_k:1.5", "`1.5`"),
            ("qsgd:0", "`0`"),
            ("qsgd:1", "`1`"),
            ("qsgd:9", "`9`"),
            ("qsgd:x", "`x`"),
            ("low_rank", "argument count"),
            ("low_rank:0", "`0`"),
            ("low_rank:129", "`129`"),
            ("low_rank:2:0", "`0`"),
            ("low_rank:2:17", "`17`"),
            ("low_rank:x", "`x`"),
            ("low_rank:2:3:4", "argument count"),
            ("ef+ef+sign", "base codec"),
            ("ef+low_rank:0", "`0`"),
            ("top_k:nope", "`nope`"),
            ("sign:1", "argument count"),
            ("identity:x", "argument count"),
        ] {
            let err = CodecSpec::parse(bad)
                .err()
                .unwrap_or_else(|| panic!("`{bad}` should not parse"));
            assert!(matches!(err, CodecError::BadSpec(_)), "`{bad}`: {err}");
            let msg = err.to_string();
            assert!(msg.contains(token), "`{bad}`: `{msg}` misses `{token}`");
            assert!(
                msg.contains("grammar"),
                "`{bad}`: `{msg}` must restate the grammar"
            );
        }
        assert_eq!(CodecSpec::parse("qsgd:4").unwrap().name(), "qsgd 4b");
        assert_eq!(
            CodecSpec::parse("ef+top_k:0.1").unwrap().name(),
            "ef+top_k 10%"
        );
        assert_eq!(
            CodecSpec::parse("rand_k:0.1:vo").unwrap().name(),
            "rand_k 10% vo"
        );
        assert_eq!(CodecSpec::parse("low_rank:2").unwrap().name(),
                   "low_rank r2");
        assert_eq!(CodecSpec::parse("low_rank:2:3").unwrap().name(),
                   "low_rank r2x3");
        assert_eq!(CodecSpec::parse("ef+low_rank:1").unwrap().name(),
                   "ef+low_rank r1");
    }

    #[test]
    fn nominal_bytes_match_wire_ablation_accounting() {
        let d = 60416usize; // fashion-scale d_pad
        let nnz = |k: f64| (d as f64 * k).round() as usize;
        for k in [0.01, 0.1, 0.2] {
            assert_eq!(
                CodecSpec::RandK { k_frac: k, mode: WireMode::Explicit }
                    .nominal_frame_bytes(d),
                8 * nnz(k)
            );
            assert_eq!(
                CodecSpec::RandK { k_frac: k, mode: WireMode::ValuesOnly }
                    .nominal_frame_bytes(d),
                4 * nnz(k)
            );
        }
        assert_eq!(CodecSpec::Identity.nominal_frame_bytes(d), 4 * d);
        let buckets = (d + QsgdCodec::BUCKET - 1) / QsgdCodec::BUCKET;
        assert_eq!(
            CodecSpec::Qsgd { bits: 4 }.nominal_frame_bytes(d),
            4 * buckets + (4 * d + 7) / 8
        );
        assert_eq!(
            CodecSpec::SignNorm.nominal_frame_bytes(d),
            4 + (d + 7) / 8
        );
        // low_rank's unbound accounting must equal the bytes a real
        // unbound codec instance serializes (shared reshape helper).
        let spec = CodecSpec::LowRank { rank: 2, iters: 1 };
        let x = randn(d, 99);
        let f = spec.build().encode(&x, &ctx(d, 0));
        assert_eq!(spec.nominal_frame_bytes(d), f.wire_bytes());
    }

    #[test]
    fn tau_values_sane() {
        assert_eq!(CodecSpec::Identity.tau(100), 1.0);
        assert_eq!(
            CodecSpec::RandK { k_frac: 0.1, mode: WireMode::Explicit }.tau(100),
            0.1
        );
        let t = CodecSpec::Qsgd { bits: 8 }.tau(4096);
        assert!(t > 0.0 && t < 1.0, "qsgd tau {t}");
        let s = CodecSpec::SignNorm.tau(10);
        assert!((s - 2.0 / std::f64::consts::PI).abs() < 1e-12);
        // EF inherits the inner τ (α schedule keys off the inner rate).
        assert_eq!(
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK { k_frac: 0.2 }))
                .tau(100),
            0.2
        );
    }

    #[test]
    fn effectively_dense_only_for_full_rate_randk() {
        assert!(CodecSpec::RandK { k_frac: 1.0, mode: WireMode::Explicit }
            .is_effectively_dense());
        assert!(!CodecSpec::RandK { k_frac: 0.5, mode: WireMode::Explicit }
            .is_effectively_dense());
        // Identity intentionally runs the frame path (byte-identical to
        // dense) so the codec wire is exercised end to end.
        assert!(!CodecSpec::Identity.is_effectively_dense());
    }

    // The `pool_*` tests below are the Miri CI scope (the one
    // hand-rolled free list on the hot path); keep the prefix so the
    // job's test filter finds them.

    #[test]
    fn pool_recycles_dropped_frame_buffers() {
        FRAME_POOL.with(|p| p.borrow_mut().clear());
        let f = Frame::new(vec![7u8; 64]);
        assert_eq!(f.bytes().len(), 64);
        drop(f);
        let before = FRAME_POOL.with(|p| p.borrow().len());
        assert_eq!(before, 1, "dropped frame's buffer not pooled");
        let buf = pooled_buf(16);
        assert!(buf.is_empty(), "recycled buffer must come back cleared");
        assert!(buf.capacity() >= 16);
        assert_eq!(FRAME_POOL.with(|p| p.borrow().len()), 0);
    }

    #[test]
    fn pool_is_bounded_by_its_cap() {
        FRAME_POOL.with(|p| p.borrow_mut().clear());
        let frames: Vec<Frame> = (0..POOL_MAX + 10)
            .map(|_| Frame::new(vec![1u8; 8]))
            .collect();
        drop(frames);
        assert_eq!(FRAME_POOL.with(|p| p.borrow().len()), POOL_MAX);
        FRAME_POOL.with(|p| p.borrow_mut().clear());
    }

    #[test]
    fn pool_ignores_capacityless_buffers() {
        FRAME_POOL.with(|p| p.borrow_mut().clear());
        drop(Frame::new(Vec::new()));
        assert_eq!(FRAME_POOL.with(|p| p.borrow().len()), 0);
    }

    #[test]
    fn pool_roundtrip_through_a_codec_reuses_the_buffer() {
        FRAME_POOL.with(|p| p.borrow_mut().clear());
        let ctx = EdgeCtx {
            seed: 7,
            edge: 0,
            round: 0,
            receiver: 1,
            dim: 32,
            epoch: 0,
        };
        let x: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let mut codec = IdentityCodec;
        let frame = codec.encode(&x, &ctx);
        let got = codec.decode(&frame, &ctx).unwrap();
        assert_eq!(got, x);
        drop(frame);
        // The encode buffer came back; a second encode pops it again.
        assert_eq!(FRAME_POOL.with(|p| p.borrow().len()), 1);
        let frame2 = codec.encode(&x, &ctx);
        assert_eq!(FRAME_POOL.with(|p| p.borrow().len()), 0);
        drop(frame2);
        FRAME_POOL.with(|p| p.borrow_mut().clear());
    }
}
