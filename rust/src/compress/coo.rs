//! Sparse COO vector: the wire format for compressed dual variables.
//!
//! Byte accounting matches the paper's tables: a transmitted COO vector
//! costs `4 * nnz` bytes of u32 indices plus `4 * nnz` bytes of f32
//! values (so C-ECL(10%) lands at ~x5 vs dense, exactly the paper's
//! ratio). With the shared-seed mask both endpoints could skip the index
//! half; that further halving is measured as an ablation
//! (`repro ablation-wire`) rather than baked into the headline numbers,
//! to stay comparable with the paper's accounting.

/// Sparse vector in coordinate format over a dense dimension `d`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CooVec {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl CooVec {
    pub fn new(dim: usize) -> CooVec {
        CooVec {
            dim,
            idx: Vec::new(),
            val: Vec::new(),
        }
    }

    pub fn with_capacity(dim: usize, cap: usize) -> CooVec {
        CooVec {
            dim,
            idx: Vec::with_capacity(cap),
            val: Vec::with_capacity(cap),
        }
    }

    /// Gather `x` at `indices` (the comp(x; ω) of Example 1 with ω known).
    pub fn gather(x: &[f32], indices: &[u32]) -> CooVec {
        let mut v = CooVec::with_capacity(x.len(), indices.len());
        for &i in indices {
            v.idx.push(i);
            v.val.push(x[i as usize]);
        }
        v
    }

    /// Re-fill from `x` at `indices`, reusing allocations (hot path).
    pub fn gather_into(&mut self, x: &[f32], indices: &[u32]) {
        self.dim = x.len();
        self.idx.clear();
        self.val.clear();
        self.idx.extend_from_slice(indices);
        for &i in indices {
            self.val.push(x[i as usize]);
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Bytes on the wire (paper accounting: indices + values).
    pub fn wire_bytes(&self) -> usize {
        8 * self.nnz()
    }

    /// Bytes on the wire when the sparsity pattern is derivable from the
    /// shared seed (values only).
    pub fn wire_bytes_values_only(&self) -> usize {
        4 * self.nnz()
    }

    /// Dense materialization (masked-out entries zero).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.scatter_into(&mut out);
        out
    }

    /// Write into a pre-zeroed (or to-be-overwritten) dense buffer:
    /// `out` is cleared then scattered. Reuses the allocation.
    pub fn scatter_into_cleared(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.dim, 0.0);
        self.scatter_into(out);
    }

    /// `out[idx[k]] = val[k]` (no clearing).
    pub fn scatter_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
    }

    /// `out[idx[k]] += alpha * val[k]` — the fused receive-side update.
    pub fn axpy_into(&self, alpha: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += alpha * v;
        }
    }

    /// Squared L2 norm of the sparse values.
    pub fn norm2_sq(&self) -> f64 {
        self.val.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let v = CooVec::gather(&x, &[1, 3]);
        assert_eq!(v.nnz(), 2);
        assert_eq!(v.to_dense(), vec![0.0, 2.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn gather_into_reuses() {
        let x = vec![1.0, 2.0, 3.0];
        let mut v = CooVec::new(3);
        v.gather_into(&x, &[0, 2]);
        assert_eq!(v.val, vec![1.0, 3.0]);
        v.gather_into(&x, &[1]);
        assert_eq!(v.val, vec![2.0]);
        assert_eq!(v.idx, vec![1]);
    }

    #[test]
    fn wire_bytes_accounting() {
        let v = CooVec::gather(&[0.0; 100], &[1, 2, 3]);
        assert_eq!(v.wire_bytes(), 24);
        assert_eq!(v.wire_bytes_values_only(), 12);
    }

    #[test]
    fn axpy_accumulates() {
        let mut out = vec![1.0f32; 4];
        let v = CooVec::gather(&[10.0, 20.0, 30.0, 40.0], &[0, 2]);
        v.axpy_into(0.5, &mut out);
        assert_eq!(out, vec![6.0, 1.0, 16.0, 1.0]);
    }

    #[test]
    fn norm_matches_dense() {
        let v = CooVec::gather(&[3.0, 0.0, 4.0], &[0, 2]);
        assert!((v.norm2_sq() - 25.0).abs() < 1e-12);
    }
}
