//! Sparse COO vector: the PJRT-kernel interop format and the
//! `Msg::Sparse` payload.
//!
//! Byte accounting matches the paper's tables: a transmitted COO vector
//! costs `4 * nnz` bytes of u32 indices plus `4 * nnz` bytes of f32
//! values (so C-ECL(10%) lands at ~x5 vs dense, exactly the paper's
//! ratio) — the same accounting the explicit-index wire mode of the
//! rand-k codec serializes for real (`compress::codec`).  The
//! values-only halving the shared seed enables is the codec layer's
//! `WireMode::ValuesOnly`; `repro ablation-wire` reports both through
//! `CodecSpec::nominal_frame_bytes`.
//!
//! Decode paths must use the checked accessors ([`CooVec::validate`],
//! [`CooVec::try_to_dense`], [`CooVec::try_gather`]): the unchecked
//! `gather`/`scatter_into` panic on out-of-range indices and are for
//! trusted, locally-constructed vectors only.

use super::codec::CodecError;

/// Sparse vector in coordinate format over a dense dimension `d`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CooVec {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl CooVec {
    pub fn new(dim: usize) -> CooVec {
        CooVec {
            dim,
            idx: Vec::new(),
            val: Vec::new(),
        }
    }

    pub fn with_capacity(dim: usize, cap: usize) -> CooVec {
        CooVec {
            dim,
            idx: Vec::with_capacity(cap),
            val: Vec::with_capacity(cap),
        }
    }

    /// Gather `x` at `indices` (the comp(x; ω) of Example 1 with ω known).
    /// Panics on out-of-range indices — callers with untrusted indices
    /// use [`CooVec::try_gather`].
    pub fn gather(x: &[f32], indices: &[u32]) -> CooVec {
        let mut v = CooVec::with_capacity(x.len(), indices.len());
        for &i in indices {
            v.idx.push(i);
            v.val.push(x[i as usize]);
        }
        v
    }

    /// Checked gather: a typed [`CodecError`] instead of a panic when an
    /// index falls outside `x`.
    pub fn try_gather(x: &[f32], indices: &[u32]) -> Result<CooVec, CodecError> {
        if let Some(&bad) = indices.iter().find(|&&i| (i as usize) >= x.len()) {
            return Err(CodecError::IndexOutOfRange {
                idx: bad,
                dim: x.len(),
            });
        }
        Ok(CooVec::gather(x, indices))
    }

    /// Validate every index against `dim` — run this before scattering a
    /// vector that crossed a trust boundary (wire, disk).
    pub fn validate(&self) -> Result<(), CodecError> {
        if self.idx.len() != self.val.len() {
            return Err(CodecError::ArityMismatch {
                idx: self.idx.len(),
                vals: self.val.len(),
            });
        }
        if let Some(&bad) = self.idx.iter().find(|&&i| (i as usize) >= self.dim)
        {
            return Err(CodecError::IndexOutOfRange {
                idx: bad,
                dim: self.dim,
            });
        }
        Ok(())
    }

    /// Checked dense materialization: [`CooVec::validate`] +
    /// [`CooVec::to_dense`].
    pub fn try_to_dense(&self) -> Result<Vec<f32>, CodecError> {
        self.validate()?;
        Ok(self.to_dense())
    }

    /// Re-fill from `x` at `indices`, reusing allocations (hot path).
    pub fn gather_into(&mut self, x: &[f32], indices: &[u32]) {
        self.dim = x.len();
        self.idx.clear();
        self.val.clear();
        self.idx.extend_from_slice(indices);
        for &i in indices {
            self.val.push(x[i as usize]);
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Bytes on the wire (paper accounting: indices + values) — equal to
    /// the serialized length of the rand-k codec's explicit-index frame.
    pub fn wire_bytes(&self) -> usize {
        8 * self.nnz()
    }

    /// Dense materialization (masked-out entries zero).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.scatter_into(&mut out);
        out
    }

    /// Write into a pre-zeroed (or to-be-overwritten) dense buffer:
    /// `out` is cleared then scattered. Reuses the allocation.
    pub fn scatter_into_cleared(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.dim, 0.0);
        self.scatter_into(out);
    }

    /// `out[idx[k]] = val[k]` (no clearing).
    pub fn scatter_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
    }

    /// `out[idx[k]] += alpha * val[k]` — the fused receive-side update.
    pub fn axpy_into(&self, alpha: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += alpha * v;
        }
    }

    /// Squared L2 norm of the sparse values.
    pub fn norm2_sq(&self) -> f64 {
        self.val.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let v = CooVec::gather(&x, &[1, 3]);
        assert_eq!(v.nnz(), 2);
        assert_eq!(v.to_dense(), vec![0.0, 2.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn gather_into_reuses() {
        let x = vec![1.0, 2.0, 3.0];
        let mut v = CooVec::new(3);
        v.gather_into(&x, &[0, 2]);
        assert_eq!(v.val, vec![1.0, 3.0]);
        v.gather_into(&x, &[1]);
        assert_eq!(v.val, vec![2.0]);
        assert_eq!(v.idx, vec![1]);
    }

    #[test]
    fn wire_bytes_accounting() {
        let v = CooVec::gather(&[0.0; 100], &[1, 2, 3]);
        assert_eq!(v.wire_bytes(), 24);
    }

    #[test]
    fn corrupt_indices_surface_typed_errors() {
        use crate::compress::codec::CodecError;
        // try_gather refuses out-of-range indices instead of panicking.
        let err = CooVec::try_gather(&[1.0, 2.0], &[0, 7]).unwrap_err();
        assert_eq!(err, CodecError::IndexOutOfRange { idx: 7, dim: 2 });
        assert!(CooVec::try_gather(&[1.0, 2.0], &[0, 1]).is_ok());
        // A corrupted vector fails validation and checked densify.
        let mut v = CooVec::gather(&[1.0, 2.0, 3.0], &[0, 2]);
        v.idx[1] = 9;
        assert_eq!(
            v.validate().unwrap_err(),
            CodecError::IndexOutOfRange { idx: 9, dim: 3 }
        );
        assert!(v.try_to_dense().is_err());
        v.idx[1] = 1;
        assert_eq!(v.try_to_dense().unwrap(), vec![1.0, 3.0, 0.0]);
    }

    #[test]
    fn axpy_accumulates() {
        let mut out = vec![1.0f32; 4];
        let v = CooVec::gather(&[10.0, 20.0, 30.0, 40.0], &[0, 2]);
        v.axpy_into(0.5, &mut out);
        assert_eq!(out, vec![6.0, 1.0, 16.0, 1.0]);
    }

    #[test]
    fn norm_matches_dense() {
        let v = CooVec::gather(&[3.0, 0.0, 4.0], &[0, 2]);
        assert!((v.norm2_sq() - 25.0).abs() < 1e-12);
    }
}
