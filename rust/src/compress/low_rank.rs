//! Low-rank (PowerGossip-style) compression primitives, plus the
//! [`LowRankCodec`] that packages them as an [`EdgeCodec`].
//!
//! PowerGossip (Vogels, Karimireddy, Jaggi 2020) compresses the per-edge
//! model *difference* `D = M_lo − M_hi` (per layer matrix) with warm-
//! started power iteration: both endpoints hold an identical unit vector
//! `q̂`; each exchanges `p_x = M_x q̂` (rows floats) and `s_x = M_xᵀ p̂`
//! (cols floats), from which both reconstruct the same rank-1
//! approximation `p q̂ᵀ ≈ D` and the same next `q̂`.  The warm start
//! across rounds is what makes one step per round sufficient in practice
//! (the paper's PowerGossip(1) row).
//!
//! The same operator also works as a one-shot codec (`low_rank:R` in the
//! `--codec` grammar): encode deflates rank-R factors out of the input
//! and ships the `(p, q)` pairs explicitly, so C-ECL can run the
//! PowerGossip compressor through the Eq. (11) dual rule.  The
//! interactive two-node choreography lives in `algorithms::powergossip`.

use crate::compress::codec::{
    note_decode_alloc, pooled_buf, CodecError, EdgeCodec, EdgeCtx, Frame,
};
use crate::util::rng::{streams, Pcg};

/// `p = M q` for a row-major `rows x cols` matrix stored in a flat
/// slice.  The per-row dot product is 4-way unrolled with independent
/// accumulators — breaking the serial add dependence is what lets the
/// compiler keep four FMA chains in flight (and vectorize).  Summation
/// order differs from [`matvec_f32_reference`], so results agree to
/// rounding, not bit-exactly; every consumer of this function
/// tolerates that (PowerGossip normalizes, the codec ships whatever
/// was computed to both ends).
pub fn matvec_f32(m: &[f32], rows: usize, cols: usize, q: &[f32]) -> Vec<f32> {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(q.len(), cols);
    let mut p = vec![0.0f32; rows];
    let split = cols & !3;
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
        for (c4, q4) in row[..split].chunks_exact(4).zip(q[..split].chunks_exact(4)) {
            a0 += c4[0] * q4[0];
            a1 += c4[1] * q4[1];
            a2 += c4[2] * q4[2];
            a3 += c4[3] * q4[3];
        }
        let mut acc = (a0 + a2) + (a1 + a3);
        for (a, b) in row[split..].iter().zip(&q[split..]) {
            acc += a * b;
        }
        p[r] = acc;
    }
    p
}

/// `s = Mᵀ p`, blocked four rows at a time: each pass streams four
/// matrix rows against one traversal of `s`, quartering the traffic on
/// the output vector versus the row-at-a-time reference.  Same
/// rounding caveat as [`matvec_f32`].
pub fn matvec_t_f32(m: &[f32], rows: usize, cols: usize, p: &[f32]) -> Vec<f32> {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(p.len(), rows);
    let mut s = vec![0.0f32; cols];
    let rsplit = rows & !3;
    for r in (0..rsplit).step_by(4) {
        let (p0, p1, p2, p3) = (p[r], p[r + 1], p[r + 2], p[r + 3]);
        if p0 == 0.0 && p1 == 0.0 && p2 == 0.0 && p3 == 0.0 {
            continue;
        }
        let base = r * cols;
        let r0 = &m[base..base + cols];
        let r1 = &m[base + cols..base + 2 * cols];
        let r2 = &m[base + 2 * cols..base + 3 * cols];
        let r3 = &m[base + 3 * cols..base + 4 * cols];
        for j in 0..cols {
            s[j] += (r0[j] * p0 + r2[j] * p2) + (r1[j] * p1 + r3[j] * p3);
        }
    }
    for r in rsplit..rows {
        let row = &m[r * cols..(r + 1) * cols];
        let pr = p[r];
        if pr == 0.0 {
            continue;
        }
        for (sj, a) in s.iter_mut().zip(row) {
            *sj += a * pr;
        }
    }
    s
}

/// The straight-line `p = M q` loop the blocked kernel replaced.  Kept
/// as the accuracy oracle for tests and the `micro_hotpath` A/B rows.
#[doc(hidden)]
pub fn matvec_f32_reference(
    m: &[f32], rows: usize, cols: usize, q: &[f32],
) -> Vec<f32> {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(q.len(), cols);
    let mut p = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(q) {
            acc += a * b;
        }
        p[r] = acc;
    }
    p
}

/// The row-at-a-time `s = Mᵀ p` loop the blocked kernel replaced.
#[doc(hidden)]
pub fn matvec_t_f32_reference(
    m: &[f32], rows: usize, cols: usize, p: &[f32],
) -> Vec<f32> {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(p.len(), rows);
    let mut s = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        let pr = p[r];
        if pr == 0.0 {
            continue;
        }
        for (sj, a) in s.iter_mut().zip(row) {
            *sj += a * pr;
        }
    }
    s
}

/// `out += alpha * p qᵀ` (rank-1 update of a row-major matrix).
pub fn rank1_axpy(out: &mut [f32], rows: usize, cols: usize, alpha: f32,
                  p: &[f32], q: &[f32]) {
    assert_eq!(out.len(), rows * cols);
    assert_eq!(p.len(), rows);
    assert_eq!(q.len(), cols);
    for r in 0..rows {
        let coeff = alpha * p[r];
        if coeff == 0.0 {
            continue;
        }
        let row = &mut out[r * cols..(r + 1) * cols];
        for (o, &qj) in row.iter_mut().zip(q) {
            *o += coeff * qj;
        }
    }
}

/// Normalize in place; returns the original norm. Zero vectors are left
/// unchanged (norm 0 returned) so callers can re-randomize.
pub fn normalize(v: &mut [f32]) -> f32 {
    let norm = v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
        as f32;
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

/// One power-iteration step on the implicit difference `D = M_lo − M_hi`
/// given both halves of the exchange. Returns `(p, q_hat_next)` where
/// `p = D q̂` and `q_hat_next = normalize(Dᵀ p̂)`.
///
/// Both endpoints call this with the same inputs (their own half plus the
/// received half), so the results are bit-identical on the two sides.
pub fn power_iteration_step(
    p_lo: &[f32],
    p_hi: &[f32],
    s_lo: &[f32],
    s_hi: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let p: Vec<f32> = p_lo.iter().zip(p_hi).map(|(a, b)| a - b).collect();
    let mut q_next: Vec<f32> =
        s_lo.iter().zip(s_hi).map(|(a, b)| a - b).collect();
    normalize(&mut q_next);
    (p, q_next)
}

/// Warm-start state for one (edge, layer-matrix) pair. Both endpoints
/// construct it from the same derived RNG, so `q_hat` starts identical
/// and stays identical (all updates are deterministic functions of
/// exchanged values).
#[derive(Debug, Clone)]
pub struct LowRankEdgeState {
    pub q_hat: Vec<f32>,
}

impl LowRankEdgeState {
    pub fn new(cols: usize, rng: &mut Pcg) -> LowRankEdgeState {
        let mut q: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        normalize(&mut q);
        LowRankEdgeState { q_hat: q }
    }

    /// Re-randomize if power iteration collapsed (q ≈ 0, e.g. identical
    /// matrices on both sides).
    pub fn reseed_if_degenerate(&mut self, rng: &mut Pcg) {
        let norm: f32 = self.q_hat.iter().map(|x| x * x).sum();
        if norm < 1e-12 {
            for x in self.q_hat.iter_mut() {
                *x = rng.normal_f32();
            }
            normalize(&mut self.q_hat);
        }
    }
}

// ---------------------------------------------------------------------
// The low-rank edge codec (`low_rank:R[:iters]`)
// ---------------------------------------------------------------------

/// One matrix view the codec compresses: `(offset, rows, cols, len)`
/// into the flat vector.  `len < rows·cols` only for the generic
/// reshape of an unbound codec, where the tail of the matrix is
/// zero-padding.
type MatView = (usize, usize, usize, usize);

/// Near-square reshape of a flat `dim`-vector: `(rows, cols)` with
/// `cols = ⌈√d⌉` and `rows = ⌈d/cols⌉` (zero-padded to `rows·cols`).
/// The single definition behind the unbound [`LowRankCodec`] layout AND
/// the spec-level accounting (`CodecSpec::{tau, nominal_frame_bytes}`)
/// — they must never drift apart, or metered bytes would diverge from
/// the sizing tables.
pub fn near_square_shape(dim: usize) -> (usize, usize) {
    let cols = ((dim as f64).sqrt().ceil().max(1.0)) as usize;
    let rows = ((dim + cols - 1) / cols).max(1);
    (rows, cols)
}

/// PowerGossip-as-a-codec: rank-R power-iteration compression of each
/// layer matrix, rank-1 tensors shipped dense — the exact wire
/// accounting of `PowerGossipNode::bytes_per_round_per_neighbor`
/// (`algorithms::powergossip`) at `iters = R`, which the tests pin.
///
/// * **Frame layout**: per matrix view, `R` explicit `(p, q)` factor
///   pairs (`rows + cols` f32 each, deflated greedily: rank `k+1`
///   approximates the residual left by ranks `0..k`); then every
///   rank-1 tensor raw.  Frame length is deterministic per layout, so
///   decode validates it exactly.
/// * **Warm start**: the per-edge codec instance keeps one q̂ per
///   (view, rank), seeded from the shared-seed derivation
///   `(POWER, edge, receiver, view, rank)` of the first [`EdgeCtx`] it
///   encodes with, and updated after every encode with
///   `normalize(Mᵀ p̂)` — repeated encodes of a slowly-moving input
///   converge on its top singular directions exactly like PowerGossip's
///   across-round warm start.  Decode is stateless: the factors are on
///   the wire.
/// * **Layout**: [`EdgeCodec::bind_layout`] supplies the model's layer
///   structure (C-ECL binds its manifest views at construction).
///   Unbound instances fall back to reshaping the whole vector into one
///   near-square matrix (zero-padded); coordinates outside every view
///   decode to 0.
///
/// Value-dependent, so NOT linear for fixed ω — Eq. (11) rule only.
pub struct LowRankCodec {
    pub rank: usize,
    /// Power-iteration refinements per rank within one encode.
    pub iters: usize,
    views: Vec<MatView>,
    vec_views: Vec<(usize, usize)>,
    /// Dimension the views were derived for (layout binding or first
    /// ctx); later ctxs must agree.
    dim: Option<usize>,
    /// Warm-start state per (view, rank); seeded lazily from the first
    /// encode's ctx.
    states: Vec<Vec<LowRankEdgeState>>,
    scratch: Vec<f32>,
    /// Factor staging for the allocation-free `decode_into` path.
    scratch_p: Vec<f32>,
    scratch_q: Vec<f32>,
}

impl LowRankCodec {
    pub fn new(rank: usize, iters: usize) -> LowRankCodec {
        LowRankCodec {
            rank: rank.max(1),
            iters: iters.max(1),
            views: Vec::new(),
            vec_views: Vec::new(),
            dim: None,
            states: Vec::new(),
            scratch: Vec::new(),
            scratch_p: Vec::new(),
            scratch_q: Vec::new(),
        }
    }

    /// Generic layout for an unbound codec: one near-square matrix
    /// covering the whole vector, zero-padded (see
    /// [`near_square_shape`]).
    fn fallback_views(dim: usize) -> Vec<MatView> {
        let (rows, cols) = near_square_shape(dim);
        vec![(0, rows, cols, dim)]
    }

    fn ensure_views(&mut self, dim: usize) -> Result<(), CodecError> {
        match self.dim {
            Some(d) if d == dim => Ok(()),
            Some(d) => Err(CodecError::BadSpec(format!(
                "low_rank codec bound for dim {d}, used with dim {dim}"
            ))),
            None => {
                if self.views.is_empty() && self.vec_views.is_empty() {
                    self.views = Self::fallback_views(dim);
                }
                self.dim = Some(dim);
                Ok(())
            }
        }
    }

    /// Exact frame length for the current layout.
    fn frame_bytes(&self) -> usize {
        let mats: usize = self
            .views
            .iter()
            .map(|&(_, r, c, _)| (r + c) * 4)
            .sum::<usize>()
            * self.rank;
        let vecs: usize = self.vec_views.iter().map(|&(_, l)| l * 4).sum();
        mats + vecs
    }

    /// Stage view `v` of `x` into `self.scratch` (zero-pads the generic
    /// reshape's tail).
    fn load_view(&mut self, x: &[f32], v: usize) {
        let (off, rows, cols, len) = self.views[v];
        self.scratch.clear();
        self.scratch.extend_from_slice(&x[off..off + len]);
        self.scratch.resize(rows * cols, 0.0);
    }
}

impl EdgeCodec for LowRankCodec {
    fn name(&self) -> String {
        if self.iters == 1 {
            format!("low_rank r{}", self.rank)
        } else {
            format!("low_rank r{}x{}", self.rank, self.iters)
        }
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        false
    }

    fn bind_layout(&mut self, matrices: &[(usize, usize, usize)],
                   vectors: &[(usize, usize)]) {
        self.views = matrices
            .iter()
            .map(|&(off, r, c)| (off, r, c, r * c))
            .collect();
        self.vec_views = vectors.to_vec();
        self.dim = None;
        self.states.clear();
    }

    fn encode(&mut self, x: &[f32], ctx: &EdgeCtx) -> Frame {
        debug_assert_eq!(x.len(), ctx.dim);
        self.ensure_views(ctx.dim).expect("encode dim drifted from layout");
        if self.states.is_empty() {
            // Warm-start q̂ per (view, rank), derived from the shared
            // seed so two instances on the same directed edge encode
            // identical frames from round 0.
            self.states = self
                .views
                .iter()
                .enumerate()
                .map(|(v, &(_, _, cols, _))| {
                    (0..self.rank)
                        .map(|r| {
                            // Epoch 0 keeps the legacy derivation path
                            // (bit-identical static replay); a reborn
                            // edge draws a fresh, still-shared stream.
                            let mut path = vec![
                                streams::POWER,
                                ctx.edge as u64,
                                ctx.receiver as u64,
                                v as u64,
                                r as u64,
                            ];
                            if ctx.epoch > 0 {
                                path.push(ctx.epoch as u64);
                            }
                            let mut rng = Pcg::derive(ctx.seed, &path);
                            LowRankEdgeState::new(cols, &mut rng)
                        })
                        .collect()
                })
                .collect();
        }
        let mut buf = pooled_buf(self.frame_bytes());
        for v in 0..self.views.len() {
            let (_, rows, cols, _) = self.views[v];
            self.load_view(x, v);
            let mut res = std::mem::take(&mut self.scratch);
            for r in 0..self.rank {
                let mut q_used = self.states[v][r].q_hat.clone();
                let mut p = matvec_f32(&res, rows, cols, &q_used);
                for it in 0..self.iters {
                    let mut p_hat = p.clone();
                    normalize(&mut p_hat);
                    let s = matvec_t_f32(&res, rows, cols, &p_hat);
                    let mut q_next = s;
                    normalize(&mut q_next);
                    if it + 1 < self.iters {
                        // Refine within this encode.
                        q_used = q_next;
                        p = matvec_f32(&res, rows, cols, &q_used);
                    } else {
                        // Warm start for the next encode; reseed if the
                        // residual collapsed (rank < R input).  Epoch 0
                        // keeps the legacy path (static replay).
                        let mut path = vec![
                            streams::POWER,
                            u64::MAX,
                            ctx.edge as u64,
                            ctx.receiver as u64,
                            v as u64,
                            r as u64,
                            ctx.round as u64,
                        ];
                        if ctx.epoch > 0 {
                            path.push(ctx.epoch as u64);
                        }
                        let mut reseed = Pcg::derive(ctx.seed, &path);
                        self.states[v][r].q_hat = q_next;
                        self.states[v][r].reseed_if_degenerate(&mut reseed);
                    }
                }
                for &val in &p {
                    buf.extend_from_slice(&val.to_le_bytes());
                }
                for &val in &q_used {
                    buf.extend_from_slice(&val.to_le_bytes());
                }
                // Deflate: the next rank approximates what is left.
                rank1_axpy(&mut res, rows, cols, -1.0, &p, &q_used);
            }
            self.scratch = res;
        }
        for &(off, len) in &self.vec_views {
            for &val in &x[off..off + len] {
                buf.extend_from_slice(&val.to_le_bytes());
            }
        }
        Frame::new(buf)
    }

    fn decode(&mut self, frame: &Frame, ctx: &EdgeCtx) -> Result<Vec<f32>, CodecError> {
        note_decode_alloc();
        self.ensure_views(ctx.dim)?;
        let expected = self.frame_bytes();
        let b = frame.bytes();
        if b.len() != expected {
            return Err(CodecError::Length {
                expected,
                got: b.len(),
            });
        }
        let f32_at = |k: usize| {
            let o = 4 * k;
            // det:allow(index-decode): the exact-length check above pins
            // `b.len()` to `frame_bytes()`, and the view cursor walks at
            // most that many f32 slots.
            f32::from_le_bytes([b[o], b[o + 1], b[o + 2], b[o + 3]])
        };
        let mut out = vec![0.0f32; ctx.dim];
        let mut cur = 0usize; // f32 cursor
        for &(off, rows, cols, len) in &self.views {
            let mut mat = vec![0.0f32; rows * cols];
            for _ in 0..self.rank {
                let p: Vec<f32> = (0..rows).map(|i| f32_at(cur + i)).collect();
                cur += rows;
                let q: Vec<f32> = (0..cols).map(|i| f32_at(cur + i)).collect();
                cur += cols;
                rank1_axpy(&mut mat, rows, cols, 1.0, &p, &q);
            }
            // det:allow(index-decode): views are built by `ensure_views`
            // to tile exactly `ctx.dim`, which is also `out.len()`.
            out[off..off + len].copy_from_slice(&mat[..len]);
        }
        for &(off, len) in &self.vec_views {
            for i in 0..len {
                // det:allow(index-decode): same tiling invariant as the
                // matrix views above.
                out[off + i] = f32_at(cur + i);
            }
            cur += len;
        }
        Ok(out)
    }

    fn decode_into(
        &mut self,
        frame: &Frame,
        ctx: &EdgeCtx,
        out: &mut [f32],
    ) -> Result<(), CodecError> {
        if out.len() != ctx.dim {
            return Err(CodecError::Length {
                expected: ctx.dim,
                got: out.len(),
            });
        }
        self.ensure_views(ctx.dim)?;
        let expected = self.frame_bytes();
        let b = frame.bytes();
        if b.len() != expected {
            return Err(CodecError::Length {
                expected,
                got: b.len(),
            });
        }
        let f32_at = |k: usize| {
            let o = 4 * k;
            // det:allow(index-decode): the exact-length check above pins
            // `b.len()` to `frame_bytes()`, and the view cursor walks at
            // most that many f32 slots.
            f32::from_le_bytes([b[o], b[o + 1], b[o + 2], b[o + 3]])
        };
        out.fill(0.0);
        let mut cur = 0usize; // f32 cursor
        let rank = self.rank;
        for &(off, rows, cols, len) in &self.views {
            // The factor staging and the rank-1 accumulator live in
            // retained scratch so a steady-state decode never touches
            // the allocator; the `rank1_axpy` call is the same call the
            // allocating path makes, so reconstruction stays bit-exact.
            self.scratch.clear();
            self.scratch.resize(rows * cols, 0.0);
            for _ in 0..rank {
                self.scratch_p.clear();
                for i in 0..rows {
                    self.scratch_p.push(f32_at(cur + i));
                }
                cur += rows;
                self.scratch_q.clear();
                for i in 0..cols {
                    self.scratch_q.push(f32_at(cur + i));
                }
                cur += cols;
                rank1_axpy(&mut self.scratch, rows, cols, 1.0, &self.scratch_p, &self.scratch_q);
            }
            // det:allow(index-decode): views are built by `ensure_views`
            // to tile exactly `ctx.dim`, which is also `out.len()`.
            out[off..off + len].copy_from_slice(&self.scratch[..len]);
        }
        for &(off, len) in &self.vec_views {
            for i in 0..len {
                // det:allow(index-decode): same tiling invariant as the
                // matrix views above.
                out[off + i] = f32_at(cur + i);
            }
            cur += len;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn matvec_agrees_with_f64_path() {
        let rows = 7;
        let cols = 5;
        let m = randn(rows * cols, 1);
        let q = randn(cols, 2);
        let p = matvec_f32(&m, rows, cols, &q);
        for r in 0..rows {
            let want: f32 =
                (0..cols).map(|c| m[r * cols + c] * q[c]).sum();
            assert!((p[r] - want).abs() < 1e-5);
        }
        let s = matvec_t_f32(&m, rows, cols, &p);
        for c in 0..cols {
            let want: f32 = (0..rows).map(|r| m[r * cols + c] * p[r]).sum();
            assert!((s[c] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn blocked_matvecs_agree_with_reference_kernels() {
        // Odd shapes exercise the unroll tails; planted zeros exercise
        // the skip paths in both transposed kernels.
        for (rows, cols) in [(1, 1), (5, 3), (17, 13), (64, 31), (33, 64)] {
            let m = randn(rows * cols, rows as u64 * 31 + cols as u64);
            let q = randn(cols, 7);
            let mut p = randn(rows, 8);
            if rows > 2 {
                p[1] = 0.0;
                p[rows - 1] = 0.0;
            }
            let fast = matvec_f32(&m, rows, cols, &q);
            let slow = matvec_f32_reference(&m, rows, cols, &q);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "matvec {rows}x{cols}: {a} vs {b}");
            }
            let fast_t = matvec_t_f32(&m, rows, cols, &p);
            let slow_t = matvec_t_f32_reference(&m, rows, cols, &p);
            for (a, b) in fast_t.iter().zip(&slow_t) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "matvec_t {rows}x{cols}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rank1_axpy_known() {
        let mut out = vec![0.0f32; 6];
        rank1_axpy(&mut out, 2, 3, 2.0, &[1.0, 10.0], &[1.0, 2.0, 3.0]);
        assert_eq!(out, vec![2.0, 4.0, 6.0, 20.0, 40.0, 60.0]);
    }

    #[test]
    fn power_iteration_converges_to_top_singular_direction() {
        // D = sigma * u vᵀ exactly rank-1: one step from a generic q̂
        // recovers p ∝ u and the approximation p q̂_nextᵀ ≈ D after a
        // couple of iterations.
        let rows = 12;
        let cols = 9;
        let mut u = randn(rows, 3);
        let mut v = randn(cols, 4);
        normalize(&mut u);
        normalize(&mut v);
        let sigma = 5.0f32;
        // M_lo = sigma u vᵀ, M_hi = 0 → D = M_lo.
        let mut m_lo = vec![0.0f32; rows * cols];
        rank1_axpy(&mut m_lo, rows, cols, sigma, &u, &v);
        let m_hi = vec![0.0f32; rows * cols];

        let mut rng = Pcg::new(5);
        let mut state = LowRankEdgeState::new(cols, &mut rng);
        let mut p = vec![0.0f32; rows];
        for _ in 0..3 {
            let p_lo = matvec_f32(&m_lo, rows, cols, &state.q_hat);
            let p_hi = matvec_f32(&m_hi, rows, cols, &state.q_hat);
            let mut p_hat: Vec<f32> =
                p_lo.iter().zip(&p_hi).map(|(a, b)| a - b).collect();
            normalize(&mut p_hat);
            let s_lo = matvec_t_f32(&m_lo, rows, cols, &p_hat);
            let s_hi = matvec_t_f32(&m_hi, rows, cols, &p_hat);
            let (pp, q_next) = power_iteration_step(&p_lo, &p_hi, &s_lo, &s_hi);
            p = pp;
            state.q_hat = q_next;
        }
        // Reconstruction error of p q̂ᵀ vs D should be tiny (D is rank-1).
        let mut approx = vec![0.0f32; rows * cols];
        rank1_axpy(&mut approx, rows, cols, 1.0, &p, &state.q_hat);
        let err: f32 = approx
            .iter()
            .zip(&m_lo)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let norm: f32 = m_lo.iter().map(|x| x * x).sum();
        assert!(err / norm < 1e-3, "rel err {}", err / norm);
    }

    #[test]
    fn both_endpoints_stay_in_sync() {
        // Simulate the two endpoints exchanging halves: derived identical
        // q̂ initialization + deterministic updates = identical states.
        let rows = 6;
        let cols = 4;
        let m_lo = randn(rows * cols, 6);
        let m_hi = randn(rows * cols, 7);
        let mut rng_a = Pcg::derive(9, &[5, 0]);
        let mut rng_b = Pcg::derive(9, &[5, 0]);
        let mut sa = LowRankEdgeState::new(cols, &mut rng_a);
        let mut sb = LowRankEdgeState::new(cols, &mut rng_b);
        assert_eq!(sa.q_hat, sb.q_hat);
        for _ in 0..4 {
            // endpoint A (= lo) computes its halves; endpoint B (= hi) its.
            let p_lo = matvec_f32(&m_lo, rows, cols, &sa.q_hat);
            let p_hi = matvec_f32(&m_hi, rows, cols, &sb.q_hat);
            let mut p_hat: Vec<f32> =
                p_lo.iter().zip(&p_hi).map(|(a, b)| a - b).collect();
            normalize(&mut p_hat);
            let s_lo = matvec_t_f32(&m_lo, rows, cols, &p_hat);
            let s_hi = matvec_t_f32(&m_hi, rows, cols, &p_hat);
            let (_, qa) = power_iteration_step(&p_lo, &p_hi, &s_lo, &s_hi);
            let (_, qb) = power_iteration_step(&p_lo, &p_hi, &s_lo, &s_hi);
            assert_eq!(qa, qb);
            sa.q_hat = qa;
            sb.q_hat = qb;
        }
    }

    #[test]
    fn degenerate_reseed() {
        let mut rng = Pcg::new(11);
        let mut s = LowRankEdgeState {
            q_hat: vec![0.0; 8],
        };
        s.reseed_if_degenerate(&mut rng);
        let norm: f32 = s.q_hat.iter().map(|x| x * x).sum();
        assert!((norm - 1.0).abs() < 1e-4);
    }

    fn codec_ctx(dim: usize, round: usize) -> EdgeCtx {
        EdgeCtx {
            seed: 19,
            edge: 2,
            round,
            receiver: 1,
            dim,
            epoch: 0,
        }
    }

    #[test]
    fn low_rank_codec_reconstructs_exact_rank_r_input() {
        // A bound (rows x cols) view holding an exactly rank-2 matrix:
        // after a couple of warm-started encodes, low_rank:2 must
        // reconstruct it almost exactly.
        let rows = 14;
        let cols = 10;
        let dim = rows * cols;
        let mut m = vec![0.0f32; dim];
        for (k, sigma) in [(0u64, 4.0f32), (1, 2.0)] {
            let mut u = randn(rows, 30 + k);
            let mut v = randn(cols, 40 + k);
            normalize(&mut u);
            normalize(&mut v);
            rank1_axpy(&mut m, rows, cols, sigma, &u, &v);
        }
        let mut codec = LowRankCodec::new(2, 2);
        codec.bind_layout(&[(0, rows, cols)], &[]);
        let mut last_err = f32::MAX;
        for round in 0..6 {
            let c = codec_ctx(dim, round);
            let f = codec.encode(&m, &c);
            assert_eq!(f.wire_bytes(), 2 * (rows + cols) * 4);
            let y = codec.decode(&f, &c).unwrap();
            let err: f32 = y
                .iter()
                .zip(&m)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let norm: f32 = m.iter().map(|x| x * x).sum();
            last_err = err / norm;
        }
        assert!(last_err < 1e-2, "rank-2 input rel err {last_err}");
    }

    #[test]
    fn low_rank_codec_layout_ships_vectors_dense_and_pins_bytes() {
        // Layout: one 4x5 matrix + one len-3 rank-1 tensor.
        let dim = 23;
        let x = randn(dim, 50);
        let mut codec = LowRankCodec::new(3, 2);
        codec.bind_layout(&[(0, 4, 5)], &[(20, 3)]);
        let c = codec_ctx(dim, 0);
        let f = codec.encode(&x, &c);
        // 3 ranks x (4 + 5) floats + 3 raw floats (iters is refinement
        // quality, not wire size).
        assert_eq!(f.wire_bytes(), (3 * 9 + 3) * 4);
        let y = codec.decode(&f, &c).unwrap();
        // Rank-1 tensors round-trip bit-exactly.
        for i in 20..23 {
            assert_eq!(y[i].to_bits(), x[i].to_bits(), "vec coord {i}");
        }
        // Rank 3 cannot lose much of a 4x5 matrix (full rank is 4).
        let err: f32 = y[..20]
            .iter()
            .zip(&x[..20])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let norm: f32 = x[..20].iter().map(|v| v * v).sum();
        assert!(err / norm < 0.6, "rel err {}", err / norm);
    }

    #[test]
    fn low_rank_codec_unbound_fallback_and_corrupt_frames() {
        let dim = 96; // cols = 10, rows = 10, 4 coords of padding
        let x = randn(dim, 60);
        let mut codec = LowRankCodec::new(2, 1);
        let c = codec_ctx(dim, 0);
        let f = codec.encode(&x, &c);
        assert_eq!(f.wire_bytes(), 2 * (10 + 10) * 4);
        // Two fresh instances produce identical frames (shared-seed
        // warm start) and identical decodes.
        let f2 = LowRankCodec::new(2, 1).encode(&x, &c);
        assert_eq!(f, f2, "encode not deterministic");
        let y = LowRankCodec::new(2, 1).decode(&f, &c).unwrap();
        assert_eq!(y.len(), dim);
        // Truncated frame -> typed length error, never a panic.
        let mut bad = f.clone();
        bad.bytes_mut().pop();
        assert!(matches!(
            LowRankCodec::new(2, 1).decode(&bad, &c),
            Err(CodecError::Length { .. })
        ));
    }
}
