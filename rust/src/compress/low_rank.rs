//! Low-rank (PowerGossip-style) compression primitives.
//!
//! PowerGossip (Vogels, Karimireddy, Jaggi 2020) compresses the per-edge
//! model *difference* `D = M_lo − M_hi` (per layer matrix) with warm-
//! started power iteration: both endpoints hold an identical unit vector
//! `q̂`; each exchanges `p_x = M_x q̂` (rows floats) and `s_x = M_xᵀ p̂`
//! (cols floats), from which both reconstruct the same rank-1
//! approximation `p q̂ᵀ ≈ D` and the same next `q̂`.  The warm start
//! across rounds is what makes one step per round sufficient in practice
//! (the paper's PowerGossip(1) row).
//!
//! This module is the math; the exchange choreography lives in
//! `algorithms::powergossip`.

use crate::util::rng::Pcg;

/// `p = M q` for a row-major `rows x cols` matrix stored in a flat slice.
pub fn matvec_f32(m: &[f32], rows: usize, cols: usize, q: &[f32]) -> Vec<f32> {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(q.len(), cols);
    let mut p = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(q) {
            acc += a * b;
        }
        p[r] = acc;
    }
    p
}

/// `s = Mᵀ p`.
pub fn matvec_t_f32(m: &[f32], rows: usize, cols: usize, p: &[f32]) -> Vec<f32> {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(p.len(), rows);
    let mut s = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        let pr = p[r];
        if pr == 0.0 {
            continue;
        }
        for (sj, a) in s.iter_mut().zip(row) {
            *sj += a * pr;
        }
    }
    s
}

/// `out += alpha * p qᵀ` (rank-1 update of a row-major matrix).
pub fn rank1_axpy(out: &mut [f32], rows: usize, cols: usize, alpha: f32,
                  p: &[f32], q: &[f32]) {
    assert_eq!(out.len(), rows * cols);
    assert_eq!(p.len(), rows);
    assert_eq!(q.len(), cols);
    for r in 0..rows {
        let coeff = alpha * p[r];
        if coeff == 0.0 {
            continue;
        }
        let row = &mut out[r * cols..(r + 1) * cols];
        for (o, &qj) in row.iter_mut().zip(q) {
            *o += coeff * qj;
        }
    }
}

/// Normalize in place; returns the original norm. Zero vectors are left
/// unchanged (norm 0 returned) so callers can re-randomize.
pub fn normalize(v: &mut [f32]) -> f32 {
    let norm = v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
        as f32;
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

/// One power-iteration step on the implicit difference `D = M_lo − M_hi`
/// given both halves of the exchange. Returns `(p, q_hat_next)` where
/// `p = D q̂` and `q_hat_next = normalize(Dᵀ p̂)`.
///
/// Both endpoints call this with the same inputs (their own half plus the
/// received half), so the results are bit-identical on the two sides.
pub fn power_iteration_step(
    p_lo: &[f32],
    p_hi: &[f32],
    s_lo: &[f32],
    s_hi: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let p: Vec<f32> = p_lo.iter().zip(p_hi).map(|(a, b)| a - b).collect();
    let mut q_next: Vec<f32> =
        s_lo.iter().zip(s_hi).map(|(a, b)| a - b).collect();
    normalize(&mut q_next);
    (p, q_next)
}

/// Warm-start state for one (edge, layer-matrix) pair. Both endpoints
/// construct it from the same derived RNG, so `q_hat` starts identical
/// and stays identical (all updates are deterministic functions of
/// exchanged values).
#[derive(Debug, Clone)]
pub struct LowRankEdgeState {
    pub q_hat: Vec<f32>,
}

impl LowRankEdgeState {
    pub fn new(cols: usize, rng: &mut Pcg) -> LowRankEdgeState {
        let mut q: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        normalize(&mut q);
        LowRankEdgeState { q_hat: q }
    }

    /// Re-randomize if power iteration collapsed (q ≈ 0, e.g. identical
    /// matrices on both sides).
    pub fn reseed_if_degenerate(&mut self, rng: &mut Pcg) {
        let norm: f32 = self.q_hat.iter().map(|x| x * x).sum();
        if norm < 1e-12 {
            for x in self.q_hat.iter_mut() {
                *x = rng.normal_f32();
            }
            normalize(&mut self.q_hat);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn matvec_agrees_with_f64_path() {
        let rows = 7;
        let cols = 5;
        let m = randn(rows * cols, 1);
        let q = randn(cols, 2);
        let p = matvec_f32(&m, rows, cols, &q);
        for r in 0..rows {
            let want: f32 =
                (0..cols).map(|c| m[r * cols + c] * q[c]).sum();
            assert!((p[r] - want).abs() < 1e-5);
        }
        let s = matvec_t_f32(&m, rows, cols, &p);
        for c in 0..cols {
            let want: f32 = (0..rows).map(|r| m[r * cols + c] * p[r]).sum();
            assert!((s[c] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn rank1_axpy_known() {
        let mut out = vec![0.0f32; 6];
        rank1_axpy(&mut out, 2, 3, 2.0, &[1.0, 10.0], &[1.0, 2.0, 3.0]);
        assert_eq!(out, vec![2.0, 4.0, 6.0, 20.0, 40.0, 60.0]);
    }

    #[test]
    fn power_iteration_converges_to_top_singular_direction() {
        // D = sigma * u vᵀ exactly rank-1: one step from a generic q̂
        // recovers p ∝ u and the approximation p q̂_nextᵀ ≈ D after a
        // couple of iterations.
        let rows = 12;
        let cols = 9;
        let mut u = randn(rows, 3);
        let mut v = randn(cols, 4);
        normalize(&mut u);
        normalize(&mut v);
        let sigma = 5.0f32;
        // M_lo = sigma u vᵀ, M_hi = 0 → D = M_lo.
        let mut m_lo = vec![0.0f32; rows * cols];
        rank1_axpy(&mut m_lo, rows, cols, sigma, &u, &v);
        let m_hi = vec![0.0f32; rows * cols];

        let mut rng = Pcg::new(5);
        let mut state = LowRankEdgeState::new(cols, &mut rng);
        let mut p = vec![0.0f32; rows];
        for _ in 0..3 {
            let p_lo = matvec_f32(&m_lo, rows, cols, &state.q_hat);
            let p_hi = matvec_f32(&m_hi, rows, cols, &state.q_hat);
            let mut p_hat: Vec<f32> =
                p_lo.iter().zip(&p_hi).map(|(a, b)| a - b).collect();
            normalize(&mut p_hat);
            let s_lo = matvec_t_f32(&m_lo, rows, cols, &p_hat);
            let s_hi = matvec_t_f32(&m_hi, rows, cols, &p_hat);
            let (pp, q_next) = power_iteration_step(&p_lo, &p_hi, &s_lo, &s_hi);
            p = pp;
            state.q_hat = q_next;
        }
        // Reconstruction error of p q̂ᵀ vs D should be tiny (D is rank-1).
        let mut approx = vec![0.0f32; rows * cols];
        rank1_axpy(&mut approx, rows, cols, 1.0, &p, &state.q_hat);
        let err: f32 = approx
            .iter()
            .zip(&m_lo)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let norm: f32 = m_lo.iter().map(|x| x * x).sum();
        assert!(err / norm < 1e-3, "rel err {}", err / norm);
    }

    #[test]
    fn both_endpoints_stay_in_sync() {
        // Simulate the two endpoints exchanging halves: derived identical
        // q̂ initialization + deterministic updates = identical states.
        let rows = 6;
        let cols = 4;
        let m_lo = randn(rows * cols, 6);
        let m_hi = randn(rows * cols, 7);
        let mut rng_a = Pcg::derive(9, &[5, 0]);
        let mut rng_b = Pcg::derive(9, &[5, 0]);
        let mut sa = LowRankEdgeState::new(cols, &mut rng_a);
        let mut sb = LowRankEdgeState::new(cols, &mut rng_b);
        assert_eq!(sa.q_hat, sb.q_hat);
        for _ in 0..4 {
            // endpoint A (= lo) computes its halves; endpoint B (= hi) its.
            let p_lo = matvec_f32(&m_lo, rows, cols, &sa.q_hat);
            let p_hi = matvec_f32(&m_hi, rows, cols, &sb.q_hat);
            let mut p_hat: Vec<f32> =
                p_lo.iter().zip(&p_hi).map(|(a, b)| a - b).collect();
            normalize(&mut p_hat);
            let s_lo = matvec_t_f32(&m_lo, rows, cols, &p_hat);
            let s_hi = matvec_t_f32(&m_hi, rows, cols, &p_hat);
            let (_, qa) = power_iteration_step(&p_lo, &p_hi, &s_lo, &s_hi);
            let (_, qb) = power_iteration_step(&p_lo, &p_hi, &s_lo, &s_hi);
            assert_eq!(qa, qb);
            sa.q_hat = qa;
            sb.q_hat = qb;
        }
    }

    #[test]
    fn degenerate_reseed() {
        let mut rng = Pcg::new(11);
        let mut s = LowRankEdgeState {
            q_hat: vec![0.0; 8],
        };
        s.reseed_if_degenerate(&mut rng);
        let norm: f32 = s.q_hat.iter().map(|x| x * x).sum();
        assert!((norm - 1.0).abs() < 1e-4);
    }
}
