//! Compression substrate (paper §3.1, Assumption 1).
//!
//! The operator `comp` is realized as a family of **edge codecs**
//! ([`codec::EdgeCodec`]): stateful per-edge encoders/decoders that
//! produce byte-exact wire [`codec::Frame`]s — the frame length *is*
//! the metered wire size.  See [`codec`] for the codec families
//! (identity / rand-k in two wire modes / top-k / QSGD quantization /
//! sign+norm / error feedback), the [`codec::CodecSpec`] CLI grammar,
//! and which codecs are linear for fixed ω (Eqs. 8–9) and therefore
//! licensed to run the Eq. (13) dual rule.
//!
//! This module keeps the low-level pieces the codecs and the rest of
//! the crate build on:
//!
//! * [`RandK`] — the paper's Example 1 `rand_k%` mask sampler.  Its
//!   sparsity pattern ω derives from a shared per-edge/per-round seed,
//!   so both endpoints regenerate the identical mask and never transmit
//!   it (Alg. 1 lines 5–6 “can be omitted”).  Used by the rand-k codec,
//!   the convex `quadratic` substrate, and the PJRT dual-update path.
//! * [`CooVec`] — sparse COO vectors (the PJRT kernel interop format
//!   and the `Msg::Sparse` payload), with checked accessors for decode
//!   paths.
//! * [`LowRankEdgeState`] / [`LowRankCodec`] (in `low_rank.rs`) — the
//!   PowerGossip power-iteration primitive, and the same operator as a
//!   first-class `low_rank:R[:iters]` edge codec (explicit p/q factor
//!   frames, warm-started per-edge state).

pub mod codec;
pub mod coo;
pub mod low_rank;

pub use codec::{
    hotpath_counters, measure_codec_contraction, reset_hotpath_counters,
    CodecError, CodecSpec, EdgeCodec, EdgeCtx, Frame, WireMode,
};
pub use coo::CooVec;
pub use low_rank::{power_iteration_step, LowRankCodec, LowRankEdgeState};

use crate::util::rng::Pcg;

/// The paper's Example 1: keep each coordinate independently with
/// probability `k_frac` (NOT rescaled — the paper's operator is a pure
/// mask `s ∘ x`, and τ = k).  Linear for fixed ω (Eqs. 8–9), which is
/// what licenses the Eq. (13) rewrite `comp(y − z) = comp(y) − comp(z)`.
#[derive(Debug, Clone, Copy)]
pub struct RandK {
    pub k_frac: f64,
}

impl RandK {
    pub fn new(k_frac: f64) -> RandK {
        assert!(
            k_frac > 0.0 && k_frac <= 1.0,
            "k% must be in (0, 100], got {}",
            k_frac * 100.0
        );
        RandK { k_frac }
    }

    /// Sample the mask ω as a sorted index list. Both edge endpoints call
    /// this with identically-derived RNGs (`Pcg::derive(seed,
    /// [EDGE_MASK, edge, round, dir])` — see `codec::EdgeCtx::mask_rng`).
    ///
    /// Uses geometric gap-sampling: instead of one Bernoulli draw per
    /// coordinate (O(d)), draw the gap to the next kept coordinate from
    /// Geometric(k) — O(k·d) expected draws, identical i.i.d.
    /// Bernoulli(k) marginals.  EXPERIMENTS.md §Perf records the ~8×
    /// speedup at k = 10% over the naive path (kept below as the A/B
    /// baseline for the bench).
    pub fn sample_mask(&self, dim: usize, rng: &mut Pcg) -> Vec<u32> {
        if self.k_frac >= 1.0 {
            return (0..dim as u32).collect();
        }
        let mut idx = Vec::with_capacity(
            ((dim as f64) * self.k_frac * 1.2) as usize + 8,
        );
        // gap ~ Geometric(p): floor(ln(U) / ln(1-p)) zeros before the
        // next success.
        let inv_log_q = 1.0 / (1.0 - self.k_frac).ln();
        let mut i = 0f64;
        loop {
            let u = rng.f64().max(1e-300);
            i += (u.ln() * inv_log_q).floor();
            if i >= dim as f64 {
                break;
            }
            idx.push(i as u32);
            i += 1.0;
        }
        idx
    }

    /// Naive per-coordinate Bernoulli sampling — the pre-optimization
    /// baseline, kept for the §Perf A/B bench and as a distribution
    /// cross-check in tests.
    pub fn sample_mask_naive(&self, dim: usize, rng: &mut Pcg) -> Vec<u32> {
        let mut idx = Vec::with_capacity(
            ((dim as f64) * self.k_frac * 1.2) as usize + 8,
        );
        if self.k_frac >= 1.0 {
            idx.extend(0..dim as u32);
            return idx;
        }
        for i in 0..dim as u32 {
            if rng.f64() < self.k_frac {
                idx.push(i);
            }
        }
        idx
    }

    /// Dense 0/1 mask (for the PJRT dual-update path).
    pub fn mask_to_dense(dim: usize, idx: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.resize(dim, 0.0);
        for &i in idx {
            out[i as usize] = 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{streams, Pcg};

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn randk_mask_shared_seed_identical() {
        // Both edge endpoints derive the same ω — Alg. 1 lines 5-6 omitted.
        let op = RandK::new(0.1);
        let mut a = Pcg::derive(99, &[streams::EDGE_MASK, 4, 17, 0]);
        let mut b = Pcg::derive(99, &[streams::EDGE_MASK, 4, 17, 0]);
        assert_eq!(op.sample_mask(5000, &mut a), op.sample_mask(5000, &mut b));
        // ... and a different round gives a different ω.
        let mut c = Pcg::derive(99, &[streams::EDGE_MASK, 4, 18, 0]);
        assert_ne!(op.sample_mask(5000, &mut a), op.sample_mask(5000, &mut c));
    }

    #[test]
    fn randk_density_close_to_k() {
        let op = RandK::new(0.1);
        let mut rng = Pcg::new(1);
        let mask = op.sample_mask(200_000, &mut rng);
        let density = mask.len() as f64 / 200_000.0;
        assert!((density - 0.1).abs() < 0.005, "density={density}");
    }

    #[test]
    fn gap_sampler_matches_naive_distribution() {
        // The geometric-gap fast path and the naive Bernoulli loop must
        // produce the same marginal density and strictly-sorted unique
        // indices (they need not produce identical masks per seed).
        for k in [0.01, 0.1, 0.37, 0.8] {
            let op = RandK::new(k);
            let d = 300_000;
            let fast = op.sample_mask(d, &mut Pcg::new(2));
            let naive = op.sample_mask_naive(d, &mut Pcg::new(3));
            for m in [&fast, &naive] {
                assert!(m.windows(2).all(|w| w[0] < w[1]), "not sorted");
                assert!(m.last().map(|&i| (i as usize) < d).unwrap_or(true));
                let density = m.len() as f64 / d as f64;
                assert!(
                    (density - k).abs() < 0.01,
                    "k={k}: density {density}"
                );
            }
        }
    }

    #[test]
    fn randk_linearity_for_fixed_omega() {
        // comp(x + y; ω) == comp(x; ω) + comp(y; ω) exactly (Eq. 8).
        let op = RandK::new(0.3);
        let x = randn(1000, 4);
        let y = randn(1000, 5);
        let mut rng = Pcg::new(6);
        let mask = op.sample_mask(1000, &mut rng);
        let sum: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let cx = CooVec::gather(&x, &mask);
        let cy = CooVec::gather(&y, &mask);
        let csum = CooVec::gather(&sum, &mask);
        for k in 0..mask.len() {
            assert_eq!(csum.val[k], cx.val[k] + cy.val[k]);
        }
    }

    #[test]
    fn randk_negation_eq9() {
        let op = RandK::new(0.3);
        let x = randn(500, 7);
        let neg: Vec<f32> = x.iter().map(|a| -a).collect();
        let mut rng = Pcg::new(8);
        let mask = op.sample_mask(500, &mut rng);
        let cx = CooVec::gather(&x, &mask);
        let cn = CooVec::gather(&neg, &mask);
        for k in 0..mask.len() {
            assert_eq!(cn.val[k], -cx.val[k]);
        }
    }

    #[test]
    fn full_rate_mask_is_identity() {
        let op = RandK::new(1.0);
        let mut rng = Pcg::new(10);
        assert_eq!(
            op.sample_mask(100, &mut rng),
            (0..100u32).collect::<Vec<_>>()
        );
    }

    #[test]
    fn dense_mask_helper() {
        let mut out = Vec::new();
        RandK::mask_to_dense(5, &[1, 4], &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn zero_k_rejected() {
        let _ = RandK::new(0.0);
    }
}
