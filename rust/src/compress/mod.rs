//! Compression-operator substrate (paper §3.1, Assumption 1).
//!
//! The C-ECL hot path uses [`RandK`] — the paper's Example 1
//! `rand_k%` — whose sparsity pattern ω is derived from a shared
//! per-edge/per-round seed, so both endpoints of an edge regenerate the
//! identical mask and never transmit it (Alg. 1 lines 5–6 “can be
//! omitted”).  `rand_k%` is *linear for fixed ω* (Eqs. 8–9), which is
//! what licenses the Eq. (13) rewrite `comp(y − z) = comp(y) − comp(z)`.
//!
//! [`TopK`] is value-dependent (violates the fixed-ω linearity) and is
//! provided for the compression-operator study / the naive Eq. (11)
//! ablation.  [`LowRank`] (in `low_rank.rs`) is the PowerGossip
//! primitive.

pub mod coo;
pub mod low_rank;

pub use coo::CooVec;
pub use low_rank::{power_iteration_step, LowRankEdgeState};

use crate::util::rng::Pcg;

/// A compression operator `comp: R^d -> R^d` in the sense of
/// Assumption 1, materialized as a sparse output.
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;

    /// The contraction parameter τ of Eq. (7):
    /// `E‖comp(x) − x‖² ≤ (1 − τ)‖x‖²`.
    fn tau(&self) -> f64;

    /// Compress `x`, drawing ω from `rng`.
    fn compress(&self, x: &[f32], rng: &mut Pcg) -> CooVec;

    /// Whether `comp(x + y; ω) = comp(x; ω) + comp(y; ω)` holds for fixed
    /// ω (Eqs. 8–9) — required by the C-ECL update.
    fn is_linear_for_fixed_omega(&self) -> bool;
}

/// The paper's Example 1: keep each coordinate independently with
/// probability `k_frac` (NOT rescaled — the paper's operator is a pure
/// mask `s ∘ x`, and τ = k).
#[derive(Debug, Clone, Copy)]
pub struct RandK {
    pub k_frac: f64,
}

impl RandK {
    pub fn new(k_frac: f64) -> RandK {
        assert!(
            k_frac > 0.0 && k_frac <= 1.0,
            "k% must be in (0, 100], got {}",
            k_frac * 100.0
        );
        RandK { k_frac }
    }

    /// Sample the mask ω as a sorted index list. Both edge endpoints call
    /// this with identically-derived RNGs (`Pcg::derive(seed,
    /// [EDGE_MASK, edge, round, dir])`).
    ///
    /// Uses geometric gap-sampling: instead of one Bernoulli draw per
    /// coordinate (O(d)), draw the gap to the next kept coordinate from
    /// Geometric(k) — O(k·d) expected draws, identical i.i.d.
    /// Bernoulli(k) marginals.  EXPERIMENTS.md §Perf records the ~8×
    /// speedup at k = 10% over the naive path (kept below as the A/B
    /// baseline for the bench).
    pub fn sample_mask(&self, dim: usize, rng: &mut Pcg) -> Vec<u32> {
        if self.k_frac >= 1.0 {
            return (0..dim as u32).collect();
        }
        let mut idx = Vec::with_capacity(
            ((dim as f64) * self.k_frac * 1.2) as usize + 8,
        );
        // gap ~ Geometric(p): floor(ln(U) / ln(1-p)) zeros before the
        // next success.
        let inv_log_q = 1.0 / (1.0 - self.k_frac).ln();
        let mut i = 0f64;
        loop {
            let u = rng.f64().max(1e-300);
            i += (u.ln() * inv_log_q).floor();
            if i >= dim as f64 {
                break;
            }
            idx.push(i as u32);
            i += 1.0;
        }
        idx
    }

    /// Naive per-coordinate Bernoulli sampling — the pre-optimization
    /// baseline, kept for the §Perf A/B bench and as a distribution
    /// cross-check in tests.
    pub fn sample_mask_naive(&self, dim: usize, rng: &mut Pcg) -> Vec<u32> {
        let mut idx = Vec::with_capacity(
            ((dim as f64) * self.k_frac * 1.2) as usize + 8,
        );
        if self.k_frac >= 1.0 {
            idx.extend(0..dim as u32);
            return idx;
        }
        for i in 0..dim as u32 {
            if rng.f64() < self.k_frac {
                idx.push(i);
            }
        }
        idx
    }

    /// Dense 0/1 mask (for the PJRT dual-update path).
    pub fn mask_to_dense(dim: usize, idx: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.resize(dim, 0.0);
        for &i in idx {
            out[i as usize] = 1.0;
        }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand_{}%", (self.k_frac * 100.0).round() as u32)
    }

    fn tau(&self) -> f64 {
        // E‖s∘x − x‖² = (1−k)‖x‖², so τ = k (Stich et al. 2018).
        self.k_frac
    }

    fn compress(&self, x: &[f32], rng: &mut Pcg) -> CooVec {
        let mask = self.sample_mask(x.len(), rng);
        CooVec::gather(x, &mask)
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        true
    }
}

/// Deterministic top-k by magnitude. τ ≥ k/d in the worst case but
/// value-dependent: NOT linear for fixed ω, so it cannot implement the
/// Eq. (13) decomposition — ablation use only.
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    pub k_frac: f64,
}

impl TopK {
    pub fn new(k_frac: f64) -> TopK {
        assert!(k_frac > 0.0 && k_frac <= 1.0);
        TopK { k_frac }
    }

    fn k_of(&self, dim: usize) -> usize {
        (((dim as f64) * self.k_frac).round() as usize).clamp(1, dim)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top_{}%", (self.k_frac * 100.0).round() as u32)
    }

    fn tau(&self) -> f64 {
        self.k_frac // lower bound; actual contraction is data-dependent
    }

    fn compress(&self, x: &[f32], _rng: &mut Pcg) -> CooVec {
        let k = self.k_of(x.len());
        let mut order: Vec<u32> = (0..x.len() as u32).collect();
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            x[b as usize]
                .abs()
                .partial_cmp(&x[a as usize].abs())
                .unwrap()
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        CooVec::gather(x, &idx)
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        false
    }
}

/// Identity (τ = 1): turns C-ECL into exact ECL — Corollary 1.
#[derive(Debug, Clone, Copy)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".to_string()
    }

    fn tau(&self) -> f64 {
        1.0
    }

    fn compress(&self, x: &[f32], _rng: &mut Pcg) -> CooVec {
        let idx: Vec<u32> = (0..x.len() as u32).collect();
        CooVec::gather(x, &idx)
    }

    fn is_linear_for_fixed_omega(&self) -> bool {
        true
    }
}

/// Empirically verify Eq. (7) for an operator on a given input: returns
/// the measured contraction `E‖comp(x) − x‖² / ‖x‖²` over `trials`.
pub fn measure_contraction<C: Compressor>(
    comp: &C,
    x: &[f32],
    trials: usize,
    rng: &mut Pcg,
) -> f64 {
    let norm: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
    if norm == 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for _ in 0..trials {
        let c = comp.compress(x, rng);
        let dense = c.to_dense();
        let err: f64 = x
            .iter()
            .zip(&dense)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum();
        acc += err / norm;
    }
    acc / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{streams, Pcg};

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn randk_mask_shared_seed_identical() {
        // Both edge endpoints derive the same ω — Alg. 1 lines 5-6 omitted.
        let op = RandK::new(0.1);
        let mut a = Pcg::derive(99, &[streams::EDGE_MASK, 4, 17, 0]);
        let mut b = Pcg::derive(99, &[streams::EDGE_MASK, 4, 17, 0]);
        assert_eq!(op.sample_mask(5000, &mut a), op.sample_mask(5000, &mut b));
        // ... and a different round gives a different ω.
        let mut c = Pcg::derive(99, &[streams::EDGE_MASK, 4, 18, 0]);
        assert_ne!(op.sample_mask(5000, &mut a), op.sample_mask(5000, &mut c));
    }

    #[test]
    fn randk_density_close_to_k() {
        let op = RandK::new(0.1);
        let mut rng = Pcg::new(1);
        let mask = op.sample_mask(200_000, &mut rng);
        let density = mask.len() as f64 / 200_000.0;
        assert!((density - 0.1).abs() < 0.005, "density={density}");
    }

    #[test]
    fn gap_sampler_matches_naive_distribution() {
        // The geometric-gap fast path and the naive Bernoulli loop must
        // produce the same marginal density and strictly-sorted unique
        // indices (they need not produce identical masks per seed).
        for k in [0.01, 0.1, 0.37, 0.8] {
            let op = RandK::new(k);
            let d = 300_000;
            let fast = op.sample_mask(d, &mut Pcg::new(2));
            let naive = op.sample_mask_naive(d, &mut Pcg::new(3));
            for m in [&fast, &naive] {
                assert!(m.windows(2).all(|w| w[0] < w[1]), "not sorted");
                assert!(m.last().map(|&i| (i as usize) < d).unwrap_or(true));
                let density = m.len() as f64 / d as f64;
                assert!(
                    (density - k).abs() < 0.01,
                    "k={k}: density {density}"
                );
            }
        }
    }

    #[test]
    fn randk_satisfies_eq7() {
        // E‖comp(x) − x‖² ≈ (1 − τ)‖x‖².
        let op = RandK::new(0.25);
        let x = randn(5000, 2);
        let mut rng = Pcg::new(3);
        let contraction = measure_contraction(&op, &x, 50, &mut rng);
        assert!(
            (contraction - (1.0 - op.tau())).abs() < 0.02,
            "contraction={contraction}"
        );
    }

    #[test]
    fn randk_linearity_for_fixed_omega() {
        // comp(x + y; ω) == comp(x; ω) + comp(y; ω) exactly (Eq. 8).
        let op = RandK::new(0.3);
        let x = randn(1000, 4);
        let y = randn(1000, 5);
        let mut rng = Pcg::new(6);
        let mask = op.sample_mask(1000, &mut rng);
        let sum: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let cx = CooVec::gather(&x, &mask);
        let cy = CooVec::gather(&y, &mask);
        let csum = CooVec::gather(&sum, &mask);
        for k in 0..mask.len() {
            assert_eq!(csum.val[k], cx.val[k] + cy.val[k]);
        }
    }

    #[test]
    fn randk_negation_eq9() {
        let op = RandK::new(0.3);
        let x = randn(500, 7);
        let neg: Vec<f32> = x.iter().map(|a| -a).collect();
        let mut rng = Pcg::new(8);
        let mask = op.sample_mask(500, &mut rng);
        let cx = CooVec::gather(&x, &mask);
        let cn = CooVec::gather(&neg, &mask);
        for k in 0..mask.len() {
            assert_eq!(cn.val[k], -cx.val[k]);
        }
    }

    #[test]
    fn randk_full_is_identity() {
        let op = RandK::new(1.0);
        let x = randn(100, 9);
        let mut rng = Pcg::new(10);
        assert_eq!(op.compress(&x, &mut rng).to_dense(), x);
    }

    #[test]
    fn topk_picks_largest() {
        let op = TopK::new(0.25);
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.3, 0.0, 1.0, 0.05];
        let mut rng = Pcg::new(11);
        let c = op.compress(&x, &mut rng);
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.idx, vec![1, 3]);
        assert!(!op.is_linear_for_fixed_omega());
    }

    #[test]
    fn topk_beats_randk_contraction() {
        // On heavy-tailed inputs top-k preserves far more energy.
        let mut x = randn(1000, 12);
        for i in 0..20 {
            x[i * 50] *= 30.0;
        }
        let mut rng = Pcg::new(13);
        let ct = measure_contraction(&TopK::new(0.05), &x, 1, &mut rng);
        let cr = measure_contraction(&RandK::new(0.05), &x, 20, &mut rng);
        assert!(ct < cr, "top-k {ct} vs rand-k {cr}");
    }

    #[test]
    fn identity_is_exact() {
        let x = randn(64, 14);
        let mut rng = Pcg::new(15);
        let c = Identity.compress(&x, &mut rng);
        assert_eq!(c.to_dense(), x);
        assert_eq!(Identity.tau(), 1.0);
    }

    #[test]
    fn dense_mask_helper() {
        let mut out = Vec::new();
        RandK::mask_to_dense(5, &[1, 4], &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn zero_k_rejected() {
        let _ = RandK::new(0.0);
    }
}
