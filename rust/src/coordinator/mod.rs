//! The training coordinator: thread-per-node execution of any
//! [`AlgorithmSpec`] over a [`Graph`], with the AOT-compiled PJRT
//! artifacts doing all numerical work and the byte-metered bus doing all
//! communication.
//!
//! Round structure (paper §5.1): every node runs `K = local_steps`
//! minibatch updates of Eq. (6) (gossip methods: `alpha_deg = 0` ⇒ plain
//! SGD), then the algorithm's `exchange` fires once.  Evaluation runs on
//! every node's own model every `eval_every` epochs and the mean is
//! reported (the paper's “average test accuracy of each node”).

use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Context, Result};

use crate::algorithms::{build_node, AlgorithmSpec, BuildCtx, DualPath};
use crate::comm::{build_bus, NodeComm};
use crate::data::{build_node_datasets, Batcher, Dataset, Partition,
                  SyntheticSpec};
use crate::graph::Graph;
use crate::metrics::{EpochRecord, History, Mean};
use crate::model::Manifest;
use crate::runtime::{Engine, ModelRuntime};

/// Full experiment description (one table row / one figure series).
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Dataset config name from the artifact manifest (`fashion`/`cifar`).
    pub dataset: String,
    pub algorithm: AlgorithmSpec,
    pub epochs: usize,
    /// Node count (the paper uses 8). Forced to 1 for `Sgd`.
    pub nodes: usize,
    /// Training samples per node (SGD gets `nodes *` this, per the paper:
    /// “a single node containing all training data”).
    pub train_per_node: usize,
    /// Shared test-set size (multiple of the AOT eval batch).
    pub test_size: usize,
    pub partition: Partition,
    /// K — local updates between exchanges (paper: 5).
    pub local_steps: usize,
    /// Learning rate η.
    pub eta: f32,
    /// Evaluate every this many epochs (also evaluates at the end).
    pub eval_every: usize,
    pub seed: u64,
    pub dual_path: DualPath,
    /// Override the artifact directory (defaults to `$CECL_ARTIFACTS` or
    /// `./artifacts`).
    pub artifacts_dir: Option<String>,
    /// Print per-eval progress lines.
    pub verbose: bool,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            dataset: "fashion".to_string(),
            algorithm: AlgorithmSpec::Ecl { theta: 1.0 },
            epochs: 10,
            nodes: 8,
            train_per_node: 500,
            test_size: 1000,
            partition: Partition::Homogeneous,
            local_steps: 5,
            eta: 0.02,
            eval_every: 2,
            seed: 42,
            dual_path: DualPath::Native,
            artifacts_dir: None,
            verbose: false,
        }
    }
}

/// Result of one experiment run.
#[derive(Debug, Clone)]
pub struct Report {
    pub algorithm: String,
    pub dataset: String,
    pub partition: String,
    pub topology: String,
    pub history: History,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    /// Mean bytes sent per node per epoch — the paper's “Send/Epoch”.
    pub mean_bytes_per_epoch: f64,
    pub total_bytes: u64,
    pub wallclock_secs: f64,
}

/// Run one experiment on the given topology. This is the crate's main
/// entry point (see `examples/`).
pub fn run_experiment(spec: &ExperimentSpec, graph: &Graph) -> Result<Report> {
    let manifest = match &spec.artifacts_dir {
        Some(dir) => Manifest::load(dir)?,
        None => Manifest::load_default()?,
    };
    let engine = Engine::cpu()?;
    run_with_engine(&engine, &manifest, spec, graph)
}

/// Run with a pre-built engine/manifest (lets callers amortize PJRT
/// startup and artifact compilation across many runs — the experiment
/// drivers use this).
pub fn run_with_engine(
    engine: &Engine,
    manifest: &Manifest,
    spec: &ExperimentSpec,
    graph: &Graph,
) -> Result<Report> {
    let t0 = std::time::Instant::now();
    let ds = manifest.dataset(&spec.dataset)?.clone();
    let runtime = ModelRuntime::load(engine, &ds)?;

    // SGD trains on one node holding all data.
    let is_sgd = !spec.algorithm.is_decentralized();
    let (graph_owned, nodes, train_per_node) = if is_sgd {
        (Graph::from_edges(1, &[]), 1, spec.train_per_node * spec.nodes)
    } else {
        (graph.clone(), graph.n(), spec.train_per_node)
    };
    let graph = Arc::new(graph_owned);
    if !is_sgd && graph.n() != spec.nodes {
        return Err(anyhow!(
            "graph has {} nodes, spec expects {}",
            graph.n(),
            spec.nodes
        ));
    }

    let batches_per_epoch = train_per_node / ds.batch;
    if batches_per_epoch == 0 {
        return Err(anyhow!(
            "train_per_node {} < batch {}",
            train_per_node,
            ds.batch
        ));
    }
    let rounds_per_epoch = (batches_per_epoch / spec.local_steps).max(1);
    let total_rounds = spec.epochs * rounds_per_epoch;

    // Data.
    let (h, wdt, c) = ds.input;
    let data_spec = SyntheticSpec::for_dataset(
        &spec.dataset, h, wdt, c, ds.classes, spec.seed,
    );
    let (trains, test) = build_node_datasets(
        &data_spec,
        if is_sgd { Partition::Homogeneous } else { spec.partition },
        nodes,
        train_per_node,
        spec.test_size,
    );
    let test = Arc::new(test);
    let init_w = Arc::new(ds.load_init_w()?);

    // Bus + collector.
    let (comms, meter) = build_bus(&graph);
    let (tx, rx) = mpsc::channel::<(usize, usize, f64, f64, f64)>();

    // Eval schedule: end of every `eval_every`-th epoch plus the last.
    let eval_epochs: Vec<usize> = (1..=spec.epochs)
        .filter(|e| e % spec.eval_every == 0 || *e == spec.epochs)
        .collect();
    let eval_rounds: std::collections::BTreeMap<usize, usize> = eval_epochs
        .iter()
        .map(|&e| (e * rounds_per_epoch - 1, e))
        .collect();

    let worker = |node: usize,
                  comm: NodeComm,
                  train: Dataset,
                  tx: mpsc::Sender<(usize, usize, f64, f64, f64)>|
     -> Result<()> {
        let ctx = BuildCtx {
            node,
            graph: Arc::clone(&graph),
            manifest: ds.clone(),
            seed: spec.seed,
            eta: spec.eta,
            local_steps: spec.local_steps,
            rounds_per_epoch,
            dual_path: spec.dual_path,
            runtime: Some(Arc::clone(&runtime)),
        };
        let mut algo = build_node(&spec.algorithm, &ctx);
        let mut w = (*init_w).clone();
        let zeros = vec![0.0f32; ds.d_pad];
        let mut batcher = Batcher::new(train.n, ds.batch, spec.seed, node);
        let mut x = vec![0.0f32; ds.batch * train.sample_len];
        let mut y = vec![0i32; ds.batch];
        let mut train_loss = Mean::default();
        for round in 0..total_rounds {
            for _ in 0..spec.local_steps {
                batcher.next_batch(&train, &mut x, &mut y);
                let zsum = algo.zsum().unwrap_or(&zeros);
                let (w_next, loss) = runtime
                    .train_step(&w, zsum, &x, &y, spec.eta, algo.alpha_deg())
                    .with_context(|| format!("train_step node {node}"))?;
                w = w_next;
                train_loss.add(loss as f64);
            }
            if !is_sgd {
                algo.exchange(round, &mut w, &comm);
            }
            if let Some(&epoch) = eval_rounds.get(&round) {
                let (acc, loss) = runtime
                    .evaluate(&w, &test)
                    .with_context(|| format!("eval node {node}"))?;
                tx.send((node, epoch, acc, loss, train_loss.take()))
                    .map_err(|_| anyhow!("collector closed"))?;
            }
        }
        Ok(())
    };

    // Spawn node threads.
    let mut history = History::default();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for ((node, comm), train) in
            (0..nodes).zip(comms).zip(trains.into_iter())
        {
            let worker = &worker;
            let tx = tx.clone();
            handles.push(s.spawn(move || worker(node, comm, train, tx)));
        }
        drop(tx);

        // Collector: aggregate per-epoch means over nodes. Per-node slots
        // are filled first and summed in node order, so the result is
        // bit-deterministic regardless of message arrival order.
        type Slot = Vec<Option<(f64, f64, f64)>>;
        let mut pending: std::collections::BTreeMap<usize, Slot> =
            Default::default();
        let mut done = 0usize;
        let expected = eval_epochs.len();
        while done < expected {
            match rx.recv() {
                Ok((node, epoch, acc, loss, tloss)) => {
                    let entry = pending
                        .entry(epoch)
                        .or_insert_with(|| vec![None; nodes]);
                    entry[node] = Some((acc, loss, tloss));
                    if entry.iter().all(Option::is_some) {
                        let slots = pending.remove(&epoch).unwrap();
                        let (mut a, mut l, mut t) =
                            (Mean::default(), Mean::default(), Mean::default());
                        for s in slots.into_iter().flatten() {
                            a.add(s.0);
                            l.add(s.1);
                            t.add(s.2);
                        }
                        let rec = EpochRecord {
                            epoch,
                            mean_accuracy: a.take(),
                            mean_loss: l.take(),
                            train_loss: t.take(),
                            cum_bytes_per_node: meter.mean_bytes_per_node(),
                        };
                        if spec.verbose {
                            println!(
                                "[{}] epoch {:>4}: acc {:.3} loss {:.3} \
                                 train {:.3} sent/node {:.0} KB",
                                spec.algorithm.name(),
                                rec.epoch,
                                rec.mean_accuracy,
                                rec.mean_loss,
                                rec.train_loss,
                                rec.cum_bytes_per_node / 1024.0
                            );
                        }
                        history.push(rec);
                        done += 1;
                    }
                }
                Err(_) => break, // all workers exited (possibly with error)
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    })?;

    let total_bytes = meter.total_bytes();
    let mean_bytes_per_epoch =
        total_bytes as f64 / nodes as f64 / spec.epochs as f64;
    Ok(Report {
        algorithm: spec.algorithm.name(),
        dataset: spec.dataset.clone(),
        partition: spec.partition.name(),
        topology: if is_sgd { "single".to_string() } else { "graph".to_string() },
        final_accuracy: history.final_accuracy(),
        best_accuracy: history.best_accuracy(),
        history,
        mean_bytes_per_epoch,
        total_bytes,
        wallclock_secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_shaped() {
        let spec = ExperimentSpec::default();
        assert_eq!(spec.nodes, 8);
        assert_eq!(spec.local_steps, 5);
        assert_eq!(spec.partition, Partition::Homogeneous);
    }

    #[test]
    fn eval_schedule_includes_last_epoch() {
        // (Pure logic replicated from run_with_engine.)
        let epochs = 7usize;
        let eval_every = 3usize;
        let evals: Vec<usize> = (1..=epochs)
            .filter(|e| e % eval_every == 0 || *e == epochs)
            .collect();
        assert_eq!(evals, vec![3, 6, 7]);
    }
}
