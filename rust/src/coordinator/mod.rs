//! The training coordinator: runs any [`AlgorithmSpec`] over a
//! [`Graph`] on one of two execution engines, selected via
//! [`ExperimentSpec::exec`]:
//!
//! * **Threaded** — one OS thread per node over the blocking
//!   byte-metered bus (`comm::build_bus`); the original engine, right
//!   for artifact-backed wall-clock benchmarking at paper scale (8
//!   nodes).
//! * **Simulated** — the event-driven virtual-time engine
//!   (`crate::sim`): single thread, 512+ nodes, pluggable link models
//!   (latency / bandwidth / drops / stragglers), a time-varying
//!   topology (`SimConfig::churn`: outage holds, edge churn, node
//!   join-leave), and a simulated time-to-accuracy clock.  Local
//!   numerics run through the PJRT artifacts when present
//!   ([`run_with_engine`]) or through the artifact-free softmax
//!   backend ([`run_simulated_native`]).  The threaded engine is
//!   epoch-constant by construction — churn schedules exist only on
//!   the simulated path.
//!
//! Round structure (paper §5.1): every node runs `K = local_steps`
//! minibatch updates of Eq. (6) (gossip methods: `alpha_deg = 0` ⇒ plain
//! SGD), then the algorithm's exchange fires once.  Evaluation runs on
//! every node's own model every `eval_every` epochs and the mean is
//! reported (the paper's “average test accuracy of each node”).

use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Context, Result};

use crate::algorithms::{build_machine, build_node, AlgorithmSpec, BuildCtx,
                        DualPath, RoundPolicy};
use crate::comm::{build_bus, NodeComm};
use crate::data::{build_node_datasets, Batcher, Dataset, Partition,
                  SyntheticSpec};
use crate::graph::Graph;
use crate::metrics::{EpochRecord, History, Mean};
use crate::model::{DatasetManifest, Manifest};
use crate::runtime::{Engine, ModelRuntime};
use crate::sim::{self, Schedule, SimConfig, SoftmaxLocal};

/// Which execution engine runs the experiment.
#[derive(Debug, Clone, Default)]
pub enum ExecMode {
    /// Thread-per-node over blocking channels (zero-latency, lossless).
    #[default]
    Threaded,
    /// Event-driven virtual-time simulation with the given scenario.
    Simulated(SimConfig),
}

/// Full experiment description (one table row / one figure series).
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Dataset config name from the artifact manifest (`fashion`/`cifar`).
    pub dataset: String,
    pub algorithm: AlgorithmSpec,
    pub epochs: usize,
    /// Node count (the paper uses 8; the simulated engine scales to
    /// 512+). Forced to 1 for `Sgd`.
    pub nodes: usize,
    /// Training samples per node (SGD gets `nodes *` this, per the paper:
    /// “a single node containing all training data”).
    pub train_per_node: usize,
    /// Shared test-set size (multiple of the AOT eval batch).
    pub test_size: usize,
    pub partition: Partition,
    /// K — local updates between exchanges (paper: 5).
    pub local_steps: usize,
    /// Learning rate η.
    pub eta: f32,
    /// Evaluate every this many epochs (also evaluates at the end).
    pub eval_every: usize,
    pub seed: u64,
    pub dual_path: DualPath,
    /// Execution engine (threaded vs virtual-time).
    pub exec: ExecMode,
    /// Round policy: bulk-synchronous (default; trajectory pinned
    /// identical to the pre-async schedule) or event-driven with
    /// bounded per-edge staleness (`--rounds async:<s>`; requires the
    /// virtual-time engine).
    pub rounds: RoundPolicy,
    /// Override the artifact directory (defaults to `$CECL_ARTIFACTS` or
    /// `./artifacts`).
    pub artifacts_dir: Option<String>,
    /// Print per-eval progress lines.
    pub verbose: bool,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            dataset: "fashion".to_string(),
            algorithm: AlgorithmSpec::Ecl { theta: 1.0 },
            epochs: 10,
            nodes: 8,
            train_per_node: 500,
            test_size: 1000,
            partition: Partition::Homogeneous,
            local_steps: 5,
            eta: 0.02,
            eval_every: 2,
            seed: 42,
            dual_path: DualPath::Native,
            exec: ExecMode::Threaded,
            rounds: RoundPolicy::Sync,
            artifacts_dir: None,
            verbose: false,
        }
    }
}

/// Result of one experiment run.
#[derive(Debug, Clone)]
pub struct Report {
    pub algorithm: String,
    pub dataset: String,
    pub partition: String,
    pub topology: String,
    pub history: History,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    /// Mean bytes sent per node per epoch — the paper's “Send/Epoch”
    /// (first-copy payload bytes; retransmissions are separate).
    pub mean_bytes_per_epoch: f64,
    pub total_bytes: u64,
    /// Extra bytes burned on retransmissions (0 on lossless links and
    /// under the threaded engine).
    pub retransmit_bytes: u64,
    /// Total simulated time (None under the threaded engine).
    pub sim_time_secs: Option<f64>,
    /// Largest per-edge staleness (rounds) any node consumed — 0 under
    /// sync rounds and the threaded engine.
    pub max_staleness: usize,
    /// Edge lifecycle transitions applied by the churn scheduler — 0 on
    /// a static schedule and under the threaded engine (which accepts
    /// only epoch-constant schedules).
    pub edges_churned: u64,
    /// Frames drained in flight by topology churn (their payload bytes
    /// stay in the send accounting — byte-exact metering).
    pub frames_dropped_by_churn: u64,
    /// Framing overhead bytes (wire headers) — nonzero only under the
    /// net engine; the in-process engines carry no framing.  Kept apart
    /// from `total_bytes` so payload accounting stays comparable across
    /// engines.
    pub header_overhead_bytes: u64,
    /// Payload bytes per directed edge (`comm::directed_edge_index`
    /// layout).  Filled by the virtual-time and net engines; empty under
    /// the threaded engine.  This is the cross-engine identity surface:
    /// a net run's vector must equal the sim's for the same spec/seed.
    pub edge_payload_bytes: Vec<u64>,
    pub wallclock_secs: f64,
}

impl Report {
    /// The simulated clock, or a typed error for threaded runs — for
    /// callers that require a virtual time instead of unwrapping the
    /// `Option` (drivers print `—` for the missing case).
    pub fn require_sim_time(&self) -> Result<f64, crate::metrics::MetricsError> {
        self.sim_time_secs
            .ok_or(crate::metrics::MetricsError::NoSimClock)
    }
}

/// Derived round/eval structure for a spec against a dataset config.
pub(crate) fn build_schedule(spec: &ExperimentSpec, train_per_node: usize,
                             batch: usize) -> Result<Schedule> {
    let batches_per_epoch = train_per_node / batch;
    if batches_per_epoch == 0 {
        return Err(anyhow!(
            "train_per_node {train_per_node} < batch {batch}"
        ));
    }
    let rounds_per_epoch = (batches_per_epoch / spec.local_steps).max(1);
    Ok(Schedule::new(spec.epochs, rounds_per_epoch, spec.local_steps,
                     spec.eval_every))
}

/// SGD trains on one node holding all data; everything else keeps the
/// caller's graph.  Returns `(graph, nodes, train_per_node)`.
fn effective_graph(spec: &ExperimentSpec, graph: &Graph)
                   -> Result<(Arc<Graph>, usize, usize)> {
    if !spec.algorithm.is_decentralized() {
        return Ok((
            Arc::new(Graph::from_edges(1, &[])),
            1,
            spec.train_per_node * spec.nodes,
        ));
    }
    if graph.n() != spec.nodes {
        return Err(anyhow!(
            "graph has {} nodes, spec expects {}",
            graph.n(),
            spec.nodes
        ));
    }
    Ok((Arc::new(graph.clone()), graph.n(), spec.train_per_node))
}

/// Run one experiment on the given topology. This is the crate's main
/// entry point (see `examples/`).  Requires AOT artifacts; for the
/// artifact-free simulated path use [`run_simulated_native`].
pub fn run_experiment(spec: &ExperimentSpec, graph: &Graph) -> Result<Report> {
    let manifest = match &spec.artifacts_dir {
        Some(dir) => Manifest::load(dir)?,
        None => Manifest::load_default()?,
    };
    let engine = Engine::cpu()?;
    run_with_engine(&engine, &manifest, spec, graph)
}

/// Run with a pre-built engine/manifest (lets callers amortize PJRT
/// startup and artifact compilation across many runs — the experiment
/// drivers use this).  Dispatches on `spec.exec`.
pub fn run_with_engine(
    engine: &Engine,
    manifest: &Manifest,
    spec: &ExperimentSpec,
    graph: &Graph,
) -> Result<Report> {
    if let ExecMode::Simulated(cfg) = &spec.exec {
        let cfg = cfg.clone();
        return run_simulated_pjrt(engine, manifest, spec, graph, &cfg);
    }
    run_threaded(engine, manifest, spec, graph)
}

fn run_threaded(
    engine: &Engine,
    manifest: &Manifest,
    spec: &ExperimentSpec,
    graph: &Graph,
) -> Result<Report> {
    if spec.rounds.is_async() {
        return Err(anyhow!(
            "RoundPolicy::{} requires the virtual-time engine \
             (ExecMode::Simulated): the threaded bus blocks on every \
             neighbor and is bulk-synchronous by construction",
            spec.rounds.name()
        ));
    }
    let t0 = std::time::Instant::now();
    let ds = manifest.dataset(&spec.dataset)?.clone();
    let runtime = ModelRuntime::load(engine, &ds)?;

    let is_sgd = !spec.algorithm.is_decentralized();
    let (graph, nodes, train_per_node) = effective_graph(spec, graph)?;
    let sched = build_schedule(spec, train_per_node, ds.batch)?;
    let rounds_per_epoch = sched.rounds_per_epoch;
    let total_rounds = sched.total_rounds();

    // Data.
    let (h, wdt, c) = ds.input;
    let data_spec = SyntheticSpec::for_dataset(
        &spec.dataset, h, wdt, c, ds.classes, spec.seed,
    );
    let (trains, test) = build_node_datasets(
        &data_spec,
        if is_sgd { Partition::Homogeneous } else { spec.partition },
        nodes,
        train_per_node,
        spec.test_size,
    );
    let test = Arc::new(test);
    let init_w = Arc::new(ds.load_init_w()?);

    // Bus + collector.
    let (comms, meter) = build_bus(&graph);
    let (tx, rx) = mpsc::channel::<(usize, usize, f64, f64, f64)>();

    let worker = |node: usize,
                  comm: NodeComm,
                  train: Dataset,
                  tx: mpsc::Sender<(usize, usize, f64, f64, f64)>|
     -> Result<()> {
        let ctx = BuildCtx {
            node,
            graph: Arc::clone(&graph),
            manifest: ds.clone(),
            seed: spec.seed,
            eta: spec.eta,
            local_steps: spec.local_steps,
            rounds_per_epoch,
            dual_path: spec.dual_path,
            runtime: Some(Arc::clone(&runtime)),
            round_policy: RoundPolicy::Sync,
        };
        let mut algo = build_node(&spec.algorithm, &ctx)?;
        let mut w = (*init_w).clone();
        let zeros = vec![0.0f32; ds.d_pad];
        let mut batcher = Batcher::new(train.n, ds.batch, spec.seed, node);
        let mut x = vec![0.0f32; ds.batch * train.sample_len];
        let mut y = vec![0i32; ds.batch];
        let mut train_loss = Mean::default();
        for round in 0..total_rounds {
            for _ in 0..spec.local_steps {
                batcher.next_batch(&train, &mut x, &mut y);
                let zsum = algo.zsum().unwrap_or(&zeros);
                let (w_next, loss) = runtime
                    .train_step(&w, zsum, &x, &y, spec.eta, algo.alpha_deg())
                    .with_context(|| format!("train_step node {node}"))?;
                w = w_next;
                train_loss.add(loss as f64);
            }
            if !is_sgd {
                algo.exchange(round, &mut w, &comm)
                    .with_context(|| format!("exchange node {node} round {round}"))?;
            }
            if let Some(&epoch) = sched.eval_rounds.get(&round) {
                let (acc, loss) = runtime
                    .evaluate(&w, &test)
                    .with_context(|| format!("eval node {node}"))?;
                tx.send((node, epoch, acc, loss, train_loss.take()))
                    .map_err(|_| anyhow!("collector closed"))?;
            }
        }
        Ok(())
    };

    // Spawn node threads.
    let mut history = History::default();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for ((node, comm), train) in
            (0..nodes).zip(comms).zip(trains.into_iter())
        {
            let worker = &worker;
            let tx = tx.clone();
            handles.push(s.spawn(move || worker(node, comm, train, tx)));
        }
        drop(tx);

        // Collector: aggregate per-epoch means over nodes. Per-node slots
        // are filled first and summed in node order, so the result is
        // bit-deterministic regardless of message arrival order.
        type Slot = Vec<Option<(f64, f64, f64)>>;
        let mut pending: std::collections::BTreeMap<usize, Slot> =
            Default::default();
        let mut done = 0usize;
        let expected = sched.eval_rounds.len();
        while done < expected {
            match rx.recv() {
                Ok((node, epoch, acc, loss, tloss)) => {
                    let entry = pending
                        .entry(epoch)
                        .or_insert_with(|| vec![None; nodes]);
                    entry[node] = Some((acc, loss, tloss));
                    if entry.iter().all(Option::is_some) {
                        let slots = pending.remove(&epoch).unwrap();
                        let (mut a, mut l, mut t) =
                            (Mean::default(), Mean::default(), Mean::default());
                        for s in slots.into_iter().flatten() {
                            a.add(s.0);
                            l.add(s.1);
                            t.add(s.2);
                        }
                        let rec = EpochRecord {
                            epoch,
                            mean_accuracy: a.take(),
                            mean_loss: l.take(),
                            train_loss: t.take(),
                            cum_bytes_per_node: meter.mean_bytes_per_node(),
                            sim_time_secs: 0.0,
                        };
                        if spec.verbose {
                            println!(
                                "[{}] epoch {:>4}: acc {:.3} loss {:.3} \
                                 train {:.3} sent/node {:.0} KB",
                                spec.algorithm.name(),
                                rec.epoch,
                                rec.mean_accuracy,
                                rec.mean_loss,
                                rec.train_loss,
                                rec.cum_bytes_per_node / 1024.0
                            );
                        }
                        history.push(rec);
                        done += 1;
                    }
                }
                Err(_) => break, // all workers exited (possibly with error)
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    })?;

    let total_bytes = meter.total_bytes();
    let mean_bytes_per_epoch =
        total_bytes as f64 / nodes as f64 / spec.epochs as f64;
    Ok(Report {
        algorithm: spec.algorithm.name(),
        dataset: spec.dataset.clone(),
        partition: spec.partition.name(),
        topology: if is_sgd { "single".to_string() } else { "graph".to_string() },
        final_accuracy: history.final_accuracy(),
        best_accuracy: history.best_accuracy(),
        history,
        mean_bytes_per_epoch,
        total_bytes,
        retransmit_bytes: 0,
        sim_time_secs: None,
        max_staleness: 0,
        edges_churned: 0,
        frames_dropped_by_churn: 0,
        header_overhead_bytes: 0,
        edge_payload_bytes: Vec::new(),
        wallclock_secs: t0.elapsed().as_secs_f64(),
    })
}

// ---------------------------------------------------------------------
// Virtual-time paths
// ---------------------------------------------------------------------

/// PJRT-backed local numerics for the virtual-time engine.
struct PjrtLocal {
    runtime: Arc<ModelRuntime>,
    train: Dataset,
    test: Arc<Dataset>,
    batcher: Batcher,
    x: Vec<f32>,
    y: Vec<i32>,
    eta: f32,
    local_steps: usize,
}

impl sim::LocalUpdate for PjrtLocal {
    fn local_round(&mut self, _round: usize, w: &mut [f32], zsum: &[f32],
                   alpha_deg: f32) -> Result<f64> {
        let mut m = Mean::default();
        for _ in 0..self.local_steps {
            self.batcher.next_batch(&self.train, &mut self.x, &mut self.y);
            let (w_next, loss) = self
                .runtime
                .train_step(w, zsum, &self.x, &self.y, self.eta, alpha_deg)?;
            w.copy_from_slice(&w_next);
            m.add(loss as f64);
        }
        Ok(m.get())
    }

    fn evaluate(&mut self, w: &[f32]) -> Result<(f64, f64)> {
        self.runtime.evaluate(w, &self.test)
    }
}

/// Shared virtual-time driver: builds data + machines, runs the event
/// loop, assembles the Report.  `make_local` supplies the numerics
/// backend per node.
fn run_simulated_inner<F>(
    spec: &ExperimentSpec,
    graph: &Graph,
    cfg: &SimConfig,
    ds: &DatasetManifest,
    init_w: Vec<f32>,
    mut make_local: F,
) -> Result<Report>
where
    F: FnMut(usize, Dataset, Arc<Dataset>) -> Result<Box<dyn sim::LocalUpdate>>,
{
    let t0 = std::time::Instant::now();
    let is_sgd = !spec.algorithm.is_decentralized();
    let (graph, nodes, train_per_node) = effective_graph(spec, graph)?;
    let sched = build_schedule(spec, train_per_node, ds.batch)?;

    let (h, wdt, c) = ds.input;
    let data_spec = SyntheticSpec::for_dataset(
        &spec.dataset, h, wdt, c, ds.classes, spec.seed,
    );
    let (trains, test) = build_node_datasets(
        &data_spec,
        if is_sgd { Partition::Homogeneous } else { spec.partition },
        nodes,
        train_per_node,
        spec.test_size,
    );
    let test = Arc::new(test);

    let mut setups = Vec::with_capacity(nodes);
    for (node, train) in trains.into_iter().enumerate() {
        let ctx = BuildCtx {
            node,
            graph: Arc::clone(&graph),
            manifest: ds.clone(),
            seed: spec.seed,
            eta: spec.eta,
            local_steps: spec.local_steps,
            rounds_per_epoch: sched.rounds_per_epoch,
            // The state machines always run the native fused dual path;
            // DualPath::Pjrt is a threaded-engine option.
            dual_path: DualPath::Native,
            runtime: None,
            round_policy: spec.rounds,
        };
        setups.push(sim::NodeSetup {
            machine: build_machine(&spec.algorithm, &ctx)?,
            local: make_local(node, train, Arc::clone(&test))?,
            w: init_w.clone(),
        });
    }

    let out = sim::simulate(&graph, cfg, spec.seed, &sched, setups,
                            spec.rounds, spec.verbose)?;
    let total_bytes = out.meter.total_bytes();
    let mean_bytes_per_epoch =
        total_bytes as f64 / nodes as f64 / spec.epochs as f64;
    Ok(Report {
        algorithm: spec.algorithm.name(),
        dataset: spec.dataset.clone(),
        partition: spec.partition.name(),
        topology: if is_sgd { "single".to_string() } else { "graph".to_string() },
        final_accuracy: out.history.final_accuracy(),
        best_accuracy: out.history.best_accuracy(),
        history: out.history,
        mean_bytes_per_epoch,
        total_bytes,
        retransmit_bytes: out.meter.total_retransmit_bytes(),
        sim_time_secs: Some(out.vtime_ns as f64 / 1e9),
        max_staleness: out.max_staleness,
        edges_churned: out.edges_churned,
        frames_dropped_by_churn: out.meter.churn_dropped_frames(),
        header_overhead_bytes: 0,
        edge_payload_bytes: out.meter.edge_payload_bytes().unwrap_or_default(),
        wallclock_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Virtual-time run with the PJRT CNN as the local model (requires AOT
/// artifacts).  Usually reached through [`run_with_engine`] with
/// `spec.exec = ExecMode::Simulated(..)`.
pub fn run_simulated_pjrt(
    engine: &Engine,
    manifest: &Manifest,
    spec: &ExperimentSpec,
    graph: &Graph,
    cfg: &SimConfig,
) -> Result<Report> {
    let ds = manifest.dataset(&spec.dataset)?.clone();
    let runtime = ModelRuntime::load(engine, &ds)?;
    let init_w = ds.load_init_w()?;
    let eta = spec.eta;
    let local_steps = spec.local_steps;
    let seed = spec.seed;
    let batch = ds.batch;
    run_simulated_inner(spec, graph, cfg, &ds, init_w, move |node, train, test| {
        let local: Box<dyn sim::LocalUpdate> = Box::new(PjrtLocal {
            runtime: Arc::clone(&runtime),
            batcher: Batcher::new(train.n, batch, seed, node),
            x: vec![0.0f32; batch * train.sample_len],
            y: vec![0i32; batch],
            train,
            test,
            eta,
            local_steps,
        });
        Ok(local)
    })
}

/// Input shape for the artifact-free linear model, keyed off the spec's
/// dataset name (shape-compatible stand-ins, like the data generator).
pub(crate) fn native_input(dataset: &str) -> (usize, usize, usize) {
    match dataset {
        "cifar" => (32, 32, 3),
        "fashion" => (28, 28, 1),
        _ => (8, 8, 1),
    }
}

/// Batch size of the artifact-free softmax backend.
pub const NATIVE_SIM_BATCH: usize = 10;

/// Virtual-time run with the artifact-free softmax-regression local
/// model: no PJRT, no Python, no artifacts — this is what the CI smoke
/// run, the 512-node scale tests, and `repro sim` use.
pub fn run_simulated_native(spec: &ExperimentSpec, graph: &Graph)
                            -> Result<Report> {
    let cfg = match &spec.exec {
        ExecMode::Simulated(c) => c.clone(),
        ExecMode::Threaded => SimConfig::default(),
    };
    let classes = 10;
    let ds = DatasetManifest::synthetic_linear(
        &spec.dataset,
        native_input(&spec.dataset),
        classes,
        NATIVE_SIM_BATCH,
        NATIVE_SIM_BATCH,
    );
    let init_w = vec![0.0f32; ds.d_pad];
    let eta = spec.eta;
    let local_steps = spec.local_steps;
    let seed = spec.seed;
    run_simulated_inner(spec, graph, &cfg, &ds, init_w, move |node, train, test| {
        let local: Box<dyn sim::LocalUpdate> = Box::new(SoftmaxLocal::new(
            node,
            train,
            test,
            classes,
            seed,
            eta,
            NATIVE_SIM_BATCH,
            local_steps,
        )?);
        Ok(local)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::LinkSpec;

    #[test]
    fn defaults_are_paper_shaped() {
        let spec = ExperimentSpec::default();
        assert_eq!(spec.nodes, 8);
        assert_eq!(spec.local_steps, 5);
        assert_eq!(spec.partition, Partition::Homogeneous);
        assert!(matches!(spec.exec, ExecMode::Threaded));
        // The default round policy IS the pre-async schedule.
        assert_eq!(spec.rounds, RoundPolicy::Sync);
    }

    #[test]
    fn async_native_sim_runs_replays_and_bounds_staleness() {
        let graph = Graph::ring(6);
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::CEcl {
                k_frac: 0.2,
                theta: 1.0,
                dense_first_epoch: false,
            },
            epochs: 3,
            nodes: 6,
            train_per_node: 20,
            test_size: 40,
            local_steps: 2,
            eta: 0.1,
            eval_every: 1,
            seed: 11,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 300 },
                stragglers: vec![(1, 6.0)],
                ..SimConfig::default()
            }),
            rounds: RoundPolicy::Async { max_staleness: 2 },
            ..Default::default()
        };
        let a = run_simulated_native(&spec, &graph).unwrap();
        let b = run_simulated_native(&spec, &graph).unwrap();
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.sim_time_secs, b.sim_time_secs);
        assert_eq!(a.max_staleness, b.max_staleness);
        assert!(a.max_staleness <= 2, "bound violated: {}", a.max_staleness);
        assert!(a.final_accuracy.is_finite());
        // PowerGossip runs async too (conversation counters — PR 3
        // pinned a typed rejection here) and honors the same bound.
        let pg = ExperimentSpec {
            algorithm: AlgorithmSpec::PowerGossip { iters: 2 },
            ..spec.clone()
        };
        let r = run_simulated_native(&pg, &graph).unwrap();
        assert!(r.max_staleness <= 2, "PG bound violated: {}", r.max_staleness);
        assert!(r.final_accuracy.is_finite());
        assert!(r.total_bytes > 0);
    }

    #[test]
    fn schedule_includes_last_epoch() {
        let spec = ExperimentSpec {
            epochs: 7,
            eval_every: 3,
            local_steps: 2,
            ..Default::default()
        };
        // 100 samples / batch 10 = 10 batches; K=2 -> 5 rounds/epoch.
        let sched = build_schedule(&spec, 100, 10).unwrap();
        assert_eq!(sched.rounds_per_epoch, 5);
        let epochs: Vec<usize> = sched.eval_rounds.values().copied().collect();
        assert_eq!(epochs, vec![3, 6, 7]);
        // Each eval lands on the epoch's last round.
        for (&round, &epoch) in &sched.eval_rounds {
            assert_eq!(round, epoch * 5 - 1);
        }
    }

    #[test]
    fn schedule_rejects_tiny_datasets() {
        let spec = ExperimentSpec::default();
        assert!(build_schedule(&spec, 3, 10).is_err());
    }

    #[test]
    fn effective_graph_forces_sgd_to_one_node() {
        let spec = ExperimentSpec {
            algorithm: AlgorithmSpec::Sgd,
            nodes: 8,
            train_per_node: 100,
            ..Default::default()
        };
        let g = Graph::ring(8);
        let (g1, n, tpn) = effective_graph(&spec, &g).unwrap();
        assert_eq!(g1.n(), 1);
        assert_eq!(n, 1);
        assert_eq!(tpn, 800);
        // Mismatched node counts are rejected for decentralized specs.
        let spec = ExperimentSpec {
            nodes: 6,
            ..Default::default()
        };
        assert!(effective_graph(&spec, &g).is_err());
    }

    #[test]
    fn native_sim_churn_counters_surface_in_report() {
        use crate::graph::ChurnSchedule;
        let graph = Graph::ring(6);
        let mut churn = ChurnSchedule::default();
        // 40% per edge per 1 ms slot: across ~7 slots x 6 edges the
        // probability of a seeded run with zero transitions is ~1e-9.
        churn.random_edge_churn_with_slot(0.4, 3, 1_000_000);
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::CEcl {
                k_frac: 0.2,
                theta: 1.0,
                dense_first_epoch: false,
            },
            epochs: 3,
            nodes: 6,
            train_per_node: 20,
            test_size: 40,
            local_steps: 2,
            eta: 0.1,
            eval_every: 1,
            seed: 11,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 500 },
                churn,
                ..SimConfig::default()
            }),
            rounds: RoundPolicy::Async { max_staleness: 2 },
            ..Default::default()
        };
        let a = run_simulated_native(&spec, &graph).unwrap();
        assert!(a.edges_churned > 0, "0.2/2ms churn must transition");
        assert!(a.final_accuracy.is_finite());
        assert!(a.max_staleness <= 2, "bound over live edges only");
        // Replays bit-identically, churn and all.
        let b = run_simulated_native(&spec, &graph).unwrap();
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.edges_churned, b.edges_churned);
        assert_eq!(a.frames_dropped_by_churn, b.frames_dropped_by_churn);
        // A static run reports zeros (the drivers print `—`).
        let static_spec = ExperimentSpec {
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 500 },
                ..SimConfig::default()
            }),
            ..spec.clone()
        };
        let s = run_simulated_native(&static_spec, &graph).unwrap();
        assert_eq!(s.edges_churned, 0);
        assert_eq!(s.frames_dropped_by_churn, 0);
    }

    #[test]
    fn native_sim_runs_and_replays_bit_identically() {
        let graph = Graph::ring(4);
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::CEcl {
                k_frac: 0.2,
                theta: 1.0,
                dense_first_epoch: false,
            },
            epochs: 2,
            nodes: 4,
            train_per_node: 20,
            test_size: 40,
            local_steps: 2,
            eta: 0.1,
            eval_every: 1,
            seed: 5,
            exec: ExecMode::Simulated(SimConfig::default()),
            ..Default::default()
        };
        let a = run_simulated_native(&spec, &graph).unwrap();
        let b = run_simulated_native(&spec, &graph).unwrap();
        assert_eq!(a.history.records.len(), 2);
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.sim_time_secs, b.sim_time_secs);
        assert!(a.total_bytes > 0);
        assert!(a.sim_time_secs.unwrap() > 0.0);
    }
}
