//! Per-node minibatch scheduling: shuffled epochs, fixed batch size,
//! wrap-around so every epoch yields exactly `n / batch` (ceil) batches
//! of the full AOT-compiled batch shape.

use super::Dataset;
use crate::util::rng::{streams, Pcg};

/// Iterates shuffled minibatches over one node's dataset.
pub struct Batcher {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Pcg,
    epoch: usize,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64, node: usize) -> Batcher {
        assert!(batch > 0 && n >= batch, "need n >= batch (n={n}, b={batch})");
        let mut rng = Pcg::derive(seed, &[streams::BATCH, node as u64]);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Batcher {
            order,
            cursor: 0,
            batch,
            rng,
            epoch: 0,
        }
    }

    /// Batches per epoch (floor; the tail wraps into the next epoch's
    /// shuffle so every sample is seen at equal long-run frequency).
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Fill `x`/`y` with the next minibatch from `data`.
    pub fn next_batch(&mut self, data: &Dataset, x: &mut [f32], y: &mut [i32]) {
        let slen = data.sample_len;
        assert_eq!(x.len(), self.batch * slen);
        assert_eq!(y.len(), self.batch);
        for b in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
                self.epoch += 1;
            }
            let i = self.order[self.cursor];
            self.cursor += 1;
            x[b * slen..(b + 1) * slen].copy_from_slice(data.sample(i));
            y[b] = data.y[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Generator, SyntheticSpec};

    fn tiny_dataset(n: usize) -> Dataset {
        let spec = SyntheticSpec::for_dataset("t", 4, 4, 1, 3, 1);
        let g = Generator::new(&spec);
        let mut rng = Pcg::new(2);
        g.generate(&[0, 1, 2], n, &mut rng)
    }

    #[test]
    fn batches_cover_dataset_each_epoch() {
        let data = tiny_dataset(12);
        let mut b = Batcher::new(12, 4, 5, 0);
        assert_eq!(b.batches_per_epoch(), 3);
        let mut seen = vec![0usize; 3];
        let mut x = vec![0.0; 4 * 16];
        let mut y = vec![0i32; 4];
        for _ in 0..3 {
            b.next_batch(&data, &mut x, &mut y);
            for &label in &y {
                seen[label as usize] += 1;
            }
        }
        // 12 samples, 4 per class.
        assert_eq!(seen, vec![4, 4, 4]);
        assert_eq!(b.epoch(), 0);
        b.next_batch(&data, &mut x, &mut y);
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn batch_contents_match_dataset() {
        let data = tiny_dataset(8);
        let mut b = Batcher::new(8, 2, 7, 1);
        let mut x = vec![0.0; 2 * 16];
        let mut y = vec![0i32; 2];
        b.next_batch(&data, &mut x, &mut y);
        // Find which sample the first row is — must match its label.
        let row = &x[0..16];
        let idx = (0..8).find(|&i| data.sample(i) == row).expect("in set");
        assert_eq!(data.y[idx], y[0]);
    }

    #[test]
    fn deterministic_per_node_seed() {
        let data = tiny_dataset(8);
        let mut b1 = Batcher::new(8, 4, 9, 3);
        let mut b2 = Batcher::new(8, 4, 9, 3);
        let mut b3 = Batcher::new(8, 4, 9, 4);
        let (mut x1, mut y1) = (vec![0.0; 64], vec![0i32; 4]);
        let (mut x2, mut y2) = (vec![0.0; 64], vec![0i32; 4]);
        let (mut x3, mut y3) = (vec![0.0; 64], vec![0i32; 4]);
        b1.next_batch(&data, &mut x1, &mut y1);
        b2.next_batch(&data, &mut x2, &mut y2);
        b3.next_batch(&data, &mut x3, &mut y3);
        assert_eq!(y1, y2);
        assert_eq!(x1, x2);
        assert_ne!(y1, y3); // different node, different shuffle (w.h.p.)
    }

    #[test]
    #[should_panic]
    fn batch_larger_than_dataset_rejected() {
        let _ = Batcher::new(3, 4, 0, 0);
    }
}
