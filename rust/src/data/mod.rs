//! Synthetic dataset substrate (DESIGN.md §2: FashionMNIST / CIFAR10
//! stand-ins for this offline sandbox).
//!
//! Each of the 10 classes is a fixed smooth random template (a low-res
//! Gaussian grid bilinearly upsampled per channel); a sample is the
//! template under random amplitude jitter, circular shift, and additive
//! pixel noise.  The paper's two data splits are reproduced exactly:
//!
//! * **homogeneous** — every node draws from all 10 classes, balanced;
//! * **heterogeneous** — every node draws from its own random 8-of-10
//!   class subset (paper §5.1), balanced within the subset, same total
//!   count per node;
//! * **dirichlet(α)** — each node's class *proportions* are a symmetric
//!   Dirichlet(α) draw (Hsu et al. 2019, the standard federated non-IID
//!   knob): α → ∞ recovers the homogeneous split, α → 0 approaches
//!   one-class-per-node.  Node sizes stay equal, per the paper.
//!
//! The class-conditional distributions are what drive the paper's
//! client-drift phenomenon, so this generator exercises the same code
//! paths and failure mode as the real datasets.

pub mod batcher;

pub use batcher::Batcher;

use crate::util::rng::{streams, Pcg};

/// Template grid resolution before upsampling.
const TEMPLATE_GRID: usize = 7;
/// Max circular shift (pixels) applied per sample.
const MAX_SHIFT: i32 = 4;
/// Additive pixel noise std (tuned so the task has headroom: single-node
/// SGD lands in the high-80s like the paper's FashionMNIST numbers, and
/// client drift is visible under the heterogeneous split).
const NOISE_STD: f32 = 1.8;
/// Amplitude jitter std around 1.0.
const AMP_STD: f32 = 0.35;

/// Generation parameters for one dataset scale.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub name: String,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub seed: u64,
}

impl SyntheticSpec {
    /// Shape-compatible stand-in for the named dataset config of the
    /// artifact manifest.
    pub fn for_dataset(name: &str, h: usize, w: usize, c: usize,
                       classes: usize, seed: u64) -> SyntheticSpec {
        SyntheticSpec {
            name: name.to_string(),
            height: h,
            width: w,
            channels: c,
            classes,
            seed,
        }
    }

    pub fn sample_len(&self) -> usize {
        self.height * self.width * self.channels
    }
}

/// The paper's two data splits (§5.1) plus the Dirichlet-α label-skew
/// axis used for the head-to-head against compressed gossip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    Homogeneous,
    /// Each node holds data of `classes_per_node` randomly selected
    /// classes (the paper uses 8 of 10).
    Heterogeneous { classes_per_node: usize },
    /// Each node's class proportions drawn from a symmetric
    /// Dirichlet(α); sample counts per node stay equal.
    Dirichlet { alpha: f64 },
}

/// The full `--heterogeneity` grammar, restated verbatim in every parse
/// error (same convention as `CODEC_GRAMMAR`).
pub const PARTITION_GRAMMAR: &str =
    "homogeneous | heterogeneous[:<classes_per_node>] | dirichlet:<alpha>, \
     with classes_per_node ≥ 1 and alpha a finite value > 0";

impl Partition {
    pub fn name(&self) -> String {
        match self {
            Partition::Homogeneous => "homogeneous".to_string(),
            Partition::Heterogeneous { classes_per_node } => {
                format!("heterogeneous({classes_per_node}/10)")
            }
            Partition::Dirichlet { alpha } => format!("dirichlet({alpha})"),
        }
    }

    /// Parse the `--heterogeneity` grammar (see [`PARTITION_GRAMMAR`]).
    /// Every error names the offending token and restates the grammar.
    pub fn parse(s: &str) -> Result<Partition, String> {
        let s = s.trim();
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match (head, arg) {
            ("homogeneous" | "homo" | "iid", None) => {
                Ok(Partition::Homogeneous)
            }
            ("heterogeneous" | "hetero", None) => {
                Ok(Partition::Heterogeneous { classes_per_node: 8 })
            }
            ("heterogeneous" | "hetero", Some(c)) => {
                let classes_per_node = c.parse::<usize>().map_err(|_| {
                    format!(
                        "`{s}`: `{c}` is not a class count \
                         (grammar: {PARTITION_GRAMMAR})"
                    )
                })?;
                if classes_per_node == 0 {
                    return Err(format!(
                        "`{s}`: classes_per_node must be ≥ 1 \
                         (grammar: {PARTITION_GRAMMAR})"
                    ));
                }
                Ok(Partition::Heterogeneous { classes_per_node })
            }
            ("dirichlet", Some(a)) => {
                let alpha = a.parse::<f64>().map_err(|_| {
                    format!(
                        "`{s}`: `{a}` is not an α value \
                         (grammar: {PARTITION_GRAMMAR})"
                    )
                })?;
                if !(alpha.is_finite() && alpha > 0.0) {
                    return Err(format!(
                        "`{s}`: α must be finite and > 0 \
                         (grammar: {PARTITION_GRAMMAR})"
                    ));
                }
                Ok(Partition::Dirichlet { alpha })
            }
            ("dirichlet", None) => Err(format!(
                "`{s}`: dirichlet needs an α value \
                 (grammar: {PARTITION_GRAMMAR})"
            )),
            _ => Err(format!(
                "unknown split `{head}` in `{s}` \
                 (grammar: {PARTITION_GRAMMAR})"
            )),
        }
    }
}

/// A labelled set of images, NHWC-flattened.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub sample_len: usize,
}

impl Dataset {
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.sample_len..(i + 1) * self.sample_len]
    }

    /// Class histogram.
    pub fn class_counts(&self, classes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; classes];
        for &y in &self.y {
            counts[y as usize] += 1;
        }
        counts
    }
}

/// Fixed per-class smooth templates. All nodes and the test set share the
/// same generator instance (same `spec.seed`), so train and test are
/// drawn from the same distribution.
pub struct Generator {
    spec: SyntheticSpec,
    /// `classes * channels * height * width` template pixels.
    templates: Vec<f32>,
}

impl Generator {
    pub fn new(spec: &SyntheticSpec) -> Generator {
        let mut templates =
            Vec::with_capacity(spec.classes * spec.sample_len());
        for class in 0..spec.classes {
            for ch in 0..spec.channels {
                let mut rng = Pcg::derive(
                    spec.seed,
                    &[streams::DATA, class as u64, ch as u64],
                );
                let grid: Vec<f32> = (0..TEMPLATE_GRID * TEMPLATE_GRID)
                    .map(|_| rng.normal_f32())
                    .collect();
                let plane = upsample_bilinear(
                    &grid,
                    TEMPLATE_GRID,
                    spec.height,
                    spec.width,
                );
                templates.extend(standardize(&plane));
            }
        }
        Generator {
            spec: spec.clone(),
            templates,
        }
    }

    fn template_plane(&self, class: usize, ch: usize) -> &[f32] {
        let hw = self.spec.height * self.spec.width;
        let base = (class * self.spec.channels + ch) * hw;
        &self.templates[base..base + hw]
    }

    /// Generate one sample of `class` into `out` (NHWC layout HWC here).
    pub fn sample_into(&self, class: usize, rng: &mut Pcg, out: &mut [f32]) {
        let (h, w, c) = (self.spec.height, self.spec.width, self.spec.channels);
        assert_eq!(out.len(), h * w * c);
        let amp = 1.0 + AMP_STD * rng.normal_f32();
        let dy = rng.below((2 * MAX_SHIFT + 1) as usize) as i32 - MAX_SHIFT;
        let dx = rng.below((2 * MAX_SHIFT + 1) as usize) as i32 - MAX_SHIFT;
        for ch in 0..c {
            let plane = self.template_plane(class, ch);
            for y in 0..h {
                let sy = (y as i32 - dy).rem_euclid(h as i32) as usize;
                for x in 0..w {
                    let sx = (x as i32 - dx).rem_euclid(w as i32) as usize;
                    let v = amp * plane[sy * w + sx]
                        + NOISE_STD * rng.normal_f32();
                    out[(y * w + x) * c + ch] = v;
                }
            }
        }
    }

    /// Balanced dataset over the given classes.
    pub fn generate(&self, classes: &[usize], n: usize, rng: &mut Pcg)
                    -> Dataset {
        let slen = self.spec.sample_len();
        let mut x = vec![0.0f32; n * slen];
        let mut y = Vec::with_capacity(n);
        // Balanced round-robin class schedule, shuffled.
        let mut schedule: Vec<usize> =
            (0..n).map(|i| classes[i % classes.len()]).collect();
        rng.shuffle(&mut schedule);
        for (i, &class) in schedule.iter().enumerate() {
            self.sample_into(class, rng, &mut x[i * slen..(i + 1) * slen]);
            y.push(class as i32);
        }
        Dataset {
            x,
            y,
            n,
            sample_len: slen,
        }
    }

    /// Dataset with an explicit per-class sample count (`counts[c]`
    /// samples of class `c`), shuffled with the same schedule idiom as
    /// [`Generator::generate`].
    pub fn generate_counts(&self, counts: &[usize], rng: &mut Pcg)
                           -> Dataset {
        assert_eq!(counts.len(), self.spec.classes);
        let n: usize = counts.iter().sum();
        let slen = self.spec.sample_len();
        let mut x = vec![0.0f32; n * slen];
        let mut y = Vec::with_capacity(n);
        let mut schedule = Vec::with_capacity(n);
        for (class, &count) in counts.iter().enumerate() {
            schedule.extend(std::iter::repeat(class).take(count));
        }
        rng.shuffle(&mut schedule);
        for (i, &class) in schedule.iter().enumerate() {
            self.sample_into(class, rng, &mut x[i * slen..(i + 1) * slen]);
            y.push(class as i32);
        }
        Dataset {
            x,
            y,
            n,
            sample_len: slen,
        }
    }
}

/// Per-node class subsets for a partition.
pub fn node_classes(partition: Partition, nodes: usize, classes: usize,
                    seed: u64) -> Vec<Vec<usize>> {
    match partition {
        // Dirichlet has full nominal support on every node — the skew
        // lives in the counts ([`dirichlet_class_counts`]), not the
        // support set.
        Partition::Homogeneous | Partition::Dirichlet { .. } => {
            vec![(0..classes).collect(); nodes]
        }
        Partition::Heterogeneous { classes_per_node } => {
            assert!(classes_per_node <= classes);
            (0..nodes)
                .map(|i| {
                    let mut rng = Pcg::derive(
                        seed,
                        &[streams::PARTITION, i as u64],
                    );
                    let mut picked =
                        rng.sample_indices(classes, classes_per_node);
                    picked.sort_unstable();
                    picked
                })
                .collect()
        }
    }
}

/// Per-node per-class sample counts for the Dirichlet(α) split: node
/// `i` draws class proportions from `Pcg::derive(seed, [PARTITION, i])`
/// and the proportions are apportioned over exactly `train_per_node`
/// samples by largest remainder, so node sizes stay equal (the paper's
/// constraint) while label marginals skew with α.
pub fn dirichlet_class_counts(
    nodes: usize,
    classes: usize,
    train_per_node: usize,
    alpha: f64,
    seed: u64,
) -> Vec<Vec<usize>> {
    (0..nodes)
        .map(|i| {
            let mut rng =
                Pcg::derive(seed, &[streams::PARTITION, i as u64]);
            let p = rng.dirichlet(alpha, classes);
            apportion(&p, train_per_node)
        })
        .collect()
}

/// Largest-remainder apportionment of `n` units over proportions `p`
/// (sums to exactly `n`; ties broken by class index, deterministic).
fn apportion(p: &[f64], n: usize) -> Vec<usize> {
    let mut counts: Vec<usize> =
        p.iter().map(|&q| (q * n as f64).floor() as usize).collect();
    let assigned: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..p.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = p[a] * n as f64 - (p[a] * n as f64).floor();
        let fb = p[b] * n as f64 - (p[b] * n as f64).floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &c in order.iter().take(n.saturating_sub(assigned)) {
        counts[c] += 1;
    }
    counts
}

/// Label-skew statistic for a per-node class-count matrix: the mean,
/// over nodes, of the largest single-class share.  1/classes for a
/// perfectly balanced split, → 1 as nodes collapse onto one class.
pub fn label_skew(counts: &[Vec<usize>]) -> f64 {
    assert!(!counts.is_empty());
    counts
        .iter()
        .map(|c| {
            let total: usize = c.iter().sum();
            let max = c.iter().copied().max().unwrap_or(0);
            max as f64 / total.max(1) as f64
        })
        .sum::<f64>()
        / counts.len() as f64
}

/// Build the full experiment data: per-node training sets (equal size,
/// per the paper) plus a shared balanced test set.
pub fn build_node_datasets(
    spec: &SyntheticSpec,
    partition: Partition,
    nodes: usize,
    train_per_node: usize,
    test_size: usize,
) -> (Vec<Dataset>, Dataset) {
    let generator = Generator::new(spec);
    let mut trains = Vec::with_capacity(nodes);
    match partition {
        // Count-based split: the class schedule comes from the
        // partition stream, sampling stays on the per-node data stream
        // (so homogeneous/heterogeneous trajectories are untouched).
        Partition::Dirichlet { alpha } => {
            let counts = dirichlet_class_counts(
                nodes,
                spec.classes,
                train_per_node,
                alpha,
                spec.seed,
            );
            for (i, c) in counts.iter().enumerate() {
                let mut rng = Pcg::derive(
                    spec.seed,
                    &[streams::DATA, 1000 + i as u64],
                );
                trains.push(generator.generate_counts(c, &mut rng));
            }
        }
        _ => {
            let class_sets =
                node_classes(partition, nodes, spec.classes, spec.seed);
            for (i, classes) in class_sets.iter().enumerate() {
                let mut rng = Pcg::derive(
                    spec.seed,
                    &[streams::DATA, 1000 + i as u64],
                );
                trains.push(
                    generator.generate(classes, train_per_node, &mut rng),
                );
            }
        }
    }
    let mut test_rng = Pcg::derive(spec.seed, &[streams::DATA, 9999]);
    let all: Vec<usize> = (0..spec.classes).collect();
    let test = generator.generate(&all, test_size, &mut test_rng);
    (trains, test)
}

// --------------------------------------------------------------------------

fn upsample_bilinear(grid: &[f32], g: usize, h: usize, w: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; h * w];
    for y in 0..h {
        let fy = y as f32 / (h - 1).max(1) as f32 * (g - 1) as f32;
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(g - 1);
        let ty = fy - y0 as f32;
        for x in 0..w {
            let fx = x as f32 / (w - 1).max(1) as f32 * (g - 1) as f32;
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(g - 1);
            let tx = fx - x0 as f32;
            let v00 = grid[y0 * g + x0];
            let v01 = grid[y0 * g + x1];
            let v10 = grid[y1 * g + x0];
            let v11 = grid[y1 * g + x1];
            out[y * w + x] = v00 * (1.0 - ty) * (1.0 - tx)
                + v01 * (1.0 - ty) * tx
                + v10 * ty * (1.0 - tx)
                + v11 * ty * tx;
        }
    }
    out
}

fn standardize(v: &[f32]) -> Vec<f32> {
    let n = v.len() as f32;
    let mean: f32 = v.iter().sum::<f32>() / n;
    let var: f32 =
        v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    v.iter().map(|x| (x - mean) / std).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SyntheticSpec {
        SyntheticSpec::for_dataset("fashion", 28, 28, 1, 10, 42)
    }

    #[test]
    fn templates_deterministic() {
        let g1 = Generator::new(&spec());
        let g2 = Generator::new(&spec());
        assert_eq!(g1.templates, g2.templates);
        let mut other = spec();
        other.seed = 43;
        let g3 = Generator::new(&other);
        assert_ne!(g1.templates, g3.templates);
    }

    #[test]
    fn templates_standardized_and_distinct() {
        let g = Generator::new(&spec());
        for c in 0..10 {
            let p = g.template_plane(c, 0);
            let mean: f32 = p.iter().sum::<f32>() / p.len() as f32;
            assert!(mean.abs() < 1e-4);
        }
        // Distinct classes must have visibly different templates.
        let a = g.template_plane(0, 0);
        let b = g.template_plane(1, 0);
        let diff: f32 =
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(diff > 10.0);
    }

    #[test]
    fn generate_balanced_classes() {
        let g = Generator::new(&spec());
        let mut rng = Pcg::new(1);
        let ds = g.generate(&[0, 3, 5], 300, &mut rng);
        let counts = ds.class_counts(10);
        assert_eq!(counts[0], 100);
        assert_eq!(counts[3], 100);
        assert_eq!(counts[5], 100);
        assert_eq!(counts.iter().sum::<usize>(), 300);
    }

    #[test]
    fn heterogeneous_assignment_shape() {
        let sets = node_classes(
            Partition::Heterogeneous { classes_per_node: 8 },
            8,
            10,
            7,
        );
        assert_eq!(sets.len(), 8);
        for s in &sets {
            assert_eq!(s.len(), 8);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&c| c < 10));
        }
        // Not all nodes identical (overwhelmingly likely with seed 7).
        assert!(sets.iter().any(|s| s != &sets[0]));
    }

    #[test]
    fn homogeneous_assignment_is_full() {
        let sets = node_classes(Partition::Homogeneous, 4, 10, 1);
        for s in sets {
            assert_eq!(s, (0..10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn node_datasets_equal_size_and_test_balanced() {
        let (trains, test) = build_node_datasets(
            &spec(),
            Partition::Heterogeneous { classes_per_node: 8 },
            4,
            120,
            200,
        );
        assert_eq!(trains.len(), 4);
        for t in &trains {
            assert_eq!(t.n, 120);
            // Only 8 distinct classes present.
            let nonzero =
                t.class_counts(10).iter().filter(|&&c| c > 0).count();
            assert_eq!(nonzero, 8);
        }
        assert_eq!(test.n, 200);
        let counts = test.class_counts(10);
        assert!(counts.iter().all(|&c| c == 20));
    }

    #[test]
    fn partition_parse_grammar() {
        assert_eq!(Partition::parse("homogeneous"),
                   Ok(Partition::Homogeneous));
        assert_eq!(Partition::parse("iid"), Ok(Partition::Homogeneous));
        assert_eq!(Partition::parse("hetero"),
                   Ok(Partition::Heterogeneous { classes_per_node: 8 }));
        assert_eq!(Partition::parse("heterogeneous:3"),
                   Ok(Partition::Heterogeneous { classes_per_node: 3 }));
        assert_eq!(Partition::parse("dirichlet:0.1"),
                   Ok(Partition::Dirichlet { alpha: 0.1 }));
        for bad in ["dirichlet", "dirichlet:x", "dirichlet:0",
                    "dirichlet:-1", "dirichlet:inf", "hetero:0",
                    "hetero:x", "gaussian:1"] {
            let err = Partition::parse(bad).unwrap_err();
            assert!(err.contains("grammar"), "`{bad}` -> {err}");
        }
    }

    #[test]
    fn apportion_sums_exactly_and_follows_proportions() {
        let counts = apportion(&[0.5, 0.3, 0.2], 10);
        assert_eq!(counts, vec![5, 3, 2]);
        // Fractional quotas: total still exact.
        let counts = apportion(&[1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0], 10);
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().all(|&c| (3..=4).contains(&c)));
        // A point mass keeps everything on one class.
        let counts = apportion(&[0.0, 1.0, 0.0], 7);
        assert_eq!(counts, vec![0, 7, 0]);
    }

    #[test]
    fn dirichlet_counts_equal_node_sizes_and_determinism() {
        let a = dirichlet_class_counts(16, 10, 120, 0.1, 42);
        let b = dirichlet_class_counts(16, 10, 120, 0.1, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        for c in &a {
            assert_eq!(c.iter().sum::<usize>(), 120);
        }
        // A different seed reshuffles the skew.
        let c = dirichlet_class_counts(16, 10, 120, 0.1, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn dirichlet_alpha_limits() {
        // α → large recovers the homogeneous split (≈ n/C per class).
        let big = dirichlet_class_counts(8, 10, 200, 1e6, 7);
        for node in &big {
            for &c in node {
                assert!((19..=21).contains(&c), "α=1e6 counts {node:?}");
            }
        }
        assert!((label_skew(&big) - 0.1).abs() < 0.01);
        // α = 0.1 skews hard; homogeneous baseline sits at 1/C = 0.1.
        let skewed = dirichlet_class_counts(8, 10, 200, 0.1, 7);
        assert!(label_skew(&skewed) > 0.35,
                "α=0.1 skew {}", label_skew(&skewed));
    }

    #[test]
    fn dirichlet_datasets_assign_every_sample_exactly_once() {
        let (trains, test) = build_node_datasets(
            &spec(),
            Partition::Dirichlet { alpha: 0.1 },
            4,
            60,
            100,
        );
        assert_eq!(trains.len(), 4);
        let counts = dirichlet_class_counts(4, 10, 60, 0.1, spec().seed);
        for (t, c) in trains.iter().zip(&counts) {
            assert_eq!(t.n, 60);
            assert_eq!(t.y.len(), 60);
            assert_eq!(t.x.len(), 60 * t.sample_len);
            // The emitted labels realize exactly the drawn counts.
            assert_eq!(&t.class_counts(10), c);
        }
        // Test set stays balanced regardless of the training split.
        assert!(test.class_counts(10).iter().all(|&c| c == 10));
    }

    #[test]
    fn samples_have_signal_and_noise() {
        let g = Generator::new(&spec());
        let mut rng = Pcg::new(3);
        let slen = spec().sample_len();
        let mut a = vec![0.0f32; slen];
        let mut b = vec![0.0f32; slen];
        g.sample_into(2, &mut rng, &mut a);
        g.sample_into(2, &mut rng, &mut b);
        // Same class, different draws: correlated but not identical.
        assert_ne!(a, b);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let cos = dot / (na * nb);
        assert!(cos > 0.3, "same-class cosine {cos}");
    }

    #[test]
    fn cross_class_samples_less_similar() {
        let g = Generator::new(&spec());
        let mut rng = Pcg::new(4);
        let slen = spec().sample_len();
        let mut a = vec![0.0f32; slen];
        let mut b = vec![0.0f32; slen];
        let mut cos_same = 0.0;
        let mut cos_diff = 0.0;
        for trial in 0..10 {
            g.sample_into(1, &mut rng, &mut a);
            g.sample_into(if trial % 2 == 0 { 1 } else { 6 }, &mut rng, &mut b);
            let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            if trial % 2 == 0 {
                cos_same += dot / (na * nb);
            } else {
                cos_diff += dot / (na * nb);
            }
        }
        assert!(cos_same > cos_diff, "{cos_same} vs {cos_diff}");
    }
}
