//! Synthetic dataset substrate (DESIGN.md §2: FashionMNIST / CIFAR10
//! stand-ins for this offline sandbox).
//!
//! Each of the 10 classes is a fixed smooth random template (a low-res
//! Gaussian grid bilinearly upsampled per channel); a sample is the
//! template under random amplitude jitter, circular shift, and additive
//! pixel noise.  The paper's two data splits are reproduced exactly:
//!
//! * **homogeneous** — every node draws from all 10 classes, balanced;
//! * **heterogeneous** — every node draws from its own random 8-of-10
//!   class subset (paper §5.1), balanced within the subset, same total
//!   count per node.
//!
//! The class-conditional distributions are what drive the paper's
//! client-drift phenomenon, so this generator exercises the same code
//! paths and failure mode as the real datasets.

pub mod batcher;

pub use batcher::Batcher;

use crate::util::rng::{streams, Pcg};

/// Template grid resolution before upsampling.
const TEMPLATE_GRID: usize = 7;
/// Max circular shift (pixels) applied per sample.
const MAX_SHIFT: i32 = 4;
/// Additive pixel noise std (tuned so the task has headroom: single-node
/// SGD lands in the high-80s like the paper's FashionMNIST numbers, and
/// client drift is visible under the heterogeneous split).
const NOISE_STD: f32 = 1.8;
/// Amplitude jitter std around 1.0.
const AMP_STD: f32 = 0.35;

/// Generation parameters for one dataset scale.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub name: String,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub seed: u64,
}

impl SyntheticSpec {
    /// Shape-compatible stand-in for the named dataset config of the
    /// artifact manifest.
    pub fn for_dataset(name: &str, h: usize, w: usize, c: usize,
                       classes: usize, seed: u64) -> SyntheticSpec {
        SyntheticSpec {
            name: name.to_string(),
            height: h,
            width: w,
            channels: c,
            classes,
            seed,
        }
    }

    pub fn sample_len(&self) -> usize {
        self.height * self.width * self.channels
    }
}

/// The paper's two data splits (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    Homogeneous,
    /// Each node holds data of `classes_per_node` randomly selected
    /// classes (the paper uses 8 of 10).
    Heterogeneous { classes_per_node: usize },
}

impl Partition {
    pub fn name(&self) -> String {
        match self {
            Partition::Homogeneous => "homogeneous".to_string(),
            Partition::Heterogeneous { classes_per_node } => {
                format!("heterogeneous({classes_per_node}/10)")
            }
        }
    }
}

/// A labelled set of images, NHWC-flattened.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub sample_len: usize,
}

impl Dataset {
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.sample_len..(i + 1) * self.sample_len]
    }

    /// Class histogram.
    pub fn class_counts(&self, classes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; classes];
        for &y in &self.y {
            counts[y as usize] += 1;
        }
        counts
    }
}

/// Fixed per-class smooth templates. All nodes and the test set share the
/// same generator instance (same `spec.seed`), so train and test are
/// drawn from the same distribution.
pub struct Generator {
    spec: SyntheticSpec,
    /// `classes * channels * height * width` template pixels.
    templates: Vec<f32>,
}

impl Generator {
    pub fn new(spec: &SyntheticSpec) -> Generator {
        let mut templates =
            Vec::with_capacity(spec.classes * spec.sample_len());
        for class in 0..spec.classes {
            for ch in 0..spec.channels {
                let mut rng = Pcg::derive(
                    spec.seed,
                    &[streams::DATA, class as u64, ch as u64],
                );
                let grid: Vec<f32> = (0..TEMPLATE_GRID * TEMPLATE_GRID)
                    .map(|_| rng.normal_f32())
                    .collect();
                let plane = upsample_bilinear(
                    &grid,
                    TEMPLATE_GRID,
                    spec.height,
                    spec.width,
                );
                templates.extend(standardize(&plane));
            }
        }
        Generator {
            spec: spec.clone(),
            templates,
        }
    }

    fn template_plane(&self, class: usize, ch: usize) -> &[f32] {
        let hw = self.spec.height * self.spec.width;
        let base = (class * self.spec.channels + ch) * hw;
        &self.templates[base..base + hw]
    }

    /// Generate one sample of `class` into `out` (NHWC layout HWC here).
    pub fn sample_into(&self, class: usize, rng: &mut Pcg, out: &mut [f32]) {
        let (h, w, c) = (self.spec.height, self.spec.width, self.spec.channels);
        assert_eq!(out.len(), h * w * c);
        let amp = 1.0 + AMP_STD * rng.normal_f32();
        let dy = rng.below((2 * MAX_SHIFT + 1) as usize) as i32 - MAX_SHIFT;
        let dx = rng.below((2 * MAX_SHIFT + 1) as usize) as i32 - MAX_SHIFT;
        for ch in 0..c {
            let plane = self.template_plane(class, ch);
            for y in 0..h {
                let sy = (y as i32 - dy).rem_euclid(h as i32) as usize;
                for x in 0..w {
                    let sx = (x as i32 - dx).rem_euclid(w as i32) as usize;
                    let v = amp * plane[sy * w + sx]
                        + NOISE_STD * rng.normal_f32();
                    out[(y * w + x) * c + ch] = v;
                }
            }
        }
    }

    /// Balanced dataset over the given classes.
    pub fn generate(&self, classes: &[usize], n: usize, rng: &mut Pcg)
                    -> Dataset {
        let slen = self.spec.sample_len();
        let mut x = vec![0.0f32; n * slen];
        let mut y = Vec::with_capacity(n);
        // Balanced round-robin class schedule, shuffled.
        let mut schedule: Vec<usize> =
            (0..n).map(|i| classes[i % classes.len()]).collect();
        rng.shuffle(&mut schedule);
        for (i, &class) in schedule.iter().enumerate() {
            self.sample_into(class, rng, &mut x[i * slen..(i + 1) * slen]);
            y.push(class as i32);
        }
        Dataset {
            x,
            y,
            n,
            sample_len: slen,
        }
    }
}

/// Per-node class subsets for a partition.
pub fn node_classes(partition: Partition, nodes: usize, classes: usize,
                    seed: u64) -> Vec<Vec<usize>> {
    match partition {
        Partition::Homogeneous => {
            vec![(0..classes).collect(); nodes]
        }
        Partition::Heterogeneous { classes_per_node } => {
            assert!(classes_per_node <= classes);
            (0..nodes)
                .map(|i| {
                    let mut rng = Pcg::derive(
                        seed,
                        &[streams::PARTITION, i as u64],
                    );
                    let mut picked =
                        rng.sample_indices(classes, classes_per_node);
                    picked.sort_unstable();
                    picked
                })
                .collect()
        }
    }
}

/// Build the full experiment data: per-node training sets (equal size,
/// per the paper) plus a shared balanced test set.
pub fn build_node_datasets(
    spec: &SyntheticSpec,
    partition: Partition,
    nodes: usize,
    train_per_node: usize,
    test_size: usize,
) -> (Vec<Dataset>, Dataset) {
    let generator = Generator::new(spec);
    let class_sets = node_classes(partition, nodes, spec.classes, spec.seed);
    let mut trains = Vec::with_capacity(nodes);
    for (i, classes) in class_sets.iter().enumerate() {
        let mut rng = Pcg::derive(
            spec.seed,
            &[streams::DATA, 1000 + i as u64],
        );
        trains.push(generator.generate(classes, train_per_node, &mut rng));
    }
    let mut test_rng = Pcg::derive(spec.seed, &[streams::DATA, 9999]);
    let all: Vec<usize> = (0..spec.classes).collect();
    let test = generator.generate(&all, test_size, &mut test_rng);
    (trains, test)
}

// --------------------------------------------------------------------------

fn upsample_bilinear(grid: &[f32], g: usize, h: usize, w: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; h * w];
    for y in 0..h {
        let fy = y as f32 / (h - 1).max(1) as f32 * (g - 1) as f32;
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(g - 1);
        let ty = fy - y0 as f32;
        for x in 0..w {
            let fx = x as f32 / (w - 1).max(1) as f32 * (g - 1) as f32;
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(g - 1);
            let tx = fx - x0 as f32;
            let v00 = grid[y0 * g + x0];
            let v01 = grid[y0 * g + x1];
            let v10 = grid[y1 * g + x0];
            let v11 = grid[y1 * g + x1];
            out[y * w + x] = v00 * (1.0 - ty) * (1.0 - tx)
                + v01 * (1.0 - ty) * tx
                + v10 * ty * (1.0 - tx)
                + v11 * ty * tx;
        }
    }
    out
}

fn standardize(v: &[f32]) -> Vec<f32> {
    let n = v.len() as f32;
    let mean: f32 = v.iter().sum::<f32>() / n;
    let var: f32 =
        v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    v.iter().map(|x| (x - mean) / std).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SyntheticSpec {
        SyntheticSpec::for_dataset("fashion", 28, 28, 1, 10, 42)
    }

    #[test]
    fn templates_deterministic() {
        let g1 = Generator::new(&spec());
        let g2 = Generator::new(&spec());
        assert_eq!(g1.templates, g2.templates);
        let mut other = spec();
        other.seed = 43;
        let g3 = Generator::new(&other);
        assert_ne!(g1.templates, g3.templates);
    }

    #[test]
    fn templates_standardized_and_distinct() {
        let g = Generator::new(&spec());
        for c in 0..10 {
            let p = g.template_plane(c, 0);
            let mean: f32 = p.iter().sum::<f32>() / p.len() as f32;
            assert!(mean.abs() < 1e-4);
        }
        // Distinct classes must have visibly different templates.
        let a = g.template_plane(0, 0);
        let b = g.template_plane(1, 0);
        let diff: f32 =
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(diff > 10.0);
    }

    #[test]
    fn generate_balanced_classes() {
        let g = Generator::new(&spec());
        let mut rng = Pcg::new(1);
        let ds = g.generate(&[0, 3, 5], 300, &mut rng);
        let counts = ds.class_counts(10);
        assert_eq!(counts[0], 100);
        assert_eq!(counts[3], 100);
        assert_eq!(counts[5], 100);
        assert_eq!(counts.iter().sum::<usize>(), 300);
    }

    #[test]
    fn heterogeneous_assignment_shape() {
        let sets = node_classes(
            Partition::Heterogeneous { classes_per_node: 8 },
            8,
            10,
            7,
        );
        assert_eq!(sets.len(), 8);
        for s in &sets {
            assert_eq!(s.len(), 8);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&c| c < 10));
        }
        // Not all nodes identical (overwhelmingly likely with seed 7).
        assert!(sets.iter().any(|s| s != &sets[0]));
    }

    #[test]
    fn homogeneous_assignment_is_full() {
        let sets = node_classes(Partition::Homogeneous, 4, 10, 1);
        for s in sets {
            assert_eq!(s, (0..10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn node_datasets_equal_size_and_test_balanced() {
        let (trains, test) = build_node_datasets(
            &spec(),
            Partition::Heterogeneous { classes_per_node: 8 },
            4,
            120,
            200,
        );
        assert_eq!(trains.len(), 4);
        for t in &trains {
            assert_eq!(t.n, 120);
            // Only 8 distinct classes present.
            let nonzero =
                t.class_counts(10).iter().filter(|&&c| c > 0).count();
            assert_eq!(nonzero, 8);
        }
        assert_eq!(test.n, 200);
        let counts = test.class_counts(10);
        assert!(counts.iter().all(|&c| c == 20));
    }

    #[test]
    fn samples_have_signal_and_noise() {
        let g = Generator::new(&spec());
        let mut rng = Pcg::new(3);
        let slen = spec().sample_len();
        let mut a = vec![0.0f32; slen];
        let mut b = vec![0.0f32; slen];
        g.sample_into(2, &mut rng, &mut a);
        g.sample_into(2, &mut rng, &mut b);
        // Same class, different draws: correlated but not identical.
        assert_ne!(a, b);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let cos = dot / (na * nb);
        assert!(cos > 0.3, "same-class cosine {cos}");
    }

    #[test]
    fn cross_class_samples_less_similar() {
        let g = Generator::new(&spec());
        let mut rng = Pcg::new(4);
        let slen = spec().sample_len();
        let mut a = vec![0.0f32; slen];
        let mut b = vec![0.0f32; slen];
        let mut cos_same = 0.0;
        let mut cos_diff = 0.0;
        for trial in 0..10 {
            g.sample_into(1, &mut rng, &mut a);
            g.sample_into(if trial % 2 == 0 { 1 } else { 6 }, &mut rng, &mut b);
            let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            if trial % 2 == 0 {
                cos_same += dot / (na * nb);
            } else {
                cos_diff += dot / (na * nb);
            }
        }
        assert!(cos_same > cos_diff, "{cos_same} vs {cos_diff}");
    }
}
