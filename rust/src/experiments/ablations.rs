//! Ablations the paper calls out in prose:
//!
//! * **naive** (§3.2) — compressing y directly (Eq. 11) vs compressing
//!   the update y − z (Eq. 13), on both the convex substrate and the CNN.
//! * **warmup** (§5.1) — the first-epoch dense (k = 100%) trick on/off.
//! * **wire** — the rand-k codec's two wire modes: explicit COO
//!   (idx+val, the paper's accounting) vs values-only (shared-seed
//!   masks make indices redundant), analytic via
//!   `CodecSpec::nominal_frame_bytes`.

use anyhow::Result;

use crate::algorithms::AlgorithmSpec;
use crate::compress::{CodecSpec, WireMode};
use crate::coordinator::run_with_engine;
use crate::data::Partition;
use crate::graph::Graph;
use crate::model::Manifest;
use crate::quadratic::{run_cecl, DualRule, QuadraticNetwork};
use crate::runtime::Engine;
use crate::util::stats::empirical_rate;
use crate::util::table::Table;

use super::{results_dir, Sizing};

/// Eq. (11) vs Eq. (13) — quadratic rates plus CNN accuracy.
pub fn run_naive_ablation(
    engine: &Engine,
    manifest: &Manifest,
    sizing: &Sizing,
) -> Result<Table> {
    let mut t = Table::new(["setting", "rule", "metric", "value"]);

    // Convex part.
    let graph = Graph::ring(8);
    let net = QuadraticNetwork::random(8, 24, 40, 0.5, 0.5, sizing.seed);
    let alpha = net
        .best_alpha(&graph)
        .ok_or_else(|| anyhow::anyhow!("ablation needs a non-empty graph"))?;
    for (rule, name) in [
        (DualRule::CompressDiff, "Eq.13 comp(y-z)"),
        (DualRule::CompressY, "Eq.11 comp(y)"),
    ] {
        let errors =
            run_cecl(&net, &graph, alpha, 1.0, 0.5, 200, sizing.seed, rule);
        t.row([
            "quadratic k=50%".to_string(),
            name.to_string(),
            "rate".to_string(),
            format!("{:.4}", empirical_rate(&errors[40..])),
        ]);
        t.row([
            "quadratic k=50%".to_string(),
            name.to_string(),
            "final error".to_string(),
            format!("{:.3e}", errors.last().unwrap()),
        ]);
    }

    // CNN part.
    let ds = sizing.datasets.first().cloned().unwrap_or("fashion".into());
    let graph = Graph::ring(sizing.nodes);
    for (alg, name) in [
        (
            AlgorithmSpec::CEcl { k_frac: 0.1, theta: 1.0, dense_first_epoch: false },
            "Eq.13 comp(y-z)",
        ),
        (
            AlgorithmSpec::NaiveCEcl { k_frac: 0.1, theta: 1.0 },
            "Eq.11 comp(y)",
        ),
    ] {
        let mut spec = sizing.spec_base(&ds, Partition::Homogeneous);
        spec.algorithm = alg;
        eprintln!("[ablation-naive] {} ...", name);
        let report = run_with_engine(engine, manifest, &spec, &graph)?;
        t.row([
            format!("cnn {ds} k=10%"),
            name.to_string(),
            "best accuracy".to_string(),
            format!("{:.3}", report.best_accuracy),
        ]);
    }
    t.write_csv(results_dir().join("ablation_naive.csv"))?;
    Ok(t)
}

/// First-epoch dense warmup on/off (paper §5.1).
pub fn run_warmup_ablation(
    engine: &Engine,
    manifest: &Manifest,
    sizing: &Sizing,
) -> Result<Table> {
    let ds = sizing.datasets.first().cloned().unwrap_or("fashion".into());
    let graph = Graph::ring(sizing.nodes);
    let mut t = Table::new(["warmup", "k%", "best acc", "final acc",
                            "send/epoch KB"]);
    for k_frac in [0.01, 0.1] {
        for warmup in [true, false] {
            let mut spec = sizing.spec_base(&ds, Partition::Homogeneous);
            spec.algorithm = AlgorithmSpec::CEcl {
                k_frac,
                theta: 1.0,
                dense_first_epoch: warmup,
            };
            eprintln!("[ablation-warmup] k={k_frac} warmup={warmup} ...");
            let report = run_with_engine(engine, manifest, &spec, &graph)?;
            t.row([
                warmup.to_string(),
                format!("{}", (k_frac * 100.0) as u32),
                format!("{:.3}", report.best_accuracy),
                format!("{:.3}", report.final_accuracy),
                format!("{:.0}", report.mean_bytes_per_epoch / 1024.0),
            ]);
        }
    }
    t.write_csv(results_dir().join("ablation_warmup.csv"))?;
    Ok(t)
}

/// Client-drift stress regime: sweep heterogeneity strength
/// (classes-per-node 10 → 8 → 4) and show the paper's headline ordering
/// emerge as drift grows — D-PSGD degrades, the primal-dual methods
/// hold.  (At the paper's 8-of-10 with our shortened horizon the gap is
/// small; at 4-of-10 it is unambiguous.  See EXPERIMENTS.md §T2.)
pub fn run_drift_ablation(
    engine: &Engine,
    manifest: &Manifest,
    sizing: &Sizing,
) -> Result<Table> {
    let ds = sizing.datasets.first().cloned().unwrap_or("fashion".into());
    let graph = Graph::ring(sizing.nodes);
    let methods = [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::CEcl { k_frac: 0.2, theta: 1.0, dense_first_epoch: true },
    ];
    let mut t = Table::new(["classes/node", "method", "best acc"]);
    for classes_per_node in [10usize, 8, 4] {
        let partition = if classes_per_node == 10 {
            Partition::Homogeneous
        } else {
            Partition::Heterogeneous { classes_per_node }
        };
        for alg in &methods {
            let mut spec = sizing.spec_base(&ds, partition);
            spec.algorithm = alg.clone();
            eprintln!("[ablation-drift] {}/{} ...", classes_per_node, alg.name());
            let report = run_with_engine(engine, manifest, &spec, &graph)?;
            t.row([
                classes_per_node.to_string(),
                alg.name(),
                format!("{:.3}", report.best_accuracy),
            ]);
        }
    }
    t.write_csv(results_dir().join("ablation_drift.csv"))?;
    Ok(t)
}

/// Wire-format accounting: the rand-k codec's explicit-index mode (the
/// paper's COO accounting) vs its values-only mode (the shared seed
/// makes indices redundant). Pure accounting through
/// `CodecSpec::nominal_frame_bytes` — no training.
pub fn run_wire_ablation(manifest: &Manifest, sizing: &Sizing) -> Result<Table> {
    let mut t = Table::new([
        "dataset", "k%", "dense KB", "coo KB (paper)", "values-only KB",
        "coo ratio", "values-only ratio",
    ]);
    for ds_name in &sizing.datasets {
        let ds = manifest.dataset(ds_name)?;
        let dense =
            CodecSpec::Identity.nominal_frame_bytes(ds.d_pad) as f64 / 1024.0;
        for k in [0.01, 0.1, 0.2] {
            let coo = CodecSpec::RandK { k_frac: k, mode: WireMode::Explicit }
                .nominal_frame_bytes(ds.d_pad) as f64
                / 1024.0;
            let vals = CodecSpec::RandK { k_frac: k, mode: WireMode::ValuesOnly }
                .nominal_frame_bytes(ds.d_pad) as f64
                / 1024.0;
            t.row([
                ds_name.clone(),
                format!("{}", (k * 100.0) as u32),
                format!("{dense:.0}"),
                format!("{coo:.0}"),
                format!("{vals:.0}"),
                format!("x{:.1}", dense / coo),
                format!("x{:.1}", dense / vals),
            ]);
        }
    }
    t.write_csv(results_dir().join("ablation_wire.csv"))?;
    Ok(t)
}
