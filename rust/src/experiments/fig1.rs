//! Figure 1: test-accuracy curves for the four topologies under both
//! data splits.  Emits one CSV per (topology, partition) with an
//! `epoch` column plus one column per method — ready to plot.

use anyhow::Result;

use crate::algorithms::AlgorithmSpec;
use crate::coordinator::run_with_engine;
use crate::data::Partition;
use crate::graph::{Graph, Topology};
use crate::model::Manifest;
use crate::runtime::Engine;
use crate::util::table::Table;

use super::{results_dir, Sizing};

/// The figure's method set (paper Fig. 1 legend).
pub fn figure_methods() -> Vec<AlgorithmSpec> {
    vec![
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 10 },
        AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: true },
    ]
}

/// Run the full figure (or a subset of topologies). Returns the list of
/// CSV paths written.
pub fn run_fig1(
    engine: &Engine,
    manifest: &Manifest,
    sizing: &Sizing,
    topologies: &[Topology],
) -> Result<Vec<std::path::PathBuf>> {
    let ds = sizing
        .datasets
        .first()
        .cloned()
        .unwrap_or_else(|| "fashion".to_string());
    let methods = figure_methods();
    let partitions = [
        Partition::Homogeneous,
        Partition::Heterogeneous { classes_per_node: 8 },
    ];
    let mut written = Vec::new();
    for &topology in topologies {
        let graph = Graph::build(topology, sizing.nodes);
        for partition in partitions {
            let mut series: Vec<Vec<(usize, f64)>> = Vec::new();
            for alg in &methods {
                let mut spec = sizing.spec_base(&ds, partition);
                spec.algorithm = alg.clone();
                eprintln!(
                    "[fig1] {} / {} / {} ...",
                    topology.name(),
                    partition.name(),
                    alg.name()
                );
                let report = run_with_engine(engine, manifest, &spec, &graph)?;
                series.push(report.history.accuracy_series());
            }
            // Assemble: all series share the eval schedule.
            let epochs: Vec<usize> =
                series[0].iter().map(|&(e, _)| e).collect();
            let mut headers = vec!["epoch".to_string()];
            headers.extend(methods.iter().map(|m| m.name()));
            let mut t = Table::new(headers);
            for (row_i, &epoch) in epochs.iter().enumerate() {
                let mut row = vec![epoch.to_string()];
                for s in &series {
                    row.push(format!("{:.4}", s[row_i].1));
                }
                t.row(row);
            }
            let path = results_dir().join(format!(
                "fig1_{}_{}.csv",
                topology.name(),
                if partition == Partition::Homogeneous {
                    "homogeneous"
                } else {
                    "heterogeneous"
                }
            ));
            t.write_csv(&path)?;
            println!("--- fig1: {} / {} ---", topology.name(), partition.name());
            println!("{}", t.render());
            written.push(path);
        }
    }
    Ok(written)
}
