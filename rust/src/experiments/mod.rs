//! Experiment drivers: one per table/figure of the paper's evaluation
//! section (see DESIGN.md §4 for the index).

pub mod ablations;
pub mod fig1;
pub mod sim;
pub mod tables;
pub mod theory;

use crate::algorithms::{DualPath, RoundPolicy};
use crate::compress::CodecSpec;
use crate::data::Partition;
use crate::util::cli::Args;

/// Shared sizing knobs for the CNN experiments, scaled to this CPU
/// testbed (DESIGN.md §2). Every driver accepts CLI overrides.
#[derive(Debug, Clone)]
pub struct Sizing {
    pub nodes: usize,
    pub epochs: usize,
    pub train_per_node: usize,
    pub test_size: usize,
    pub eta: f32,
    pub local_steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub dual_path: DualPath,
    pub verbose: bool,
    /// Restrict to these dataset configs (default: both).
    pub datasets: Vec<String>,
    /// Extra edge codecs (`--codec rand_k:0.1,qsgd:4,...`): appended as
    /// C-ECL rows to the comparison/sim tables; the first entry drives
    /// single-run commands (`repro train` / `repro sim`).
    pub codecs: Vec<CodecSpec>,
    /// Round policy (`--rounds sync|async:<s>`).  Async needs the
    /// virtual-time engine; in `repro sim --table` a non-sync value
    /// adds an async sweep next to the sync baseline.
    pub rounds: RoundPolicy,
    /// Heterogeneity axis (`--heterogeneity homogeneous|heterogeneous
    /// [:c]|dirichlet:<alpha>`).  `None` keeps each command's own
    /// partition default; in `repro sim --table` a Dirichlet value
    /// sweeps the α ladder {set α, 1.0, ∞} instead of a single split.
    pub partition: Option<Partition>,
}

impl Default for Sizing {
    fn default() -> Self {
        Sizing {
            nodes: 8,
            epochs: 16,
            train_per_node: 500,
            test_size: 1000,
            eta: 0.02,
            local_steps: 5,
            eval_every: 4,
            seed: 42,
            dual_path: DualPath::Native,
            verbose: false,
            datasets: vec!["fashion".to_string(), "cifar".to_string()],
            codecs: Vec::new(),
            rounds: RoundPolicy::Sync,
            partition: None,
        }
    }
}

impl Sizing {
    /// Apply `--epochs`, `--nodes`, `--train-per-node`, `--test-size`,
    /// `--eta`, `--local-steps`, `--eval-every`, `--seed`, `--dataset`,
    /// `--dual-path`, `--codec`, `--verbose` overrides.
    pub fn from_args(args: &Args) -> Sizing {
        let mut s = Sizing::default();
        s.nodes = args.get("nodes", s.nodes);
        s.epochs = args.get("epochs", s.epochs);
        s.train_per_node = args.get("train-per-node", s.train_per_node);
        s.test_size = args.get("test-size", s.test_size);
        s.eta = args.get("eta", s.eta);
        s.local_steps = args.get("local-steps", s.local_steps);
        s.eval_every = args.get("eval-every", s.eval_every);
        s.seed = args.get("seed", s.seed);
        s.verbose = args.flag("verbose");
        if let Some(ds) = args.get_opt::<String>("dataset") {
            s.datasets = vec![ds];
        }
        if let Some(list) = args.get_opt::<String>("codec") {
            s.codecs = list
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| {
                    CodecSpec::parse(p)
                        .unwrap_or_else(|e| panic!("--codec {p}: {e}"))
                })
                .collect();
        }
        match args.get_str("dual-path", "native").as_str() {
            "native" => s.dual_path = DualPath::Native,
            "pjrt" => s.dual_path = DualPath::Pjrt,
            other => panic!("--dual-path {other}: use native|pjrt"),
        }
        let rounds = args.get_str("rounds", "sync");
        s.rounds = RoundPolicy::parse(&rounds)
            .unwrap_or_else(|e| panic!("--rounds: {e}"));
        if let Some(h) = args.get_opt::<String>("heterogeneity") {
            s.partition = Some(
                Partition::parse(&h)
                    .unwrap_or_else(|e| panic!("--heterogeneity: {e}")),
            );
        }
        s
    }

    pub fn spec_base(&self, dataset: &str,
                     partition: Partition) -> crate::coordinator::ExperimentSpec {
        crate::coordinator::ExperimentSpec {
            dataset: dataset.to_string(),
            epochs: self.epochs,
            nodes: self.nodes,
            train_per_node: self.train_per_node,
            test_size: self.test_size,
            partition,
            local_steps: self.local_steps,
            eta: self.eta,
            eval_every: self.eval_every,
            seed: self.seed,
            dual_path: self.dual_path,
            rounds: self.rounds,
            verbose: self.verbose,
            ..Default::default()
        }
    }
}

/// Results directory (created on demand).
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from(
        std::env::var("CECL_RESULTS").unwrap_or_else(|_| "results".to_string()),
    );
    let _ = std::fs::create_dir_all(&dir);
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizing_overrides() {
        let args = Args::parse(
            "x --epochs 3 --dataset cifar --eta 0.5 --dual-path pjrt --verbose"
                .split_whitespace()
                .map(String::from),
        );
        let s = Sizing::from_args(&args);
        assert_eq!(s.epochs, 3);
        assert_eq!(s.datasets, vec!["cifar".to_string()]);
        assert_eq!(s.dual_path, DualPath::Pjrt);
        assert!(s.verbose);
        assert!((s.eta - 0.5).abs() < 1e-6);
        assert!(s.codecs.is_empty());
    }

    #[test]
    fn sizing_parses_round_policy() {
        let s = Sizing::from_args(&Args::parse(
            "x --rounds async:3".split_whitespace().map(String::from),
        ));
        assert_eq!(s.rounds, RoundPolicy::Async { max_staleness: 3 });
        let s = Sizing::from_args(&Args::parse(
            "x --rounds sync".split_whitespace().map(String::from),
        ));
        assert_eq!(s.rounds, RoundPolicy::Sync);
        assert_eq!(Sizing::default().rounds, RoundPolicy::Sync);
        assert_eq!(
            s.spec_base("fashion", Partition::Homogeneous).rounds,
            RoundPolicy::Sync
        );
    }

    #[test]
    #[should_panic]
    fn broken_round_policy_fails_loudly() {
        let _ = Sizing::from_args(&Args::parse(
            "x --rounds async".split_whitespace().map(String::from),
        ));
    }

    #[test]
    fn sizing_parses_heterogeneity() {
        let s = Sizing::from_args(&Args::parse(
            "x --heterogeneity dirichlet:0.1"
                .split_whitespace()
                .map(String::from),
        ));
        assert_eq!(s.partition, Some(Partition::Dirichlet { alpha: 0.1 }));
        let s = Sizing::from_args(&Args::parse(
            "x --heterogeneity heterogeneous:4"
                .split_whitespace()
                .map(String::from),
        ));
        assert_eq!(
            s.partition,
            Some(Partition::Heterogeneous { classes_per_node: 4 })
        );
        assert_eq!(Sizing::default().partition, None);
    }

    #[test]
    #[should_panic]
    fn broken_heterogeneity_fails_loudly() {
        let _ = Sizing::from_args(&Args::parse(
            "x --heterogeneity dirichlet:0".split_whitespace().map(String::from),
        ));
    }

    #[test]
    fn sizing_parses_codec_list() {
        let args = Args::parse(
            "x --codec rand_k:0.1,qsgd:4,ef+top_k:0.01"
                .split_whitespace()
                .map(String::from),
        );
        let s = Sizing::from_args(&args);
        assert_eq!(s.codecs.len(), 3);
        assert_eq!(s.codecs[1], CodecSpec::Qsgd { bits: 4 });
    }

    #[test]
    #[should_panic]
    fn broken_codec_spec_fails_loudly() {
        let args = Args::parse(
            "x --codec qsgd:99".split_whitespace().map(String::from),
        );
        let _ = Sizing::from_args(&args);
    }

    #[test]
    fn spec_base_carries_partition() {
        let s = Sizing::default();
        let spec = s.spec_base(
            "fashion",
            Partition::Heterogeneous { classes_per_node: 8 },
        );
        assert_eq!(
            spec.partition,
            Partition::Heterogeneous { classes_per_node: 8 }
        );
        assert_eq!(spec.dataset, "fashion");
    }
}
