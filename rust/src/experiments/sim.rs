//! Virtual-time experiments: simulated *time-to-accuracy* under
//! realistic links — the table the paper's byte counts are a proxy for.
//!
//! For each method × link model, runs the artifact-free simulated
//! engine and reports final accuracy, total simulated seconds, the
//! first virtual time at which the target accuracy was reached, payload
//! bytes, and retransmit overhead.  On a bandwidth-limited or lossy
//! link, C-ECL's smaller messages translate directly into earlier
//! arrival times — compression becomes a *time* win, which bytes alone
//! cannot show.

use anyhow::Result;

use crate::algorithms::AlgorithmSpec;
use crate::compress::{CodecSpec, WireMode};
use crate::coordinator::{run_simulated_native, ExecMode, ExperimentSpec,
                         Report};
use crate::data::Partition;
use crate::graph::Graph;
use crate::sim::{LinkSpec, SimConfig};
use crate::util::table::Table;

use super::{results_dir, Sizing};

/// The link ladder the table sweeps: from the threaded engine's ideal
/// network to a slow, lossy one.
pub fn link_ladder() -> Vec<LinkSpec> {
    vec![
        LinkSpec::Ideal,
        LinkSpec::Constant { latency_us: 500 },
        LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 100.0 },
        LinkSpec::Lossy {
            latency_us: 500,
            mbit_per_sec: 100.0,
            drop_p: 0.05,
        },
    ]
}

/// Methods compared in the simulated table: the baselines plus a
/// C-ECL codec ladder — the paper's rand-k, top-k, the values-only
/// wire, a b-bit quantizer, sign+norm, and an error-feedback variant.
/// Extra `--codec` specs from [`Sizing::codecs`] are appended by
/// [`run_sim_table`].
pub fn sim_methods() -> Vec<AlgorithmSpec> {
    let cecl_codec = |codec: CodecSpec| AlgorithmSpec::CEclCodec {
        codec,
        theta: 1.0,
        dense_first_epoch: false,
    };
    vec![
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 4 },
        AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
        cecl_codec(CodecSpec::RandK {
            k_frac: 0.10,
            mode: WireMode::ValuesOnly,
        }),
        cecl_codec(CodecSpec::TopK { k_frac: 0.10 }),
        cecl_codec(CodecSpec::Qsgd { bits: 4 }),
        cecl_codec(CodecSpec::SignNorm),
        cecl_codec(CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK {
            k_frac: 0.10,
        }))),
    ]
}

/// Run the time-to-accuracy table on a ring. `target_acc` picks the
/// accuracy threshold the "t2a" column reports.
pub fn run_sim_table(sizing: &Sizing, cfg_base: &SimConfig,
                     target_acc: f64) -> Result<(Table, Vec<Report>)> {
    let graph = Graph::ring(sizing.nodes);
    let dataset = sizing
        .datasets
        .first()
        .cloned()
        .unwrap_or_else(|| "fashion".to_string());
    let headers: Vec<String> = vec![
        "method".into(),
        "link".into(),
        "final acc".into(),
        "sim secs".into(),
        format!("t2a@{:.0}%", target_acc * 100.0),
        "KB/node/epoch".into(),
        "retrans KB".into(),
    ];
    let mut table = Table::new(headers);
    let mut reports = Vec::new();
    let mut methods = sim_methods();
    methods.extend(sizing.codecs.iter().map(|c| AlgorithmSpec::CEclCodec {
        codec: c.clone(),
        theta: 1.0,
        dense_first_epoch: false,
    }));
    for alg in methods {
        for link in link_ladder() {
            let mut spec: ExperimentSpec =
                sizing.spec_base(&dataset, Partition::Homogeneous);
            spec.algorithm = alg.clone();
            spec.exec = ExecMode::Simulated(SimConfig {
                link: link.clone(),
                ..cfg_base.clone()
            });
            if sizing.verbose {
                eprintln!("[sim] {} / {} ...", alg.name(), link.name());
            }
            let report = run_simulated_native(&spec, &graph)?;
            let t2a = report
                .history
                .time_to_accuracy(target_acc)
                .map(|(_, t)| format!("{t:.2}s"))
                .unwrap_or_else(|| "-".to_string());
            table.row([
                report.algorithm.clone(),
                link.name(),
                format!("{:.3}", report.final_accuracy),
                format!("{:.2}", report.sim_time_secs.unwrap_or(0.0)),
                t2a,
                format!("{:.0}", report.mean_bytes_per_epoch / 1024.0),
                format!(
                    "{:.0}",
                    report.retransmit_bytes as f64 / 1024.0
                ),
            ]);
            reports.push(report);
        }
    }
    let _ = table.write_csv(results_dir().join("sim_time_to_accuracy.csv"));
    Ok((table, reports))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_has_lossless_and_lossy_links() {
        let ladder = link_ladder();
        assert!(ladder.contains(&LinkSpec::Ideal));
        assert!(ladder
            .iter()
            .any(|l| matches!(l, LinkSpec::Lossy { .. })));
        assert!(sim_methods().len() >= 3);
    }

    #[test]
    fn tiny_sim_table_runs() {
        let sizing = Sizing {
            nodes: 4,
            epochs: 1,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eval_every: 1,
            datasets: vec!["tiny".to_string()],
            ..Sizing::default()
        };
        let (table, reports) =
            run_sim_table(&sizing, &SimConfig::default(), 0.99).unwrap();
        assert_eq!(reports.len(), sim_methods().len() * link_ladder().len());
        let rendered = table.render();
        assert!(rendered.contains("C-ECL"));
        assert!(rendered.contains("ideal"));
        // The codec ladder is present: ≥ 4 codecs including a
        // quantizer and an error-feedback variant.
        for row in ["rand_k 10%", "top_k 10%", "qsgd 4b", "sign",
                    "ef+top_k 10%"] {
            assert!(rendered.contains(row), "missing codec row `{row}`");
        }
        // Every report carries a virtual clock.
        assert!(reports.iter().all(|r| r.sim_time_secs.is_some()));
    }

    #[test]
    fn extra_codec_specs_append_rows() {
        let sizing = Sizing {
            nodes: 4,
            epochs: 1,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eval_every: 1,
            datasets: vec!["tiny".to_string()],
            codecs: vec![CodecSpec::Qsgd { bits: 8 }],
            ..Sizing::default()
        };
        let (table, reports) =
            run_sim_table(&sizing, &SimConfig::default(), 0.99).unwrap();
        assert_eq!(
            reports.len(),
            (sim_methods().len() + 1) * link_ladder().len()
        );
        assert!(table.render().contains("qsgd 8b"));
    }
}
