//! Virtual-time experiments: simulated *time-to-accuracy* under
//! realistic links — the table the paper's byte counts are a proxy for.
//!
//! For each method × link model × round policy, runs the artifact-free
//! simulated engine and reports final accuracy, total simulated
//! seconds, the first virtual time at which the target accuracy was
//! reached, payload bytes, retransmit overhead, and the largest
//! per-edge staleness actually consumed.  On a bandwidth-limited or
//! lossy link, C-ECL's smaller messages translate directly into
//! earlier arrival times — compression becomes a *time* win, which
//! bytes alone cannot show.  Under a straggler or a slow edge, the
//! async policy (`--rounds async:<s>`) additionally hides
//! communication behind the slowest node's compute, so the sync rows
//! double as the ablation baseline.

use anyhow::Result;

use crate::algorithms::{AlgorithmSpec, RoundPolicy};
use crate::compress::{CodecSpec, WireMode};
use crate::coordinator::{run_simulated_native, ExecMode, ExperimentSpec,
                         Report};
use crate::data::Partition;
use crate::graph::{ChurnSchedule, Graph};
use crate::sim::{LinkSpec, SimConfig};
use crate::util::table::Table;

use super::{results_dir, Sizing};

/// The link ladder the table sweeps: from the threaded engine's ideal
/// network to a slow, lossy one.
pub fn link_ladder() -> Vec<LinkSpec> {
    vec![
        LinkSpec::Ideal,
        LinkSpec::Constant { latency_us: 500 },
        LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 100.0 },
        LinkSpec::Lossy {
            latency_us: 500,
            mbit_per_sec: 100.0,
            drop_p: 0.05,
        },
    ]
}

/// Methods compared in the simulated table: the baselines plus a
/// C-ECL codec ladder — the paper's rand-k, top-k, the values-only
/// wire, a b-bit quantizer, sign+norm, and an error-feedback variant.
/// Extra `--codec` specs from [`Sizing::codecs`] are appended by
/// [`run_sim_table`].
pub fn sim_methods() -> Vec<AlgorithmSpec> {
    let cecl_codec = |codec: CodecSpec| AlgorithmSpec::CEclCodec {
        codec,
        theta: 1.0,
        dense_first_epoch: false,
    };
    vec![
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 4 },
        AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
        cecl_codec(CodecSpec::RandK {
            k_frac: 0.10,
            mode: WireMode::ValuesOnly,
        }),
        cecl_codec(CodecSpec::TopK { k_frac: 0.10 }),
        cecl_codec(CodecSpec::Qsgd { bits: 4 }),
        cecl_codec(CodecSpec::SignNorm),
        // PowerGossip's compressor on the C-ECL wire — byte-identical
        // per neighbor per round to the PowerGossip(4) row above.
        cecl_codec(CodecSpec::LowRank { rank: 4, iters: 1 }),
        cecl_codec(CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK {
            k_frac: 0.10,
        }))),
        // The compressed-gossip rivals (ROADMAP direction 2): CHOCO-SGD
        // at the same explicit rand-k wire as the C-ECL 10% row — byte-
        // identical frames, so the table isolates the algorithm — and
        // LEAD on the 4-bit quantizer next to the `cecl:qsgd:4` row.
        AlgorithmSpec::Choco {
            codec: CodecSpec::RandK {
                k_frac: 0.10,
                mode: WireMode::Explicit,
            },
        },
        AlgorithmSpec::Lead { codec: CodecSpec::Qsgd { bits: 4 } },
    ]
}

/// The round-policy sweep for a sizing: sync alone by default, sync
/// plus the requested async policy when `--rounds async:<s>` was given
/// (so every async row has its barrier baseline right above it).
pub fn policy_ladder(sizing: &Sizing) -> Vec<RoundPolicy> {
    if sizing.rounds.is_async() {
        vec![RoundPolicy::Sync, sizing.rounds]
    } else {
        vec![RoundPolicy::Sync]
    }
}

/// The heterogeneity sweep for a sizing: the single requested split by
/// default; a `--heterogeneity dirichlet:<alpha>` request sweeps the
/// paper's α ladder — homogeneous (α = ∞), moderate skew (α = 1.0),
/// and the requested α — so every non-IID row has its IID baseline in
/// the same table, mirroring [`policy_ladder`].
pub fn heterogeneity_ladder(sizing: &Sizing) -> Vec<Partition> {
    match sizing.partition {
        Some(Partition::Dirichlet { alpha }) => {
            let mut ladder =
                vec![Partition::Homogeneous, Partition::Dirichlet { alpha: 1.0 }];
            if alpha != 1.0 {
                ladder.push(Partition::Dirichlet { alpha });
            }
            ladder
        }
        Some(p) => vec![p],
        None => vec![Partition::Homogeneous],
    }
}

/// The churn sweep for a base schedule: static alone when nothing
/// churns, otherwise static plus the requested schedule — every churn
/// row gets its static baseline right above it, mirroring
/// [`policy_ladder`].
pub fn churn_ladder(base: &ChurnSchedule) -> Vec<ChurnSchedule> {
    if base.has_churn() {
        vec![ChurnSchedule::new(), base.clone()]
    } else {
        // Epoch-constant (possibly outage-only) schedule: one row.
        vec![base.clone()]
    }
}

/// Run the time-to-accuracy table on a ring. `target_acc` picks the
/// accuracy threshold the "t2a" column reports; `policies` is the
/// round-policy sweep (see [`policy_ladder`]).  The churn ladder is
/// derived from `cfg_base.churn` ([`churn_ladder`]): a churn-bearing
/// schedule runs every row twice, static baseline first.  A method
/// that cannot run a policy is skipped rather than failing the whole
/// table (no current method is — PowerGossip joined the async contract
/// via per-edge conversation counters); rows that never reach the
/// target print `—` in the t2a column instead of aborting the sweep,
/// and static rows print `—` in the churn counters (the PR 4
/// convention).
pub fn run_sim_table(sizing: &Sizing, cfg_base: &SimConfig, target_acc: f64,
                     policies: &[RoundPolicy])
                     -> Result<(Table, Vec<Report>)> {
    let graph = Graph::ring(sizing.nodes);
    let dataset = sizing
        .datasets
        .first()
        .cloned()
        .unwrap_or_else(|| "fashion".to_string());
    let headers: Vec<String> = vec![
        "method".into(),
        "link".into(),
        "rounds".into(),
        "churn".into(),
        "het".into(),
        "final acc".into(),
        "sim secs".into(),
        format!("t2a@{:.0}%", target_acc * 100.0),
        "lag".into(),
        "churned".into(),
        "chdrops".into(),
        "KB/node/epoch".into(),
        "retrans KB".into(),
    ];
    let mut table = Table::new(headers);
    let mut reports = Vec::new();
    let mut methods = sim_methods();
    methods.extend(sizing.codecs.iter().map(|c| AlgorithmSpec::CEclCodec {
        codec: c.clone(),
        theta: 1.0,
        dense_first_epoch: false,
    }));
    let churns = churn_ladder(&cfg_base.churn);
    let partitions = heterogeneity_ladder(sizing);
    for alg in methods {
        for link in link_ladder() {
            for &policy in policies {
                if policy.is_async() && !alg.supports_async() {
                    continue;
                }
                for churn in &churns {
                  for &partition in &partitions {
                    let mut spec: ExperimentSpec =
                        sizing.spec_base(&dataset, partition);
                    spec.algorithm = alg.clone();
                    spec.rounds = policy;
                    spec.exec = ExecMode::Simulated(SimConfig {
                        link: link.clone(),
                        churn: churn.clone(),
                        ..cfg_base.clone()
                    });
                    if sizing.verbose {
                        eprintln!("[sim] {} / {} / {} / {} / {} ...",
                                  alg.name(), link.name(), policy.name(),
                                  churn.label(), partition.name());
                    }
                    let report = run_simulated_native(&spec, &graph)?;
                    // A run that never reached the target
                    // (straggler-heavy lossy rows genuinely may not)
                    // prints `—` instead of aborting the sweep — same
                    // for a missing virtual clock, and for the churn
                    // counters of static rows.
                    let t2a = report
                        .history
                        .time_to_accuracy(target_acc)
                        .map(|(_, t)| format!("{t:.2}s"))
                        .unwrap_or_else(|| "—".to_string());
                    let sim_secs = report
                        .sim_time_secs
                        .map(|t| format!("{t:.2}"))
                        .unwrap_or_else(|| "—".to_string());
                    let (churned, chdrops) = if churn.has_churn() {
                        (
                            format!("{}", report.edges_churned),
                            format!("{}", report.frames_dropped_by_churn),
                        )
                    } else {
                        ("—".to_string(), "—".to_string())
                    };
                    table.row([
                        report.algorithm.clone(),
                        link.name(),
                        policy.name(),
                        churn.label(),
                        partition.name(),
                        format!("{:.3}", report.final_accuracy),
                        sim_secs,
                        t2a,
                        format!("{}", report.max_staleness),
                        churned,
                        chdrops,
                        format!("{:.0}", report.mean_bytes_per_epoch / 1024.0),
                        format!(
                            "{:.0}",
                            report.retransmit_bytes as f64 / 1024.0
                        ),
                    ]);
                    reports.push(report);
                  }
                }
            }
        }
    }
    let _ = table.write_csv(results_dir().join("sim_time_to_accuracy.csv"));
    Ok((table, reports))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_has_lossless_and_lossy_links() {
        let ladder = link_ladder();
        assert!(ladder.contains(&LinkSpec::Ideal));
        assert!(ladder
            .iter()
            .any(|l| matches!(l, LinkSpec::Lossy { .. })));
        assert!(sim_methods().len() >= 3);
    }

    fn tiny_sizing() -> Sizing {
        Sizing {
            nodes: 4,
            epochs: 1,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eval_every: 1,
            datasets: vec!["tiny".to_string()],
            ..Sizing::default()
        }
    }

    #[test]
    fn tiny_sim_table_runs() {
        let sizing = tiny_sizing();
        let (table, reports) = run_sim_table(&sizing, &SimConfig::default(),
                                             0.99, &policy_ladder(&sizing))
            .unwrap();
        assert_eq!(reports.len(), sim_methods().len() * link_ladder().len());
        let rendered = table.render();
        assert!(rendered.contains("C-ECL"));
        assert!(rendered.contains("ideal"));
        assert!(rendered.contains("sync"));
        // The codec ladder is present: ≥ 5 codecs including a
        // quantizer, the low-rank (PowerGossip) compressor, and an
        // error-feedback variant.
        for row in ["rand_k 10%", "top_k 10%", "qsgd 4b", "sign",
                    "low_rank r4", "ef+top_k 10%"] {
            assert!(rendered.contains(row), "missing codec row `{row}`");
        }
        // Every report carries a virtual clock; sync rows never lag.
        assert!(reports.iter().all(|r| r.sim_time_secs.is_some()));
        assert!(reports.iter().all(|r| r.max_staleness == 0));
    }

    #[test]
    fn sim_table_is_thread_count_invariant() {
        // The `--threads` knob rides through `cfg_base` into every
        // table cell; the conservative-PDES engine guarantees the
        // parallel trajectories are bit-identical, so the whole table
        // must reproduce (ideal-link rows quietly run serial — zero
        // lookahead — which is part of the contract).
        let sizing = tiny_sizing();
        let policies = policy_ladder(&sizing);
        let (_, serial) = run_sim_table(&sizing, &SimConfig::default(),
                                        0.99, &policies)
            .unwrap();
        let cfg = SimConfig { threads: 3, ..SimConfig::default() };
        let (_, parallel) =
            run_sim_table(&sizing, &cfg, 0.99, &policies).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.total_bytes, b.total_bytes, "{}", a.algorithm);
            assert_eq!(a.edge_payload_bytes, b.edge_payload_bytes,
                       "{}", a.algorithm);
            assert_eq!(a.final_accuracy.to_bits(),
                       b.final_accuracy.to_bits(), "{}", a.algorithm);
            assert_eq!(a.sim_time_secs, b.sim_time_secs, "{}", a.algorithm);
        }
    }

    #[test]
    fn extra_codec_specs_append_rows() {
        let sizing = Sizing {
            codecs: vec![CodecSpec::Qsgd { bits: 8 }],
            ..tiny_sizing()
        };
        let (table, reports) = run_sim_table(&sizing, &SimConfig::default(),
                                             0.99, &policy_ladder(&sizing))
            .unwrap();
        assert_eq!(
            reports.len(),
            (sim_methods().len() + 1) * link_ladder().len()
        );
        assert!(table.render().contains("qsgd 8b"));
    }

    #[test]
    fn async_policy_ladder_sweeps_sync_baseline_including_powergossip() {
        let sizing = Sizing {
            rounds: RoundPolicy::Async { max_staleness: 2 },
            ..tiny_sizing()
        };
        let policies = policy_ladder(&sizing);
        assert_eq!(
            policies,
            vec![RoundPolicy::Sync, RoundPolicy::Async { max_staleness: 2 }]
        );
        let (table, reports) =
            run_sim_table(&sizing, &SimConfig::default(), 0.99, &policies)
                .unwrap();
        // Every method runs BOTH policies — PowerGossip included, now
        // that its conversation counters support async rounds.
        assert_eq!(
            reports.len(),
            2 * sim_methods().len() * link_ladder().len()
        );
        let rendered = table.render();
        assert!(rendered.contains("async:2"));
        // The PowerGossip row exists on the async sweep.
        assert!(
            reports.iter().any(|r| r.algorithm.contains("PowerGossip")
                && r.sim_time_secs.is_some()),
            "PowerGossip rows must not be skipped"
        );
        assert!(reports.iter().all(|r| r.max_staleness <= 2));
    }

    #[test]
    fn dirichlet_ladder_sweeps_alpha_with_rival_rows() {
        // Default: one homogeneous split, no ladder.
        assert_eq!(
            heterogeneity_ladder(&tiny_sizing()),
            vec![Partition::Homogeneous]
        );
        // A non-Dirichlet request stays a single row.
        let s = Sizing {
            partition: Some(Partition::Heterogeneous { classes_per_node: 4 }),
            ..tiny_sizing()
        };
        assert_eq!(heterogeneity_ladder(&s).len(), 1);
        // `--heterogeneity dirichlet:0.1` sweeps α ∈ {∞, 1.0, 0.1}…
        let s = Sizing {
            partition: Some(Partition::Dirichlet { alpha: 0.1 }),
            ..tiny_sizing()
        };
        assert_eq!(
            heterogeneity_ladder(&s),
            vec![
                Partition::Homogeneous,
                Partition::Dirichlet { alpha: 1.0 },
                Partition::Dirichlet { alpha: 0.1 },
            ]
        );
        // …and α = 1.0 is not swept twice.
        let s1 = Sizing {
            partition: Some(Partition::Dirichlet { alpha: 1.0 }),
            ..tiny_sizing()
        };
        assert_eq!(heterogeneity_ladder(&s1).len(), 2);

        // End-to-end: the ladder triples every cell, and the rival
        // CHOCO-SGD/LEAD rows run under every split.
        let (table, reports) =
            run_sim_table(&s, &SimConfig::default(), 0.99,
                          &policy_ladder(&s))
                .unwrap();
        assert_eq!(
            reports.len(),
            3 * sim_methods().len() * link_ladder().len()
        );
        let rendered = table.render();
        for cell in ["CHOCO-SGD [rand_k 10%]", "LEAD [qsgd 4b]",
                     "dirichlet(0.1)", "dirichlet(1)", "homogeneous"] {
            assert!(rendered.contains(cell), "missing `{cell}`");
        }
    }

    #[test]
    fn churn_ladder_doubles_rows_and_prints_dash_for_static() {
        use crate::graph::ChurnSchedule;
        // The ladder: static alone for epoch-constant schedules, static
        // + churn when the schedule tears topology.
        assert_eq!(churn_ladder(&ChurnSchedule::new()).len(), 1);
        let mut outage_only = ChurnSchedule::new();
        outage_only.add_outage(0, 10, 20);
        let ladder = churn_ladder(&outage_only);
        assert_eq!(ladder.len(), 1, "outage-only is epoch-constant");
        assert!(!ladder[0].is_empty(), "outage windows must be kept");
        let mut churny = ChurnSchedule::new();
        churny.random_edge_churn_with_slot(0.3, 5, 1_000_000);
        let ladder = churn_ladder(&churny);
        assert_eq!(ladder.len(), 2);
        assert!(!ladder[0].has_churn(), "static baseline first");
        assert!(ladder[1].has_churn());

        // End-to-end: the table runs both rows per cell and prints the
        // `—` convention in the churn counters of static rows.
        let sizing = tiny_sizing();
        let cfg = SimConfig {
            churn: churny,
            ..SimConfig::default()
        };
        let (table, reports) =
            run_sim_table(&sizing, &cfg, 0.99, &policy_ladder(&sizing))
                .unwrap();
        assert_eq!(
            reports.len(),
            2 * sim_methods().len() * link_ladder().len()
        );
        let rendered = table.render();
        assert!(rendered.contains("random:0.3"));
        assert!(rendered.contains("static"));
        assert!(rendered.contains("—"), "static rows print — counters");
        // Churn rows surface real transition counts.
        assert!(
            reports.iter().any(|r| r.edges_churned > 0),
            "no churn row transitioned"
        );
    }

    #[test]
    fn unreached_target_prints_em_dash_not_panic() {
        // A target no tiny run can reach: every t2a cell must render
        // `—` and the sweep must complete instead of unwrap-aborting.
        let sizing = tiny_sizing();
        let (table, reports) = run_sim_table(&sizing, &SimConfig::default(),
                                             2.0, &policy_ladder(&sizing))
            .unwrap();
        assert!(!reports.is_empty());
        let rendered = table.render();
        assert!(rendered.contains("—"), "unreached targets must print —");
        // And the typed path reports the miss with the best accuracy.
        let err = reports[0]
            .history
            .require_time_to_accuracy(2.0)
            .unwrap_err();
        assert!(err.to_string().contains("never reached"), "{err}");
    }

    #[test]
    fn async_beats_sync_under_a_straggler() -> anyhow::Result<()> {
        // The acceptance scenario in miniature: a ring with one 8×
        // straggler (16 ms rounds vs 2 ms) on a latency-dominated link
        // (30 ms).  Sync couples every round into a compute+round-trip
        // cycle (period ≈ (2·30 + 2 + 16)/2 = 39 ms); async:2 gives
        // 2 × 16 = 32 ms ≥ 30 ms of slack, so the straggler's edges lag
        // instead of stalling and the period collapses to the
        // straggler's own 16 ms compute — same target accuracy in
        // measurably less simulated time.
        let run = |rounds: RoundPolicy| {
            let sizing = Sizing {
                nodes: 8,
                epochs: 4,
                train_per_node: 40,
                rounds,
                ..tiny_sizing()
            };
            let cfg = SimConfig {
                link: LinkSpec::Constant { latency_us: 30_000 },
                compute_ns_per_step: 1_000_000,
                stragglers: vec![(0, 8.0)],
                ..SimConfig::default()
            };
            let spec = ExperimentSpec {
                algorithm: AlgorithmSpec::CEcl {
                    k_frac: 0.1,
                    theta: 1.0,
                    dense_first_epoch: false,
                },
                exec: ExecMode::Simulated(cfg),
                rounds,
                ..sizing.spec_base("tiny", Partition::Homogeneous)
            };
            run_simulated_native(&spec, &Graph::ring(8))
        };
        let sync = run(RoundPolicy::Sync)?;
        let async_ = run(RoundPolicy::Async { max_staleness: 2 })?;
        assert_eq!(sync.max_staleness, 0);
        assert!(async_.max_staleness >= 1, "straggler edges must lag");
        assert!(async_.max_staleness <= 2, "bound violated");
        // Same traffic, strictly less simulated time end-to-end AND to
        // the (trivially reachable) accuracy target — extracted through
        // the typed accessors, not `.unwrap()` (the exact panics a
        // straggler-heavy sweep used to abort on).
        assert_eq!(sync.total_bytes, async_.total_bytes);
        let ts = sync.require_sim_time()?;
        let ta = async_.require_sim_time()?;
        assert!(ta < ts, "async {ta}s !< sync {ts}s");
        let (_, t2a_s) = sync.history.require_time_to_accuracy(0.0)?;
        let (_, t2a_a) = async_.history.require_time_to_accuracy(0.0)?;
        assert!(t2a_a < t2a_s, "t2a async {t2a_a}s !< sync {t2a_s}s");
        Ok(())
    }
}
