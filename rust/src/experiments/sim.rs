//! Virtual-time experiments: simulated *time-to-accuracy* under
//! realistic links — the table the paper's byte counts are a proxy for.
//!
//! For each method × link model, runs the artifact-free simulated
//! engine and reports final accuracy, total simulated seconds, the
//! first virtual time at which the target accuracy was reached, payload
//! bytes, and retransmit overhead.  On a bandwidth-limited or lossy
//! link, C-ECL's smaller messages translate directly into earlier
//! arrival times — compression becomes a *time* win, which bytes alone
//! cannot show.

use anyhow::Result;

use crate::algorithms::AlgorithmSpec;
use crate::coordinator::{run_simulated_native, ExecMode, ExperimentSpec,
                         Report};
use crate::data::Partition;
use crate::graph::Graph;
use crate::sim::{LinkSpec, SimConfig};
use crate::util::table::Table;

use super::{results_dir, Sizing};

/// The link ladder the table sweeps: from the threaded engine's ideal
/// network to a slow, lossy one.
pub fn link_ladder() -> Vec<LinkSpec> {
    vec![
        LinkSpec::Ideal,
        LinkSpec::Constant { latency_us: 500 },
        LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 100.0 },
        LinkSpec::Lossy {
            latency_us: 500,
            mbit_per_sec: 100.0,
            drop_p: 0.05,
        },
    ]
}

/// Methods compared in the simulated table (a compact subset of the
/// paper ladder).
pub fn sim_methods() -> Vec<AlgorithmSpec> {
    vec![
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 4 },
        AlgorithmSpec::CEcl {
            k_frac: 0.10,
            theta: 1.0,
            dense_first_epoch: false,
        },
    ]
}

/// Run the time-to-accuracy table on a ring. `target_acc` picks the
/// accuracy threshold the "t2a" column reports.
pub fn run_sim_table(sizing: &Sizing, cfg_base: &SimConfig,
                     target_acc: f64) -> Result<(Table, Vec<Report>)> {
    let graph = Graph::ring(sizing.nodes);
    let dataset = sizing
        .datasets
        .first()
        .cloned()
        .unwrap_or_else(|| "fashion".to_string());
    let headers: Vec<String> = vec![
        "method".into(),
        "link".into(),
        "final acc".into(),
        "sim secs".into(),
        format!("t2a@{:.0}%", target_acc * 100.0),
        "KB/node/epoch".into(),
        "retrans KB".into(),
    ];
    let mut table = Table::new(headers);
    let mut reports = Vec::new();
    for alg in sim_methods() {
        for link in link_ladder() {
            let mut spec: ExperimentSpec =
                sizing.spec_base(&dataset, Partition::Homogeneous);
            spec.algorithm = alg.clone();
            spec.exec = ExecMode::Simulated(SimConfig {
                link: link.clone(),
                ..cfg_base.clone()
            });
            if sizing.verbose {
                eprintln!("[sim] {} / {} ...", alg.name(), link.name());
            }
            let report = run_simulated_native(&spec, &graph)?;
            let t2a = report
                .history
                .time_to_accuracy(target_acc)
                .map(|(_, t)| format!("{t:.2}s"))
                .unwrap_or_else(|| "-".to_string());
            table.row([
                report.algorithm.clone(),
                link.name(),
                format!("{:.3}", report.final_accuracy),
                format!("{:.2}", report.sim_time_secs.unwrap_or(0.0)),
                t2a,
                format!("{:.0}", report.mean_bytes_per_epoch / 1024.0),
                format!(
                    "{:.0}",
                    report.retransmit_bytes as f64 / 1024.0
                ),
            ]);
            reports.push(report);
        }
    }
    let _ = table.write_csv(results_dir().join("sim_time_to_accuracy.csv"));
    Ok((table, reports))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_has_lossless_and_lossy_links() {
        let ladder = link_ladder();
        assert!(ladder.contains(&LinkSpec::Ideal));
        assert!(ladder
            .iter()
            .any(|l| matches!(l, LinkSpec::Lossy { .. })));
        assert!(sim_methods().len() >= 3);
    }

    #[test]
    fn tiny_sim_table_runs() {
        let sizing = Sizing {
            nodes: 4,
            epochs: 1,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eval_every: 1,
            datasets: vec!["tiny".to_string()],
            ..Sizing::default()
        };
        let (table, reports) =
            run_sim_table(&sizing, &SimConfig::default(), 0.99).unwrap();
        assert_eq!(reports.len(), sim_methods().len() * link_ladder().len());
        let rendered = table.render();
        assert!(rendered.contains("C-ECL"));
        assert!(rendered.contains("ideal"));
        // Every report carries a virtual clock.
        assert!(reports.iter().all(|r| r.sim_time_secs.is_some()));
    }
}
