//! Tables 1–3 of the paper.
//!
//! * Table 1 — accuracy + Send/Epoch, homogeneous split, ring(8).
//! * Table 2 — same, heterogeneous (8-of-10 classes per node).
//! * Table 3 — Send/Epoch across chain / ring / multiplex ring / fully
//!   connected for {D-PSGD, ECL, PowerGossip(10), C-ECL(10%)}.

use anyhow::Result;

use crate::algorithms::AlgorithmSpec;
use crate::coordinator::{run_with_engine, Report};
use crate::data::Partition;
use crate::graph::{Graph, Topology};
use crate::model::Manifest;
use crate::runtime::Engine;
use crate::util::table::{kb_with_ratio, Table};

use super::{results_dir, Sizing};

/// The comparison ladder of Tables 1–2, in the paper's row order.
pub fn comparison_methods() -> Vec<AlgorithmSpec> {
    vec![
        AlgorithmSpec::Sgd,
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 1 },
        AlgorithmSpec::PowerGossip { iters: 10 },
        AlgorithmSpec::PowerGossip { iters: 20 },
        AlgorithmSpec::CEcl { k_frac: 0.01, theta: 1.0, dense_first_epoch: true },
        AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: true },
        AlgorithmSpec::CEcl { k_frac: 0.20, theta: 1.0, dense_first_epoch: true },
    ]
}

/// Run one accuracy table (Table 1 or 2). Returns the rendered table and
/// the raw reports (also written to `results/`).  Extra `--codec` specs
/// from [`Sizing::codecs`] append C-ECL rows below the paper ladder.
pub fn run_accuracy_table(
    engine: &Engine,
    manifest: &Manifest,
    sizing: &Sizing,
    partition: Partition,
    label: &str,
) -> Result<(Table, Vec<Report>)> {
    let graph = Graph::ring(sizing.nodes);
    let mut methods = comparison_methods();
    methods.extend(sizing.codecs.iter().map(|c| AlgorithmSpec::CEclCodec {
        codec: c.clone(),
        theta: 1.0,
        dense_first_epoch: true,
    }));
    let mut headers = vec!["method".to_string()];
    for ds in &sizing.datasets {
        headers.push(format!("{ds} acc"));
        headers.push(format!("{ds} send/epoch"));
    }
    let mut table = Table::new(headers);
    let mut reports = Vec::new();

    // Per dataset: run all methods; D-PSGD's bytes are the x1.0 baseline.
    let mut rows: Vec<Vec<String>> =
        methods.iter().map(|m| vec![m.name()]).collect();
    for ds in &sizing.datasets {
        let mut per_method: Vec<Report> = Vec::new();
        for spec_alg in &methods {
            let mut spec = sizing.spec_base(ds, partition);
            spec.algorithm = spec_alg.clone();
            eprintln!("[{label}] {ds} / {} ...", spec_alg.name());
            let report = run_with_engine(engine, manifest, &spec, &graph)?;
            eprintln!(
                "[{label}]   acc {:.3} best {:.3} send/epoch {:.0} KB ({:.1}s)",
                report.final_accuracy,
                report.best_accuracy,
                report.mean_bytes_per_epoch / 1024.0,
                report.wallclock_secs
            );
            per_method.push(report);
        }
        let baseline = per_method
            .iter()
            .zip(&methods)
            .find(|(_, m)| matches!(m, AlgorithmSpec::DPsgd))
            .map(|(r, _)| r.mean_bytes_per_epoch)
            .unwrap_or(0.0);
        for (row, report) in rows.iter_mut().zip(&per_method) {
            row.push(format!("{:.1}", report.best_accuracy * 100.0));
            row.push(if report.mean_bytes_per_epoch > 0.0 {
                kb_with_ratio(report.mean_bytes_per_epoch, baseline)
            } else {
                "-".to_string()
            });
        }
        reports.extend(per_method);
    }
    for row in rows {
        table.row(row);
    }
    table
        .write_csv(results_dir().join(format!("{label}.csv")))
        .ok();
    Ok((table, reports))
}

/// Table 3: Send/Epoch per topology. Runs short (bytes are per-round
/// deterministic), with the dense warmup disabled to report the steady
/// state like the paper.
pub fn run_topology_table(
    engine: &Engine,
    manifest: &Manifest,
    sizing: &Sizing,
) -> Result<Table> {
    let methods: Vec<AlgorithmSpec> = vec![
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 10 },
        AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: false },
    ];
    let ds = sizing
        .datasets
        .first()
        .cloned()
        .unwrap_or_else(|| "fashion".to_string());
    let mut headers = vec!["method".to_string()];
    for t in Topology::paper_set() {
        headers.push(t.name().to_string());
    }
    let mut table = Table::new(headers);
    let mut rows: Vec<Vec<String>> =
        methods.iter().map(|m| vec![m.name()]).collect();
    for topology in Topology::paper_set() {
        let graph = Graph::build(topology, sizing.nodes);
        for (row, alg) in rows.iter_mut().zip(&methods) {
            let mut spec = sizing.spec_base(&ds, Partition::Homogeneous);
            spec.algorithm = alg.clone();
            // Bytes/epoch are deterministic: 2 epochs suffice.
            spec.epochs = 2;
            spec.eval_every = 2;
            eprintln!("[table3] {} / {} ...", topology.name(), alg.name());
            let report = run_with_engine(engine, manifest, &spec, &graph)?;
            row.push(format!(
                "{:.0} KB",
                report.mean_bytes_per_epoch / 1024.0
            ));
        }
    }
    for row in rows {
        table.row(row);
    }
    table.write_csv(results_dir().join("table3.csv")).ok();
    Ok(table)
}
