//! Theorem 1 / Corollaries 1–3 validation on the convex-quadratic
//! substrate: measured linear rates vs the theoretical contraction
//! bound, the τ threshold, and the θ* = 1 optimality.

use anyhow::Result;

use crate::graph::Graph;
use crate::quadratic::{
    rate_bound, run_cecl, tau_threshold, theta_domain, DualRule,
    QuadraticNetwork,
};
use crate::util::stats::empirical_rate;
use crate::util::table::Table;

use super::results_dir;

/// Configuration for the theory experiment.
#[derive(Debug, Clone)]
pub struct TheoryConfig {
    pub nodes: usize,
    pub dim: usize,
    pub rows: usize,
    pub ridge: f64,
    pub hetero: f64,
    pub rounds: usize,
    pub seed: u64,
}

impl Default for TheoryConfig {
    fn default() -> Self {
        TheoryConfig {
            nodes: 8,
            dim: 24,
            rows: 40,
            ridge: 0.5,
            hetero: 0.5,
            rounds: 200,
            seed: 42,
        }
    }
}

/// Run the full theory suite; prints tables and writes CSVs. Returns the
/// (tau sweep, theta sweep) tables.
pub fn run_theory(cfg: &TheoryConfig) -> Result<(Table, Table)> {
    let graph = Graph::ring(cfg.nodes);
    let net = QuadraticNetwork::random(
        cfg.nodes, cfg.dim, cfg.rows, cfg.ridge, cfg.hetero, cfg.seed,
    );
    let alpha = net
        .best_alpha(&graph)
        .ok_or_else(|| anyhow::anyhow!("theory needs a non-empty graph"))?;
    let delta = net
        .delta(alpha, &graph)
        .ok_or_else(|| anyhow::anyhow!("theory needs a non-empty graph"))?;
    let threshold = tau_threshold(delta);
    println!(
        "quadratic network: L={:.3} mu={:.3} alpha*={:.4} delta={:.4} \
         tau_threshold={:.4}",
        net.l_smooth, net.mu, alpha, delta, threshold
    );

    // ---- τ sweep at θ = 1 (Theorem 1 + Corollary 1 at τ = 1) ---------
    let mut tau_table = Table::new([
        "tau (k%)",
        "theta domain",
        "bound rho",
        "measured rate",
        "final error",
        "converged",
    ]);
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    let taus = [1.0, 0.9, 0.7, 0.5, (threshold + 1.0) / 2.0, threshold * 0.5];
    for &tau in &taus {
        let dom = theta_domain(tau, delta);
        let errors = run_cecl(
            &net, &graph, alpha, 1.0, tau, cfg.rounds, cfg.seed,
            DualRule::CompressDiff,
        );
        let tail_start = cfg.rounds / 5;
        let rate = empirical_rate(&errors[tail_start..]);
        let bound = rate_bound(1.0, tau, delta);
        let final_err = *errors.last().unwrap();
        let converged = final_err < errors[0] * 1e-3;
        tau_table.row([
            format!("{tau:.3}"),
            dom.map(|(lo, hi)| format!("({lo:.3}, {hi:.3})"))
                .unwrap_or_else(|| "empty".to_string()),
            format!("{bound:.4}"),
            format!("{rate:.4}"),
            format!("{final_err:.3e}"),
            converged.to_string(),
        ]);
        curves.push((format!("tau={tau:.3}"), errors));
    }
    println!("--- Theorem 1: tau sweep (theta = 1) ---");
    println!("{}", tau_table.render());

    // ---- θ sweep at fixed τ (Corollary 2: θ* = 1) --------------------
    let tau = (threshold + 1.0) / 2.0;
    let mut theta_table =
        Table::new(["theta", "in domain", "bound rho", "measured rate"]);
    for theta in [0.25, 0.5, 0.75, 1.0, 1.25] {
        let dom = theta_domain(tau, delta);
        let in_dom = dom
            .map(|(lo, hi)| theta > lo && theta < hi)
            .unwrap_or(false);
        let errors = run_cecl(
            &net, &graph, alpha, theta, tau, cfg.rounds, cfg.seed,
            DualRule::CompressDiff,
        );
        let rate = empirical_rate(&errors[cfg.rounds / 5..]);
        theta_table.row([
            format!("{theta:.2}"),
            in_dom.to_string(),
            format!("{:.4}", rate_bound(theta, tau, delta)),
            format!("{rate:.4}"),
        ]);
    }
    println!("--- Corollary 2: theta sweep (tau = {tau:.3}) ---");
    println!("{}", theta_table.render());

    // ---- Convergence curves CSV --------------------------------------
    let max_len = curves.iter().map(|(_, e)| e.len()).max().unwrap_or(0);
    let mut headers = vec!["round".to_string()];
    headers.extend(curves.iter().map(|(n, _)| n.clone()));
    let mut curve_table = Table::new(headers);
    for r in 0..max_len {
        let mut row = vec![r.to_string()];
        for (_, e) in &curves {
            row.push(
                e.get(r).map(|v| format!("{v:.6e}")).unwrap_or_default(),
            );
        }
        curve_table.row(row);
    }
    curve_table.write_csv(results_dir().join("theory_curves.csv"))?;
    tau_table.write_csv(results_dir().join("theory_tau.csv"))?;
    theta_table.write_csv(results_dir().join("theory_theta.csv"))?;
    Ok((tau_table, theta_table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_suite_runs_small() {
        let cfg = TheoryConfig {
            nodes: 4,
            dim: 6,
            rows: 10,
            rounds: 60,
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("cecl_theory_test");
        std::env::set_var("CECL_RESULTS", &dir);
        let (tau, theta) = run_theory(&cfg).unwrap();
        std::env::remove_var("CECL_RESULTS");
        assert!(!tau.is_empty());
        assert!(!theta.is_empty());
        assert!(dir.join("theory_curves.csv").exists());
        let _ = std::fs::remove_dir_all(dir);
    }
}
