//! Network topology substrate: the undirected connected graph
//! G = (V, E) of §2.1, the four topologies of the paper's §5.3 (chain,
//! ring, multiplex ring, fully connected), Metropolis–Hastings gossip
//! weights (Xiao–Boyd–Kim 2007, used by D-PSGD / PowerGossip per the
//! paper's §D.1), and the A_{i|j} = ±I edge-sign convention of Eq. (2).

use crate::util::rng::Pcg;

/// The topologies evaluated in the paper (§5.3, Fig. 2) plus extras.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    Chain,
    Ring,
    /// Ring plus all 2-hop chords (the paper's “multiplex ring”).
    MultiplexRing,
    FullyConnected,
    Star,
    /// Connected Erdős–Rényi-style random graph with given extra-edge
    /// probability (beyond a spanning ring that guarantees connectivity).
    Random { extra_p_percent: u8, seed: u64 },
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Chain => "chain",
            Topology::Ring => "ring",
            Topology::MultiplexRing => "multiplex-ring",
            Topology::FullyConnected => "fully-connected",
            Topology::Star => "star",
            Topology::Random { .. } => "random",
        }
    }

    /// Parse from CLI names.
    pub fn from_name(name: &str) -> Option<Topology> {
        match name {
            "chain" => Some(Topology::Chain),
            "ring" => Some(Topology::Ring),
            "multiplex-ring" | "multiplex_ring" | "multiplex" => {
                Some(Topology::MultiplexRing)
            }
            "fully-connected" | "complete" | "full" => {
                Some(Topology::FullyConnected)
            }
            "star" => Some(Topology::Star),
            _ => None,
        }
    }

    /// The paper's four evaluation topologies (§5.3 order).
    pub fn paper_set() -> [Topology; 4] {
        [
            Topology::Chain,
            Topology::Ring,
            Topology::MultiplexRing,
            Topology::FullyConnected,
        ]
    }
}

/// Time-varying topology hook: scheduled windows (in virtual
/// nanoseconds) during which an edge of the canonical edge list is
/// down.  The virtual-time engine holds traffic on a down edge until
/// the window ends — links recover, messages are delayed rather than
/// lost, so protocol semantics (eventual delivery) are preserved while
/// outages stretch time-to-accuracy.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OutageSchedule {
    /// `(edge index, from_ns inclusive, until_ns exclusive)`.
    windows: Vec<(usize, u64, u64)>,
}

impl OutageSchedule {
    pub fn new() -> OutageSchedule {
        OutageSchedule::default()
    }

    /// Schedule edge `edge` down during `[from_ns, until_ns)`.
    pub fn add(&mut self, edge: usize, from_ns: u64, until_ns: u64) {
        assert!(from_ns < until_ns, "empty outage window");
        self.windows.push((edge, from_ns, until_ns));
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn is_up(&self, edge: usize, t_ns: u64) -> bool {
        !self
            .windows
            .iter()
            .any(|&(e, a, b)| e == edge && t_ns >= a && t_ns < b)
    }

    /// Earliest time `>= t_ns` at which `edge` is up (handles
    /// overlapping and back-to-back windows).
    pub fn next_up(&self, edge: usize, mut t_ns: u64) -> u64 {
        // Each pass either finds no covering window (done) or jumps to
        // a window end, which strictly increases t; bounded by the
        // number of windows.
        for _ in 0..=self.windows.len() {
            match self
                .windows
                .iter()
                .filter(|&&(e, a, b)| e == edge && t_ns >= a && t_ns < b)
                .map(|&(_, _, b)| b)
                .max()
            {
                Some(end) => t_ns = end,
                None => return t_ns,
            }
        }
        t_ns
    }
}

/// Undirected connected graph over nodes `0..n`.
#[derive(Debug, Clone)]
pub struct Graph {
    n: usize,
    /// Canonical edge list, each with `i < j`, sorted.
    edges: Vec<(usize, usize)>,
    /// Per-node sorted neighbor lists.
    neighbors: Vec<Vec<usize>>,
}

impl Graph {
    /// Build from an explicit edge list (self-loops and duplicates are
    /// rejected). Panics if not connected — decentralized learning
    /// assumes a connected G (paper §2.1 / Assumption 4).
    pub fn from_edges(n: usize, raw: &[(usize, usize)]) -> Graph {
        // n == 0 builds the empty graph (degree queries return `None`,
        // `is_connected` is false); the execution engines validate
        // non-emptiness where they actually require it.
        let mut edges: Vec<(usize, usize)> = raw
            .iter()
            .map(|&(a, b)| {
                assert!(a != b, "self-loop {a}");
                assert!(a < n && b < n, "edge ({a},{b}) out of range");
                (a.min(b), a.max(b))
            })
            .collect();
        edges.sort_unstable();
        let before = edges.len();
        edges.dedup();
        assert_eq!(before, edges.len(), "duplicate edges");
        let mut neighbors = vec![Vec::new(); n];
        for &(a, b) in &edges {
            neighbors[a].push(b);
            neighbors[b].push(a);
        }
        for nb in &mut neighbors {
            nb.sort_unstable();
        }
        let g = Graph {
            n,
            edges,
            neighbors,
        };
        assert!(g.n == 0 || g.is_connected(), "graph must be connected");
        g
    }

    pub fn build(topology: Topology, n: usize) -> Graph {
        match topology {
            Topology::Chain => Graph::chain(n),
            Topology::Ring => Graph::ring(n),
            Topology::MultiplexRing => Graph::multiplex_ring(n),
            Topology::FullyConnected => Graph::complete(n),
            Topology::Star => Graph::star(n),
            Topology::Random {
                extra_p_percent,
                seed,
            } => Graph::random(n, extra_p_percent as f64 / 100.0, seed),
        }
    }

    pub fn chain(n: usize) -> Graph {
        let edges: Vec<_> = (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect();
        Graph::from_edges(n, &edges)
    }

    pub fn ring(n: usize) -> Graph {
        assert!(n >= 3, "ring needs >= 3 nodes");
        let mut edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1)).collect();
        edges.push((n - 1, 0));
        Graph::from_edges(n, &edges)
    }

    /// Ring plus the 2-hop chords — every node has degree 4 (for n >= 5).
    pub fn multiplex_ring(n: usize) -> Graph {
        assert!(n >= 5, "multiplex ring needs >= 5 nodes");
        let mut edges = Vec::new();
        for i in 0..n {
            edges.push((i, (i + 1) % n));
            edges.push((i, (i + 2) % n));
        }
        // from_edges canonicalizes + dedups via assert, so dedup here.
        let mut canon: Vec<_> = edges
            .into_iter()
            .map(|(a, b)| (a.min(b), a.max(b)))
            .collect();
        canon.sort_unstable();
        canon.dedup();
        Graph::from_edges(n, &canon)
    }

    pub fn complete(n: usize) -> Graph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Graph::from_edges(n, &edges)
    }

    pub fn star(n: usize) -> Graph {
        assert!(n >= 2);
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Graph::from_edges(n, &edges)
    }

    /// Spanning ring + independent extra edges with probability `p`.
    pub fn random(n: usize, p: f64, seed: u64) -> Graph {
        let mut rng = Pcg::new(seed);
        let mut edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        for i in 0..n {
            for j in (i + 2)..n {
                if (i, j) == (0, n - 1) {
                    continue; // already a ring edge
                }
                if rng.bernoulli(p) {
                    edges.push((i, j));
                }
            }
        }
        let mut canon: Vec<_> = edges
            .into_iter()
            .map(|(a, b)| (a.min(b), a.max(b)))
            .collect();
        canon.sort_unstable();
        canon.dedup();
        Graph::from_edges(n, &canon)
    }

    // ---- accessors -------------------------------------------------------

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.neighbors[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    /// N_min of Theorem 1.  `None` on an empty graph (there is no
    /// minimum over zero nodes — callers decide, instead of a panic
    /// deep inside a sweep).
    pub fn min_degree(&self) -> Option<usize> {
        (0..self.n).map(|i| self.degree(i)).min()
    }

    /// N_max of Theorem 1.  `None` on an empty graph.
    pub fn max_degree(&self) -> Option<usize> {
        (0..self.n).map(|i| self.degree(i)).max()
    }

    /// Index of edge `(i, j)` in the canonical list.
    pub fn edge_index(&self, i: usize, j: usize) -> Option<usize> {
        let key = (i.min(j), i.max(j));
        self.edges.binary_search(&key).ok()
    }

    /// The Eq. (2) sign: `A_{i|j} = +I` if `i < j` else `-I`.
    #[inline]
    pub fn edge_sign(&self, i: usize, j: usize) -> f32 {
        if i < j {
            1.0
        } else {
            -1.0
        }
    }

    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return false;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.neighbors[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.n
    }

    /// Metropolis–Hastings mixing weights (paper §D.1): for `(i, j) ∈ E`
    /// `W_ij = 1 / (1 + max(deg_i, deg_j))`, `W_ii = 1 − Σ_j W_ij`.
    /// Symmetric and doubly stochastic.
    pub fn mh_weights(&self) -> Vec<Vec<f64>> {
        let n = self.n;
        let mut w = vec![vec![0.0; n]; n];
        for &(i, j) in &self.edges {
            let wij = 1.0 / (1.0 + self.degree(i).max(self.degree(j)) as f64);
            w[i][j] = wij;
            w[j][i] = wij;
        }
        for (i, row) in w.iter_mut().enumerate() {
            let off: f64 = row.iter().sum();
            row[i] = 1.0 - off;
        }
        w
    }

    /// ASCII rendering of the adjacency structure (Fig. 2 stand-in).
    pub fn ascii_viz(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} nodes, {} edges, degree [{}, {}]\n",
            self.n,
            self.edges.len(),
            self.min_degree().unwrap_or(0),
            self.max_degree().unwrap_or(0)
        ));
        out.push_str("    ");
        for j in 0..self.n {
            out.push_str(&format!("{j:>2} "));
        }
        out.push('\n');
        for i in 0..self.n {
            out.push_str(&format!("{i:>2} |"));
            for j in 0..self.n {
                let c = if i == j {
                    " . "
                } else if self.edge_index(i, j).is_some() {
                    " # "
                } else {
                    "   "
                };
                out.push_str(c);
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topologies_eight_nodes() {
        // Degrees match Fig. 2: chain 1..2, ring 2, multiplex ring 4,
        // complete 7.
        let chain = Graph::chain(8);
        assert_eq!(chain.edges().len(), 7);
        assert_eq!(chain.min_degree(), Some(1));
        assert_eq!(chain.max_degree(), Some(2));

        let ring = Graph::ring(8);
        assert_eq!(ring.edges().len(), 8);
        assert_eq!(ring.min_degree(), Some(2));
        assert_eq!(ring.max_degree(), Some(2));

        let mring = Graph::multiplex_ring(8);
        assert_eq!(mring.edges().len(), 16);
        assert_eq!(mring.min_degree(), Some(4));
        assert_eq!(mring.max_degree(), Some(4));

        let full = Graph::complete(8);
        assert_eq!(full.edges().len(), 28);
        assert_eq!(full.min_degree(), Some(7));
    }

    #[test]
    fn empty_graph_degrees_are_none_not_panic() {
        let g = Graph::from_edges(0, &[]);
        assert_eq!(g.min_degree(), None);
        assert_eq!(g.max_degree(), None);
        // The ASCII rendering degrades gracefully too.
        assert!(g.ascii_viz().contains("0 nodes"));
    }

    #[test]
    fn edge_lookup_and_sign() {
        let g = Graph::ring(5);
        assert!(g.edge_index(0, 1).is_some());
        assert!(g.edge_index(1, 0).is_some());
        assert!(g.edge_index(0, 2).is_none());
        assert_eq!(g.edge_sign(0, 1), 1.0);
        assert_eq!(g.edge_sign(1, 0), -1.0);
        // Constraint: A_{i|j} + A_{j|i} = 0 pairing (Eq. 2).
        for &(i, j) in g.edges() {
            assert_eq!(g.edge_sign(i, j) + g.edge_sign(j, i), 0.0);
        }
    }

    #[test]
    fn neighbors_sorted_and_symmetric() {
        let g = Graph::multiplex_ring(8);
        for i in 0..g.n() {
            let nb = g.neighbors(i);
            assert!(nb.windows(2).all(|w| w[0] < w[1]));
            for &j in nb {
                assert!(g.neighbors(j).contains(&i));
            }
        }
    }

    #[test]
    fn mh_weights_doubly_stochastic() {
        for g in [Graph::chain(8), Graph::ring(8), Graph::star(6)] {
            let w = g.mh_weights();
            for i in 0..g.n() {
                let row: f64 = w[i].iter().sum();
                assert!((row - 1.0).abs() < 1e-12);
                for j in 0..g.n() {
                    assert!((w[i][j] - w[j][i]).abs() < 1e-15);
                    assert!(w[i][j] >= -1e-15);
                    if i != j && g.edge_index(i, j).is_none() {
                        assert_eq!(w[i][j], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "connected")]
    fn disconnected_rejected() {
        let _ = Graph::from_edges(4, &[(0, 1), (2, 3)]);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn self_loop_rejected() {
        let _ = Graph::from_edges(3, &[(0, 0), (0, 1), (1, 2)]);
    }

    #[test]
    fn random_graph_connected_and_deterministic() {
        let a = Graph::random(12, 0.2, 7);
        let b = Graph::random(12, 0.2, 7);
        assert!(a.is_connected());
        assert_eq!(a.edges(), b.edges());
        let c = Graph::random(12, 0.2, 8);
        assert_ne!(a.edges(), c.edges());
    }

    #[test]
    fn topology_names_roundtrip() {
        for t in Topology::paper_set() {
            assert_eq!(Topology::from_name(t.name()), Some(t));
        }
        assert_eq!(Topology::from_name("nope"), None);
    }

    #[test]
    fn outage_schedule_windows() {
        let mut s = OutageSchedule::new();
        assert!(s.is_empty());
        assert!(s.is_up(0, 123));
        assert_eq!(s.next_up(0, 123), 123);
        s.add(0, 100, 200);
        s.add(0, 180, 300); // overlapping
        s.add(1, 50, 60);
        assert!(!s.is_empty());
        assert!(s.is_up(0, 99));
        assert!(!s.is_up(0, 100));
        assert!(!s.is_up(0, 250));
        assert!(s.is_up(0, 300)); // until is exclusive
        assert!(s.is_up(2, 150)); // other edges unaffected
        // next_up hops across the overlapping chain.
        assert_eq!(s.next_up(0, 150), 300);
        assert_eq!(s.next_up(0, 0), 0);
        assert_eq!(s.next_up(1, 55), 60);
    }

    #[test]
    #[should_panic(expected = "empty outage window")]
    fn outage_rejects_empty_window() {
        let mut s = OutageSchedule::new();
        s.add(0, 10, 10);
    }

    #[test]
    fn ascii_viz_contains_all_nodes() {
        let viz = Graph::ring(5).ascii_viz();
        assert!(viz.contains("5 nodes, 5 edges"));
        assert!(viz.lines().count() >= 7);
    }
}
