//! Network topology substrate: the undirected connected graph
//! G = (V, E) of §2.1, the four topologies of the paper's §5.3 (chain,
//! ring, multiplex ring, fully connected), Metropolis–Hastings gossip
//! weights (Xiao–Boyd–Kim 2007, used by D-PSGD / PowerGossip per the
//! paper's §D.1), and the A_{i|j} = ±I edge-sign convention of Eq. (2).
//!
//! ## Dynamic topology
//!
//! The base [`Graph`] stays immutable — it is the **universe** of edges
//! a run may ever use.  Time variation is layered on top:
//!
//! * [`ChurnSchedule`] — when edges/nodes are out of service, in
//!   virtual nanoseconds.  Two kinds of downtime
//!   ([`DownKind`]): an **outage** holds traffic and preserves per-edge
//!   protocol state (the remove/re-add pair that *preserves* state —
//!   the old `OutageSchedule` semantics, folded in here), while
//!   **churn** removes the edge from the topology: in-flight frames
//!   drop, both endpoints tear down per-edge state (duals, codec
//!   residuals, PowerGossip conversations), and a re-add is a fresh
//!   edge *epoch*.  Node join/leave is churn on every incident edge.
//! * [`TopologyView`] — the epoch-stamped live snapshot the execution
//!   engines hand to every `NodeStateMachine` callback.  Each canonical
//!   edge carries an [`EdgeLife`]: `live`, the incarnation `epoch`
//!   (0 = as constructed; each churn re-add bumps it), and the
//!   `activation_round` at which the incarnation starts carrying
//!   traffic (assigned by the engine so both endpoints open the edge at
//!   the same round number).  An empty schedule keeps the view at
//!   version 0 forever — static runs take the exact legacy code paths
//!   and replay bit-identically.

use crate::util::rng::{splitmix64, streams, Pcg};

/// The topologies evaluated in the paper (§5.3, Fig. 2) plus extras.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    Chain,
    Ring,
    /// Ring plus all 2-hop chords (the paper's “multiplex ring”).
    MultiplexRing,
    FullyConnected,
    Star,
    /// `rows × cols` wrap-around grid (`torus:RxC` on the CLI), node
    /// id `r * cols + c` — row-major, so contiguous block partitions
    /// keep each block's internal edges dominant.
    Torus { rows: u32, cols: u32 },
    /// Connected Erdős–Rényi random graph: G(n, p) resampled until
    /// connected ([`Graph::random_connected`]), `p` given in percent.
    Random { extra_p_percent: u8, seed: u64 },
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Chain => "chain",
            Topology::Ring => "ring",
            Topology::MultiplexRing => "multiplex-ring",
            Topology::FullyConnected => "fully-connected",
            Topology::Star => "star",
            Topology::Torus { .. } => "torus",
            Topology::Random { .. } => "random",
        }
    }

    /// Parse from CLI names.  `torus:RxC` carries its shape inline
    /// (e.g. `torus:16x32` — a 512-node torus); both sides must be at
    /// least 2 so every node has degree 4.
    pub fn from_name(name: &str) -> Option<Topology> {
        if let Some(shape) = name.strip_prefix("torus:") {
            let (r, c) = shape.split_once('x')?;
            let rows: u32 = r.parse().ok()?;
            let cols: u32 = c.parse().ok()?;
            if rows < 2 || cols < 2 {
                return None;
            }
            return Some(Topology::Torus { rows, cols });
        }
        match name {
            "chain" => Some(Topology::Chain),
            "ring" => Some(Topology::Ring),
            "multiplex-ring" | "multiplex_ring" | "multiplex" => {
                Some(Topology::MultiplexRing)
            }
            "fully-connected" | "complete" | "full" => {
                Some(Topology::FullyConnected)
            }
            "star" => Some(Topology::Star),
            _ => None,
        }
    }

    /// The paper's four evaluation topologies (§5.3 order).
    pub fn paper_set() -> [Topology; 4] {
        [
            Topology::Chain,
            Topology::Ring,
            Topology::MultiplexRing,
            Topology::FullyConnected,
        ]
    }
}

/// Why a scheduled edge is out of service — the semantic fork between
/// the old outage behavior and real topology churn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownKind {
    /// Link outage: traffic queued on the edge is *held* until the
    /// window ends (messages are delayed, never lost) and per-edge
    /// protocol state survives — a remove/re-add pair that preserves
    /// state.
    Outage,
    /// Topology churn: the edge leaves the graph.  In-flight frames
    /// drain as typed drops, both endpoints retire their per-edge state
    /// (dual `z_{i|j}`, error-feedback residuals, PowerGossip q̂ /
    /// conversations), and a later re-add is a fresh [`EdgeLife`]
    /// epoch.
    Churn,
}

/// The CLI grammar for `--churn` (comma-separated items; `--outage
/// e@from..to` is sugar for `outage:` items).
pub const CHURN_GRAMMAR: &str = "edge:<e>@<from_ns>..<to_ns> | \
     outage:<e>@<from_ns>..<to_ns> | node:<n>@join:<ns> | \
     node:<n>@leave:<ns> | random:<rate>[:<seed>]";

/// Default slot length of the `random:<rate>` churn rule: each edge is
/// independently down (churn-kind) in each 10 ms slot with the given
/// probability.
pub const DEFAULT_CHURN_SLOT_NS: u64 = 10_000_000;

/// How often [`Graph::random_connected`] resamples before giving up.
pub const RANDOM_CONNECT_ATTEMPTS: u64 = 64;

/// Time-varying topology schedule, in virtual nanoseconds: edge
/// outage/churn windows, node join/leave, and an optional seeded random
/// edge-churn rule.  Generalizes the old `OutageSchedule` (an outage is
/// now just a [`DownKind::Outage`] window; the interval lookup is
/// shared).  The threaded engine accepts only epoch-constant (empty)
/// schedules; the virtual-time engine turns churn boundaries into
/// first-class events.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChurnSchedule {
    /// `(edge index, from_ns inclusive, until_ns exclusive, kind)`.
    /// `until_ns == u64::MAX` means "for the rest of the run".
    windows: Vec<(usize, u64, u64, DownKind)>,
    /// `(node, from_ns, until_ns)` — the node is absent (all incident
    /// edges churn-down) during the window.
    node_windows: Vec<(usize, u64, u64)>,
    /// `(rate, seed, slot_ns)` — i.i.d. per-edge per-slot churn.
    random: Option<(f64, u64, u64)>,
}

impl ChurnSchedule {
    pub fn new() -> ChurnSchedule {
        ChurnSchedule::default()
    }

    /// Schedule an outage (state-preserving hold) on `edge` during
    /// `[from_ns, until_ns)`.
    pub fn add_outage(&mut self, edge: usize, from_ns: u64, until_ns: u64) {
        assert!(from_ns < until_ns, "empty outage window");
        self.windows.push((edge, from_ns, until_ns, DownKind::Outage));
    }

    /// Schedule churn (state-tearing removal) of `edge` during
    /// `[from_ns, until_ns)`.
    pub fn add_edge_down(&mut self, edge: usize, from_ns: u64, until_ns: u64) {
        assert!(from_ns < until_ns, "empty churn window");
        self.windows.push((edge, from_ns, until_ns, DownKind::Churn));
    }

    /// Node `node` leaves the topology at `t_ns` (and never rejoins
    /// unless a later `add_node_absent`-style window says otherwise).
    pub fn add_node_leave(&mut self, node: usize, t_ns: u64) {
        self.node_windows.push((node, t_ns, u64::MAX));
    }

    /// Node `node` joins the topology at `t_ns` (absent before that).
    pub fn add_node_join(&mut self, node: usize, t_ns: u64) {
        assert!(t_ns > 0, "join at t=0 is a no-op");
        self.node_windows.push((node, 0, t_ns));
    }

    /// Node `node` is absent during `[from_ns, until_ns)`.
    pub fn add_node_absent(&mut self, node: usize, from_ns: u64,
                           until_ns: u64) {
        assert!(from_ns < until_ns, "empty node-absence window");
        self.node_windows.push((node, from_ns, until_ns));
    }

    /// i.i.d. random edge churn: every edge is independently down
    /// (churn-kind) in each [`DEFAULT_CHURN_SLOT_NS`] slot with
    /// probability `rate`, derived deterministically from `seed`.
    pub fn random_edge_churn(&mut self, rate: f64, seed: u64) {
        self.random_edge_churn_with_slot(rate, seed, DEFAULT_CHURN_SLOT_NS);
    }

    /// [`ChurnSchedule::random_edge_churn`] with an explicit slot
    /// length (tests use short slots to pack many transitions into a
    /// short simulated horizon).
    pub fn random_edge_churn_with_slot(&mut self, rate: f64, seed: u64,
                                       slot_ns: u64) {
        assert!((0.0..1.0).contains(&rate), "churn rate must be in [0, 1)");
        assert!(slot_ns > 0, "churn slot must be positive");
        self.random = Some((rate, seed, slot_ns));
    }

    /// Fold another schedule's windows/events into this one (the CLI's
    /// `--outage` sugar merges into `--churn`).  A second random rule
    /// replaces the first.
    pub fn merge(&mut self, other: ChurnSchedule) {
        self.windows.extend(other.windows);
        self.node_windows.extend(other.node_windows);
        if other.random.is_some() {
            self.random = other.random;
        }
    }

    /// No windows, no node events, no random rule — the static
    /// schedule, pinned bit-identical to the pre-churn code paths.
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
            && self.node_windows.is_empty()
            && self.random.is_none()
    }

    /// Whether anything in the schedule *tears down* topology (churn
    /// windows, node events, or the random rule) — outage-only
    /// schedules keep the topology epoch-constant.
    pub fn has_churn(&self) -> bool {
        self.windows.iter().any(|&(_, _, _, k)| k == DownKind::Churn)
            || !self.node_windows.is_empty()
            || self.random.is_some()
    }

    /// Largest edge index referenced by an explicit window (validation).
    pub fn max_edge_index(&self) -> Option<usize> {
        self.windows.iter().map(|&(e, _, _, _)| e).max()
    }

    /// Largest node index referenced by a node window (validation).
    pub fn max_node_index(&self) -> Option<usize> {
        self.node_windows.iter().map(|&(n, _, _)| n).max()
    }

    /// Short label for result tables (`static` when nothing churns).
    pub fn label(&self) -> String {
        if !self.has_churn() {
            return "static".to_string();
        }
        if let Some((rate, _, _)) = self.random {
            if self.windows.iter().all(|w| w.3 == DownKind::Outage)
                && self.node_windows.is_empty()
            {
                return format!("random:{rate}");
            }
        }
        "churn".to_string()
    }

    // -- the single interval lookup (shared by both kinds) -------------

    fn window_covers(edge: usize, t_ns: u64, kind: DownKind,
                     w: &(usize, u64, u64, DownKind)) -> bool {
        w.0 == edge && w.3 == kind && t_ns >= w.1 && t_ns < w.2
    }

    /// Whether an *outage* window holds edge `edge` at `t_ns`.
    pub fn is_outage_down(&self, edge: usize, t_ns: u64) -> bool {
        self.windows
            .iter()
            .any(|w| Self::window_covers(edge, t_ns, DownKind::Outage, w))
    }

    /// Earliest time `>= t_ns` at which no outage window holds `edge`
    /// (handles overlapping and back-to-back windows).  Churn windows
    /// do not hold traffic — their frames drop instead.
    pub fn outage_next_up(&self, edge: usize, mut t_ns: u64) -> u64 {
        // Each pass either finds no covering window (done) or jumps to
        // a window end, which strictly increases t; bounded by the
        // number of windows.
        for _ in 0..=self.windows.len() {
            match self
                .windows
                .iter()
                .filter(|w| Self::window_covers(edge, t_ns, DownKind::Outage, w))
                .map(|&(_, _, b, _)| b)
                .max()
            {
                Some(end) => t_ns = end,
                None => return t_ns,
            }
        }
        t_ns
    }

    /// Whether edge `edge = (i, j)` is churned out of the topology at
    /// `t_ns` — by an explicit churn window, by either endpoint being
    /// absent, or by the random rule.
    pub fn churned_down(&self, edge: usize, i: usize, j: usize,
                        t_ns: u64) -> bool {
        if self
            .windows
            .iter()
            .any(|w| Self::window_covers(edge, t_ns, DownKind::Churn, w))
        {
            return true;
        }
        if self
            .node_windows
            .iter()
            .any(|&(n, a, b)| (n == i || n == j) && t_ns >= a && t_ns < b)
        {
            return true;
        }
        if let Some((rate, seed, slot_ns)) = self.random {
            let slot = t_ns / slot_ns;
            let mut rng =
                Pcg::derive(seed, &[streams::CHURN, edge as u64, slot]);
            return rng.bernoulli(rate);
        }
        false
    }

    /// Earliest churn-kind transition boundary strictly after `t_ns`
    /// (window edges, node events, or the next random slot).  Outage
    /// windows are not transitions — they never change the topology.
    pub fn next_transition_after(&self, t_ns: u64) -> Option<u64> {
        let mut next: Option<u64> = None;
        let mut consider = |b: u64| {
            if b > t_ns && b < u64::MAX {
                next = Some(next.map_or(b, |n| n.min(b)));
            }
        };
        for &(_, a, b, kind) in &self.windows {
            if kind == DownKind::Churn {
                consider(a);
                consider(b);
            }
        }
        for &(_, a, b) in &self.node_windows {
            consider(a);
            consider(b);
        }
        if let Some((_, _, slot_ns)) = self.random {
            consider((t_ns / slot_ns + 1).saturating_mul(slot_ns));
        }
        next
    }

    /// Parse the `--churn` grammar (see [`CHURN_GRAMMAR`]): a comma
    /// list of `edge:<e>@<a>..<b>`, `outage:<e>@<a>..<b>`,
    /// `node:<n>@join:<ns>`, `node:<n>@leave:<ns>`, and
    /// `random:<rate>[:<seed>]` items.
    pub fn parse(s: &str) -> Result<ChurnSchedule, String> {
        fn window(rest: &str, what: &str) -> Result<(usize, u64, u64), String> {
            let (e, range) = rest.split_once('@').ok_or_else(|| {
                format!("{what} `{rest}`: expected <e>@<from>..<to> \
                         (grammar: {CHURN_GRAMMAR})")
            })?;
            let e: usize = e.parse().map_err(|_| {
                format!("{what} `{rest}`: `{e}` is not an edge index")
            })?;
            let (a, b) = range.split_once("..").ok_or_else(|| {
                format!("{what} `{rest}`: expected <from_ns>..<to_ns>")
            })?;
            let a: u64 = a.parse().map_err(|_| {
                format!("{what} `{rest}`: `{a}` is not a time in ns")
            })?;
            let b: u64 = b.parse().map_err(|_| {
                format!("{what} `{rest}`: `{b}` is not a time in ns")
            })?;
            if a >= b {
                return Err(format!("{what} `{rest}`: empty window"));
            }
            Ok((e, a, b))
        }
        let mut sched = ChurnSchedule::new();
        for item in s.split(',').filter(|p| !p.trim().is_empty()) {
            let item = item.trim();
            if let Some(rest) = item.strip_prefix("edge:") {
                let (e, a, b) = window(rest, "edge churn")?;
                sched.add_edge_down(e, a, b);
            } else if let Some(rest) = item.strip_prefix("outage:") {
                let (e, a, b) = window(rest, "outage")?;
                sched.add_outage(e, a, b);
            } else if let Some(rest) = item.strip_prefix("node:") {
                let (n, ev) = rest.split_once('@').ok_or_else(|| {
                    format!("node event `{rest}`: expected \
                             <n>@join:<ns> or <n>@leave:<ns>")
                })?;
                let n: usize = n.parse().map_err(|_| {
                    format!("node event `{rest}`: `{n}` is not a node index")
                })?;
                if let Some(t) = ev.strip_prefix("join:") {
                    let t: u64 = t.parse().map_err(|_| {
                        format!("node event `{rest}`: `{t}` is not a time")
                    })?;
                    if t == 0 {
                        return Err(format!(
                            "node event `{rest}`: join at t=0 is a no-op"
                        ));
                    }
                    sched.add_node_join(n, t);
                } else if let Some(t) = ev.strip_prefix("leave:") {
                    let t: u64 = t.parse().map_err(|_| {
                        format!("node event `{rest}`: `{t}` is not a time")
                    })?;
                    sched.add_node_leave(n, t);
                } else {
                    return Err(format!(
                        "node event `{rest}`: expected join:<ns> or \
                         leave:<ns> (grammar: {CHURN_GRAMMAR})"
                    ));
                }
            } else if let Some(rest) = item.strip_prefix("random:") {
                let (rate, seed) = match rest.split_once(':') {
                    Some((r, s)) => {
                        let seed: u64 = s.parse().map_err(|_| {
                            format!("random churn `{rest}`: `{s}` is not \
                                     a seed")
                        })?;
                        (r, seed)
                    }
                    None => (rest, 0),
                };
                let rate: f64 = rate.parse().map_err(|_| {
                    format!("random churn `{rest}`: `{rate}` is not a rate")
                })?;
                if !(0.0..1.0).contains(&rate) {
                    return Err(format!(
                        "random churn `{rest}`: rate must be in [0, 1)"
                    ));
                }
                sched.random_edge_churn(rate, seed);
            } else {
                return Err(format!(
                    "unknown churn item `{item}` (grammar: {CHURN_GRAMMAR})"
                ));
            }
        }
        Ok(sched)
    }
}

/// One canonical edge's current incarnation in a [`TopologyView`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeLife {
    /// Whether the edge is currently part of the topology.
    pub live: bool,
    /// Incarnation count: 0 = the edge as constructed; every churn
    /// re-add bumps it, so per-edge state (duals, codec residuals, q̂
    /// warm starts) from an earlier incarnation can never be
    /// resurrected against the new one.
    pub epoch: u32,
    /// First exchange round this incarnation carries traffic (0 for the
    /// initial incarnation).  The engine assigns it on revival as
    /// `1 + max(endpoint rounds)` so both endpoints open the edge at
    /// the same round number — which is what keeps sync rounds in
    /// lockstep and shared-seed/conversation derivations aligned.
    pub activation_round: usize,
}

/// Epoch-stamped snapshot of the live topology, indexed by the base
/// [`Graph`]'s canonical edge list.  The engines thread it through
/// every `NodeStateMachine` callback; machines compare its per-edge
/// epochs against their cached ones to run birth/death lifecycle.
/// `version` is bumped on every transition, so an unchanged view (the
/// static case, version 0 forever) costs one integer compare per
/// callback.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyView {
    version: u64,
    edges: Vec<EdgeLife>,
}

impl TopologyView {
    /// The static view: every edge live, epoch 0, active from round 0.
    pub fn full(edge_count: usize) -> TopologyView {
        TopologyView {
            version: 0,
            edges: vec![
                EdgeLife { live: true, epoch: 0, activation_round: 0 };
                edge_count
            ],
        }
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Monotone change counter (0 = the static full view).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn edge_life(&self, edge: usize) -> EdgeLife {
        self.edges[edge]
    }

    pub fn is_live(&self, edge: usize) -> bool {
        self.edges[edge].live
    }

    /// Number of currently-live edges at `node`.
    pub fn live_degree(&self, graph: &Graph, node: usize) -> usize {
        graph
            .neighbors(node)
            .iter()
            .filter(|&&j| {
                graph
                    .edge_index(node, j)
                    .map(|e| self.edges[e].live)
                    .unwrap_or(false)
            })
            .count()
    }

    /// Remove `edge` from the topology (no-op if already dead).
    pub fn kill_edge(&mut self, edge: usize) {
        if self.edges[edge].live {
            self.edges[edge].live = false;
            self.version += 1;
        }
    }

    /// Re-add `edge` as a fresh incarnation activating at
    /// `activation_round`.
    pub fn revive_edge(&mut self, edge: usize, activation_round: usize) {
        let life = &mut self.edges[edge];
        debug_assert!(!life.live, "revive of a live edge");
        life.live = true;
        life.epoch += 1;
        life.activation_round = activation_round;
        self.version += 1;
    }
}

/// Undirected connected graph over nodes `0..n`.
#[derive(Debug, Clone)]
pub struct Graph {
    n: usize,
    /// Canonical edge list, each with `i < j`, sorted.
    edges: Vec<(usize, usize)>,
    /// Per-node sorted neighbor lists.
    neighbors: Vec<Vec<usize>>,
}

impl Graph {
    /// Build from an explicit edge list (self-loops and duplicates are
    /// rejected). Panics if not connected — decentralized learning
    /// assumes a connected G (paper §2.1 / Assumption 4).
    pub fn from_edges(n: usize, raw: &[(usize, usize)]) -> Graph {
        // n == 0 builds the empty graph (degree queries return `None`,
        // `is_connected` is false); the execution engines validate
        // non-emptiness where they actually require it.
        let g = Graph::from_edges_any(n, raw);
        assert!(g.n == 0 || g.is_connected(), "graph must be connected");
        g
    }

    /// [`Graph::from_edges`] without the connectivity assertion:
    /// self-loops and duplicates are still rejected, but the result may
    /// be disconnected.  This is the substrate for [`Graph::random`]
    /// (true Erdős–Rényi sampling) and for tests that reason about
    /// components explicitly; protocol drivers want [`Graph::from_edges`]
    /// or [`Graph::random_connected`].
    pub fn from_edges_any(n: usize, raw: &[(usize, usize)]) -> Graph {
        let mut edges: Vec<(usize, usize)> = raw
            .iter()
            .map(|&(a, b)| {
                assert!(a != b, "self-loop {a}");
                assert!(a < n && b < n, "edge ({a},{b}) out of range");
                (a.min(b), a.max(b))
            })
            .collect();
        edges.sort_unstable();
        let before = edges.len();
        edges.dedup();
        assert_eq!(before, edges.len(), "duplicate edges");
        let mut neighbors = vec![Vec::new(); n];
        for &(a, b) in &edges {
            neighbors[a].push(b);
            neighbors[b].push(a);
        }
        for nb in &mut neighbors {
            nb.sort_unstable();
        }
        Graph {
            n,
            edges,
            neighbors,
        }
    }

    pub fn build(topology: Topology, n: usize) -> Graph {
        match topology {
            Topology::Chain => Graph::chain(n),
            Topology::Ring => Graph::ring(n),
            Topology::MultiplexRing => Graph::multiplex_ring(n),
            Topology::FullyConnected => Graph::complete(n),
            Topology::Star => Graph::star(n),
            Topology::Torus { rows, cols } => {
                let (r, c) = (rows as usize, cols as usize);
                assert_eq!(
                    n,
                    r * c,
                    "torus:{rows}x{cols} is a {}-node topology, but the \
                     run asked for {n} nodes",
                    r * c
                );
                Graph::torus(r, c)
            }
            // Experiment drivers need a connected G (Assumption 4):
            // the topology enum always takes the connected sampler.
            Topology::Random {
                extra_p_percent,
                seed,
            } => Graph::random_connected(n, extra_p_percent as f64 / 100.0,
                                         seed),
        }
    }

    pub fn chain(n: usize) -> Graph {
        let edges: Vec<_> = (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect();
        Graph::from_edges(n, &edges)
    }

    pub fn ring(n: usize) -> Graph {
        assert!(n >= 3, "ring needs >= 3 nodes");
        let mut edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1)).collect();
        edges.push((n - 1, 0));
        Graph::from_edges(n, &edges)
    }

    /// Ring plus the 2-hop chords — every node has degree 4 (for n >= 5).
    pub fn multiplex_ring(n: usize) -> Graph {
        assert!(n >= 5, "multiplex ring needs >= 5 nodes");
        let mut edges = Vec::new();
        for i in 0..n {
            edges.push((i, (i + 1) % n));
            edges.push((i, (i + 2) % n));
        }
        // from_edges canonicalizes + dedups via assert, so dedup here.
        let mut canon: Vec<_> = edges
            .into_iter()
            .map(|(a, b)| (a.min(b), a.max(b)))
            .collect();
        canon.sort_unstable();
        canon.dedup();
        Graph::from_edges(n, &canon)
    }

    /// `rows × cols` wrap-around grid: node `(r, c)` has id
    /// `r * cols + c` and links to its four grid neighbors modulo the
    /// wrap.  With a side of exactly 2 the wrap edge coincides with the
    /// adjacent edge, so those pairs dedup to a single canonical edge
    /// (degree 3 on that axis instead of 4) — same convention as
    /// [`Graph::multiplex_ring`]'s chord dedup.
    pub fn torus(rows: usize, cols: usize) -> Graph {
        assert!(rows >= 2 && cols >= 2, "torus needs both sides >= 2");
        let n = rows * cols;
        let id = |r: usize, c: usize| r * cols + c;
        let mut canon: Vec<(usize, usize)> = Vec::with_capacity(2 * n);
        for r in 0..rows {
            for c in 0..cols {
                let a = id(r, c);
                for b in [id(r, (c + 1) % cols), id((r + 1) % rows, c)] {
                    canon.push((a.min(b), a.max(b)));
                }
            }
        }
        canon.sort_unstable();
        canon.dedup();
        Graph::from_edges(n, &canon)
    }

    pub fn complete(n: usize) -> Graph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Graph::from_edges(n, &edges)
    }

    pub fn star(n: usize) -> Graph {
        assert!(n >= 2);
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Graph::from_edges(n, &edges)
    }

    /// True Erdős–Rényi G(n, p): every pair is an edge independently
    /// with probability `p`.  **May be disconnected** — there is no
    /// implicit spanning structure.  Protocol drivers need a connected
    /// G (Assumption 4) and should call [`Graph::random_connected`];
    /// this form exists for churn scenarios and component-aware tests.
    pub fn random(n: usize, p: f64, seed: u64) -> Graph {
        let mut rng = Pcg::new(seed);
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.bernoulli(p) {
                    edges.push((i, j));
                }
            }
        }
        Graph::from_edges_any(n, &edges)
    }

    /// G(n, p) conditioned on connectivity: resamples with derived
    /// seeds up to [`RANDOM_CONNECT_ATTEMPTS`] times and panics with a
    /// clear message if `p` is too small to ever connect `n` nodes —
    /// connectivity is an explicit choice here, not a silent property.
    pub fn random_connected(n: usize, p: f64, seed: u64) -> Graph {
        for attempt in 0..RANDOM_CONNECT_ATTEMPTS {
            let g = Graph::random(n, p, splitmix64(seed ^ attempt));
            if g.is_connected() {
                return g;
            }
        }
        panic!(
            "random_connected(n={n}, p={p}): no connected sample in \
             {RANDOM_CONNECT_ATTEMPTS} attempts — raise p"
        );
    }

    // ---- accessors -------------------------------------------------------

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.neighbors[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    /// N_min of Theorem 1.  `None` on an empty graph (there is no
    /// minimum over zero nodes — callers decide, instead of a panic
    /// deep inside a sweep).
    pub fn min_degree(&self) -> Option<usize> {
        (0..self.n).map(|i| self.degree(i)).min()
    }

    /// N_max of Theorem 1.  `None` on an empty graph.
    pub fn max_degree(&self) -> Option<usize> {
        (0..self.n).map(|i| self.degree(i)).max()
    }

    /// Index of edge `(i, j)` in the canonical list.
    pub fn edge_index(&self, i: usize, j: usize) -> Option<usize> {
        let key = (i.min(j), i.max(j));
        self.edges.binary_search(&key).ok()
    }

    /// The Eq. (2) sign: `A_{i|j} = +I` if `i < j` else `-I`.
    #[inline]
    pub fn edge_sign(&self, i: usize, j: usize) -> f32 {
        if i < j {
            1.0
        } else {
            -1.0
        }
    }

    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return false;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.neighbors[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.n
    }

    /// Metropolis–Hastings mixing weights (paper §D.1): for `(i, j) ∈ E`
    /// `W_ij = 1 / (1 + max(deg_i, deg_j))`, `W_ii = 1 − Σ_j W_ij`.
    /// Symmetric and doubly stochastic.
    pub fn mh_weights(&self) -> Vec<Vec<f64>> {
        let n = self.n;
        let mut w = vec![vec![0.0; n]; n];
        for &(i, j) in &self.edges {
            let wij = 1.0 / (1.0 + self.degree(i).max(self.degree(j)) as f64);
            w[i][j] = wij;
            w[j][i] = wij;
        }
        for (i, row) in w.iter_mut().enumerate() {
            let off: f64 = row.iter().sum();
            row[i] = 1.0 - off;
        }
        w
    }

    /// Number of edges crossing partition boundaries under the block
    /// partition `starts` (as produced by [`partition_blocks`]).  This
    /// is the communication surface of the parallel simulator: only
    /// cut-edge traffic leaves a partition's event queue.
    pub fn cut_edges(&self, starts: &[usize]) -> usize {
        self.edges
            .iter()
            .filter(|&&(i, j)| {
                block_owner(starts, i) != block_owner(starts, j)
            })
            .count()
    }

    /// ASCII rendering of the adjacency structure (Fig. 2 stand-in).
    pub fn ascii_viz(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} nodes, {} edges, degree [{}, {}]\n",
            self.n,
            self.edges.len(),
            self.min_degree().unwrap_or(0),
            self.max_degree().unwrap_or(0)
        ));
        out.push_str("    ");
        for j in 0..self.n {
            out.push_str(&format!("{j:>2} "));
        }
        out.push('\n');
        for i in 0..self.n {
            out.push_str(&format!("{i:>2} |"));
            for j in 0..self.n {
                let c = if i == j {
                    " . "
                } else if self.edge_index(i, j).is_some() {
                    " # "
                } else {
                    "   "
                };
                out.push_str(c);
            }
            out.push('\n');
        }
        out
    }
}

/// Contiguous block partition of node ids `0..n` into `parts` blocks of
/// near-equal size.  Returns `parts + 1` boundaries: block `p` owns
/// nodes `starts[p]..starts[p + 1]`.
///
/// Contiguous id blocks are the locality-aware choice for this repo's
/// standard topologies: on a ring they are *optimal* (exactly `2 *
/// parts` cut edges regardless of block size), and on a row-major torus
/// or chain they keep each block's internal edges dominant.  Blocks
/// differ in size by at most one node (the first `n % parts` blocks get
/// the extra node), so per-partition event load stays balanced.
pub fn partition_blocks(n: usize, parts: usize) -> Vec<usize> {
    let parts = parts.clamp(1, n.max(1));
    let (q, r) = (n / parts, n % parts);
    let mut starts = Vec::with_capacity(parts + 1);
    let mut at = 0usize;
    starts.push(at);
    for p in 0..parts {
        at += q + usize::from(p < r);
        starts.push(at);
    }
    starts
}

/// Which block of `starts` (from [`partition_blocks`]) owns `node`.
pub fn block_owner(starts: &[usize], node: usize) -> usize {
    debug_assert!(node < *starts.last().expect("nonempty starts"));
    // starts is sorted; find the last boundary <= node.
    match starts.binary_search(&node) {
        Ok(p) => p.min(starts.len() - 2),
        Err(ins) => ins - 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_partition_covers_and_balances() {
        for (n, parts) in [(10, 3), (7, 7), (1_000, 8), (5, 1), (3, 9)] {
            let starts = partition_blocks(n, parts);
            assert_eq!(starts[0], 0);
            assert_eq!(*starts.last().unwrap(), n);
            let sizes: Vec<usize> =
                starts.windows(2).map(|w| w[1] - w[0]).collect();
            let (min, max) = (
                sizes.iter().min().unwrap(),
                sizes.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
            assert!(*min >= 1, "empty block: {sizes:?}");
            for node in 0..n {
                let p = block_owner(&starts, node);
                assert!(starts[p] <= node && node < starts[p + 1]);
            }
        }
    }

    #[test]
    fn ring_block_partition_cut_is_two_per_part() {
        for parts in [2usize, 4, 8] {
            let g = Graph::ring(64);
            let starts = partition_blocks(64, parts);
            assert_eq!(g.cut_edges(&starts), parts, "ring cut");
        }
        // A ring's undirected cut under a block partition is one edge
        // per boundary; `parts` boundaries on a cycle.
        let g = Graph::complete(8);
        let starts = partition_blocks(8, 2);
        assert_eq!(g.cut_edges(&starts), 16, "K8 bisection: 4*4 pairs");
    }

    #[test]
    fn paper_topologies_eight_nodes() {
        // Degrees match Fig. 2: chain 1..2, ring 2, multiplex ring 4,
        // complete 7.
        let chain = Graph::chain(8);
        assert_eq!(chain.edges().len(), 7);
        assert_eq!(chain.min_degree(), Some(1));
        assert_eq!(chain.max_degree(), Some(2));

        let ring = Graph::ring(8);
        assert_eq!(ring.edges().len(), 8);
        assert_eq!(ring.min_degree(), Some(2));
        assert_eq!(ring.max_degree(), Some(2));

        let mring = Graph::multiplex_ring(8);
        assert_eq!(mring.edges().len(), 16);
        assert_eq!(mring.min_degree(), Some(4));
        assert_eq!(mring.max_degree(), Some(4));

        let full = Graph::complete(8);
        assert_eq!(full.edges().len(), 28);
        assert_eq!(full.min_degree(), Some(7));
    }

    #[test]
    fn torus_structure_and_grammar() {
        // 4x8: every node degree 4, 2n edges, connected.
        let g = Graph::torus(4, 8);
        assert_eq!(g.n(), 32);
        assert_eq!(g.edges().len(), 64);
        assert_eq!(g.min_degree(), Some(4));
        assert_eq!(g.max_degree(), Some(4));
        assert!(g.is_connected());
        // Node (1, 3) = 11 touches (1,2)=10, (1,4)=12, (0,3)=3, (2,3)=19.
        assert_eq!(g.neighbors(11), &[3, 10, 12, 19]);
        // A side of 2 collapses its wrap edge onto the adjacent edge:
        // 2x3 has 3 vertical edges (deduped) + 6 horizontal = 9.
        let thin = Graph::torus(2, 3);
        assert_eq!(thin.edges().len(), 9);
        assert!(thin.is_connected());
        // CLI grammar.
        assert_eq!(
            Topology::from_name("torus:4x8"),
            Some(Topology::Torus { rows: 4, cols: 8 })
        );
        let t = Topology::from_name("torus:4x8").unwrap();
        assert_eq!(t.name(), "torus");
        let built = Graph::build(t, 32);
        assert_eq!(built.edges(), g.edges());
        for bad in ["torus:", "torus:4", "torus:4x", "torus:1x8",
                    "torus:4x1", "torus:ax8"] {
            assert_eq!(Topology::from_name(bad), None, "`{bad}` must fail");
        }
    }

    #[test]
    #[should_panic(expected = "torus:4x8")]
    fn torus_node_count_mismatch_panics() {
        let _ = Graph::build(Topology::Torus { rows: 4, cols: 8 }, 31);
    }

    #[test]
    fn empty_graph_degrees_are_none_not_panic() {
        let g = Graph::from_edges(0, &[]);
        assert_eq!(g.min_degree(), None);
        assert_eq!(g.max_degree(), None);
        // The ASCII rendering degrades gracefully too.
        assert!(g.ascii_viz().contains("0 nodes"));
    }

    #[test]
    fn edge_lookup_and_sign() {
        let g = Graph::ring(5);
        assert!(g.edge_index(0, 1).is_some());
        assert!(g.edge_index(1, 0).is_some());
        assert!(g.edge_index(0, 2).is_none());
        assert_eq!(g.edge_sign(0, 1), 1.0);
        assert_eq!(g.edge_sign(1, 0), -1.0);
        // Constraint: A_{i|j} + A_{j|i} = 0 pairing (Eq. 2).
        for &(i, j) in g.edges() {
            assert_eq!(g.edge_sign(i, j) + g.edge_sign(j, i), 0.0);
        }
    }

    #[test]
    fn neighbors_sorted_and_symmetric() {
        let g = Graph::multiplex_ring(8);
        for i in 0..g.n() {
            let nb = g.neighbors(i);
            assert!(nb.windows(2).all(|w| w[0] < w[1]));
            for &j in nb {
                assert!(g.neighbors(j).contains(&i));
            }
        }
    }

    #[test]
    fn mh_weights_doubly_stochastic() {
        for g in [Graph::chain(8), Graph::ring(8), Graph::star(6)] {
            let w = g.mh_weights();
            for i in 0..g.n() {
                let row: f64 = w[i].iter().sum();
                assert!((row - 1.0).abs() < 1e-12);
                for j in 0..g.n() {
                    assert!((w[i][j] - w[j][i]).abs() < 1e-15);
                    assert!(w[i][j] >= -1e-15);
                    if i != j && g.edge_index(i, j).is_none() {
                        assert_eq!(w[i][j], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "connected")]
    fn disconnected_rejected() {
        let _ = Graph::from_edges(4, &[(0, 1), (2, 3)]);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn self_loop_rejected() {
        let _ = Graph::from_edges(3, &[(0, 0), (0, 1), (1, 2)]);
    }

    #[test]
    fn random_graph_deterministic_and_connected_variant() {
        let a = Graph::random_connected(12, 0.3, 7);
        let b = Graph::random_connected(12, 0.3, 7);
        assert!(a.is_connected());
        assert_eq!(a.edges(), b.edges());
        let c = Graph::random_connected(12, 0.3, 8);
        assert_ne!(a.edges(), c.edges());
        // Plain `random` is honest Erdős–Rényi: p = 0 is a legal,
        // maximally disconnected sample — no panic, no hidden ring.
        let empty = Graph::random(6, 0.0, 3);
        assert_eq!(empty.edges().len(), 0);
        assert!(!empty.is_connected());
    }

    #[test]
    #[should_panic(expected = "no connected sample")]
    fn random_connected_gives_up_loudly() {
        // p = 0 can never connect more than one node.
        let _ = Graph::random_connected(4, 0.0, 1);
    }

    #[test]
    fn topology_names_roundtrip() {
        for t in Topology::paper_set() {
            assert_eq!(Topology::from_name(t.name()), Some(t));
        }
        assert_eq!(Topology::from_name("nope"), None);
    }

    #[test]
    fn outage_windows_hold_semantics() {
        // The old OutageSchedule behavior, now DownKind::Outage windows
        // of the folded ChurnSchedule.
        let mut s = ChurnSchedule::new();
        assert!(s.is_empty());
        assert!(!s.is_outage_down(0, 123));
        assert_eq!(s.outage_next_up(0, 123), 123);
        s.add_outage(0, 100, 200);
        s.add_outage(0, 180, 300); // overlapping
        s.add_outage(1, 50, 60);
        assert!(!s.is_empty());
        // Outage-only schedules are epoch-constant: no churn.
        assert!(!s.has_churn());
        assert!(!s.is_outage_down(0, 99));
        assert!(s.is_outage_down(0, 100));
        assert!(s.is_outage_down(0, 250));
        assert!(!s.is_outage_down(0, 300)); // until is exclusive
        assert!(!s.is_outage_down(2, 150)); // other edges unaffected
        // next_up hops across the overlapping chain.
        assert_eq!(s.outage_next_up(0, 150), 300);
        assert_eq!(s.outage_next_up(0, 0), 0);
        assert_eq!(s.outage_next_up(1, 55), 60);
        // Outage windows never churn an edge and are not transitions.
        assert!(!s.churned_down(0, 0, 1, 150));
        assert_eq!(s.next_transition_after(0), None);
    }

    #[test]
    #[should_panic(expected = "empty outage window")]
    fn outage_rejects_empty_window() {
        let mut s = ChurnSchedule::new();
        s.add_outage(0, 10, 10);
    }

    #[test]
    fn churn_windows_and_node_events() {
        let mut s = ChurnSchedule::new();
        s.add_edge_down(2, 100, 200);
        s.add_node_leave(3, 500);
        s.add_node_join(4, 50);
        assert!(s.has_churn());
        // Explicit edge window.
        assert!(s.churned_down(2, 1, 2, 150));
        assert!(!s.churned_down(2, 1, 2, 200));
        // Churn does NOT hold traffic — that is the outage kind.
        assert!(!s.is_outage_down(2, 150));
        // Node 3 leaves at 500 forever.
        assert!(!s.churned_down(7, 3, 5, 499));
        assert!(s.churned_down(7, 3, 5, 500));
        assert!(s.churned_down(7, 0, 3, 1_000_000));
        // Node 4 is absent until its join at 50.
        assert!(s.churned_down(9, 4, 6, 0));
        assert!(!s.churned_down(9, 4, 6, 50));
        // Transition boundaries, in order (u64::MAX never reported).
        assert_eq!(s.next_transition_after(0), Some(50));
        assert_eq!(s.next_transition_after(50), Some(100));
        assert_eq!(s.next_transition_after(100), Some(200));
        assert_eq!(s.next_transition_after(200), Some(500));
        assert_eq!(s.next_transition_after(500), None);
        assert_eq!(s.max_edge_index(), Some(2));
        assert_eq!(s.max_node_index(), Some(4));
    }

    #[test]
    fn random_churn_rule_deterministic_with_slot_boundaries() {
        let mut s = ChurnSchedule::new();
        s.random_edge_churn_with_slot(0.3, 9, 1_000);
        assert!(s.has_churn());
        assert!(!s.is_empty());
        // Deterministic per (edge, slot) and constant within a slot.
        let mut t = ChurnSchedule::new();
        t.random_edge_churn_with_slot(0.3, 9, 1_000);
        let mut downs = 0;
        for e in 0..16usize {
            for slot in 0..32u64 {
                let at = slot * 1_000 + 500;
                let a = s.churned_down(e, 0, 1, at);
                assert_eq!(a, t.churned_down(e, 0, 1, at));
                assert_eq!(a, s.churned_down(e, 0, 1, slot * 1_000));
                downs += a as usize;
            }
        }
        // ~30% of 512 samples; loose bounds, deterministic seed.
        assert!(downs > 80 && downs < 260, "downs {downs}");
        // Transitions land exactly on slot boundaries.
        assert_eq!(s.next_transition_after(0), Some(1_000));
        assert_eq!(s.next_transition_after(1_500), Some(2_000));
    }

    #[test]
    fn churn_grammar_parses_and_rejects() {
        let s = ChurnSchedule::parse(
            "edge:3@1000..2000, node:5@leave:7000, node:2@join:500, \
             outage:0@10..20, random:0.05:42",
        )
        .unwrap();
        assert!(s.has_churn());
        assert!(s.churned_down(3, 0, 3, 1500));
        assert!(s.churned_down(8, 5, 6, 7000));
        assert!(s.churned_down(8, 2, 4, 100));
        assert!(s.is_outage_down(0, 15));
        assert_eq!(s.label(), "churn");
        // Pure random schedules label with their rate.
        let r = ChurnSchedule::parse("random:0.05").unwrap();
        assert_eq!(r.label(), "random:0.05");
        assert_eq!(ChurnSchedule::new().label(), "static");
        // Broken items fail with errors that restate what was expected.
        for bad in ["edge:3", "edge:x@1..2", "edge:3@5..5", "node:1@at:5",
                    "node:1@join:0", "random:1.5", "bogus:1"] {
            assert!(ChurnSchedule::parse(bad).is_err(), "`{bad}` must fail");
        }
        let err = ChurnSchedule::parse("bogus:1").unwrap_err();
        assert!(err.contains("grammar"), "{err}");
        let err = ChurnSchedule::parse("edge:3").unwrap_err();
        assert!(err.contains("expected"), "{err}");
        let err = ChurnSchedule::parse("random:1.5").unwrap_err();
        assert!(err.contains("rate"), "{err}");
    }

    #[test]
    fn topology_view_lifecycle() {
        let mut v = TopologyView::full(4);
        assert_eq!(v.version(), 0);
        assert_eq!(v.edge_count(), 4);
        assert!(v.is_live(2));
        assert_eq!(v.edge_life(2).epoch, 0);
        assert_eq!(v.edge_life(2).activation_round, 0);
        v.kill_edge(2);
        assert!(!v.is_live(2));
        assert_eq!(v.version(), 1);
        v.kill_edge(2); // idempotent, no version bump
        assert_eq!(v.version(), 1);
        v.revive_edge(2, 7);
        let life = v.edge_life(2);
        assert!(life.live);
        assert_eq!(life.epoch, 1);
        assert_eq!(life.activation_round, 7);
        assert_eq!(v.version(), 2);
        // live_degree follows the view, not the base graph.
        let g = Graph::ring(4);
        let mut view = TopologyView::full(g.edges().len());
        assert_eq!(view.live_degree(&g, 0), 2);
        let e = g.edge_index(0, 1).unwrap();
        view.kill_edge(e);
        assert_eq!(view.live_degree(&g, 0), 1);
        assert_eq!(view.live_degree(&g, 1), 1);
        assert_eq!(view.live_degree(&g, 2), 2);
    }

    #[test]
    fn ascii_viz_contains_all_nodes() {
        let viz = Graph::ring(5).ascii_viz();
        assert!(viz.contains("5 nodes, 5 edges"));
        assert!(viz.lines().count() >= 7);
    }
}
