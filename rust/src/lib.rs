//! # cecl — Communication-Compressed Edge-Consensus Learning
//!
//! A production-quality reproduction of *“Communication Compression for
//! Decentralized Learning with Operator Splitting Methods”* (Takezawa,
//! Niwa, Yamada, 2022) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized-training coordinator: node
//!   threads over a network topology, a byte-metered message bus, the
//!   per-edge dual state of the Douglas–Rachford splitting, compression
//!   operators, the C-ECL/ECL/D-PSGD/PowerGossip protocol drivers, and
//!   every experiment of the paper's evaluation section.
//! * **L2 (python/compile/model.py, build-time only)** — the 5-layer CNN
//!   with GroupNorm, its loss/gradient, and the Eq. (6) closed-form
//!   prox-SGD local update, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time only)** — Pallas kernels
//!   for the fused compressed dual update (Alg. 1 lines 4 & 9) and the
//!   MXU-tiled matmul of the dense head.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! jax functions once; [`runtime::Engine`] loads and executes the HLO via
//! the PJRT C API (`xla` crate, CPU client).
//!
//! ## Quick start
//!
//! ```no_run
//! use cecl::prelude::*;
//!
//! let graph = Graph::ring(8);
//! let spec = ExperimentSpec {
//!     dataset: "fashion".into(),
//!     algorithm: AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: true },
//!     epochs: 10,
//!     ..ExperimentSpec::default()
//! };
//! let report = run_experiment(&spec, &graph).unwrap();
//! println!("accuracy={:.1}% sent/epoch={}", report.final_accuracy * 100.0,
//!          report.mean_bytes_per_epoch);
//! ```

pub mod algorithms;
pub mod comm;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod quadratic;
pub mod runtime;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::AlgorithmSpec;
    pub use crate::compress::{Compressor, RandK, TopK};
    pub use crate::coordinator::{run_experiment, ExperimentSpec, Report};
    pub use crate::data::{Partition, SyntheticSpec};
    pub use crate::graph::{Graph, Topology};
    pub use crate::metrics::History;
    pub use crate::quadratic::QuadraticNetwork;
    pub use crate::runtime::Engine;
    pub use crate::util::rng::Pcg;
}
