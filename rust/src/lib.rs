//! # cecl — Communication-Compressed Edge-Consensus Learning
//!
//! A production-quality reproduction of *“Communication Compression for
//! Decentralized Learning with Operator Splitting Methods”* (Takezawa,
//! Niwa, Yamada, 2022) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized-training coordinator over
//!   a network topology: a byte-metered message substrate, the per-edge
//!   dual state of the Douglas–Rachford splitting, a pluggable **edge
//!   codec** layer ([`compress::codec`]: stateful per-edge
//!   encoders/decoders producing byte-exact wire frames — rand-k in two
//!   wire modes, top-k, QSGD quantization, sign+norm, error feedback,
//!   identity), the C-ECL/ECL/D-PSGD/PowerGossip protocol drivers plus
//!   the compressed-gossip rival baselines CHOCO-SGD and LEAD, and
//!   every experiment of the paper's evaluation section.
//! * **L2 (python/compile/model.py, build-time only)** — the 5-layer CNN
//!   with GroupNorm, its loss/gradient, and the Eq. (6) closed-form
//!   prox-SGD local update, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time only)** — Pallas kernels
//!   for the fused compressed dual update (Alg. 1 lines 4 & 9) and the
//!   MXU-tiled matmul of the dense head.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! jax functions once; [`runtime::Engine`] loads and executes the HLO
//! via the PJRT C API (`xla` crate, CPU client, behind the `pjrt`
//! cargo feature).
//!
//! ## Three execution engines
//!
//! Every algorithm is written once as a poll-driven state machine
//! ([`algorithms::NodeStateMachine`]) and can be driven by any of three
//! engines — the first two selected through
//! [`coordinator::ExperimentSpec::exec`], the third through its own
//! entry points [`net::run_net_native`] / [`net::run_net_node`]:
//!
//! | | **Threaded** (`ExecMode::Threaded`) | **Virtual-time** (`ExecMode::Simulated`) | **Net** ([`net`]) |
//! |---|---|---|---|
//! | concurrency | one OS thread per node | single thread, event queue | one OS thread + TCP sockets per node; or one process per node (`repro node`) |
//! | network | zero-latency, lossless channels | pluggable [`sim::LinkModel`]s: latency, bandwidth, drops + retransmit, per-edge overrides, stragglers | real TCP streams (loopback or routable), framed wire protocol ([`net::wire`]) |
//! | topology | epoch-constant (static view) | dynamic: [`graph::ChurnSchedule`] outages + edge churn + node join/leave, epoch-stamped [`graph::TopologyView`] | static universe; a crashed peer maps onto the churn teardown lifecycle |
//! | clock | wall-clock only | virtual nanoseconds ⇒ simulated *time-to-accuracy* | wall-clock (time-to-accuracy measured, not forecast) |
//! | scale | ~dozens of nodes | 512+ nodes in one process | 64+ nodes loopback; multi-process via `repro node` |
//! | round policies | sync only | sync, or `async:<s>` bounded staleness | sync, or `async:<s>` off real arrivals |
//! | determinism | bytes deterministic; timing racy | same seed ⇒ bit-identical [`coordinator::Report`] | payload bytes bit-identical to the sim per directed edge; sync trajectory bit-identical too |
//!
//! Use the **threaded** engine to benchmark real wall-clock round costs
//! with the PJRT artifacts at paper scale (8 nodes).  Use the
//! **virtual-time** engine for everything the paper's claim is actually
//! about — communication under imperfect networks — and for scale: it
//! reports simulated time-to-accuracy under lossy/slow/straggling
//! links, replays bit-identically from a seed, and needs no artifacts
//! at all when paired with the native softmax backend
//! ([`coordinator::run_simulated_native`]).  The zero-latency lossless
//! link reproduces the threaded engine's byte accounting exactly
//! (pinned by the `sim` test suite).  Use the **net** engine to run the
//! byte-exact codec frames over actual sockets: `repro launch --nodes N`
//! spawns a whole localhost deployment in one process (and
//! `--verify-bytes` checks its per-edge payload bytes against the sim's
//! prediction), while `repro node --node I --peers a0,a1,…` runs a
//! single node against explicit addresses for real multi-process
//! deployments.
//!
//! ## The wire format (net engine)
//!
//! [`net::wire`] frames every [`comm::Msg`] with a fixed 24-byte
//! little-endian header:
//!
//! ```text
//! offset  size  field
//!      0     4  magic        0x4345434C ("CECL")
//!      4     2  version      1
//!      6     1  kind         0=Hello 1=Dense 2=Frame 3=Scalar 4=Bye
//!      7     1  reserved     must be 0
//!      8     4  src          sender node id
//!     12     4  epoch        edge incarnation (churn lifecycle stamp)
//!     16     4  round        sender's round counter
//!     20     4  payload_len  bytes following the header
//! ```
//!
//! The payload is the codec's byte-exact `Frame` (or the dense/scalar
//! encoding of the corresponding `Msg`) — identical to what the other
//! engines meter, which is what makes cross-engine byte accounting
//! comparable.  Framing rules: `Hello`/`Bye` carry no payload; `Dense`
//! payloads must be a multiple of 4; `Scalar` is exactly 8 bytes; a
//! stream ending mid-message is a protocol error (`CommError::Corrupt`)
//! while EOF at a message boundary without a preceding `Bye` is crash
//! semantics (`CommError::Disconnected` → churn teardown).  Header
//! bytes are metered separately
//! ([`coordinator::Report::header_overhead_bytes`]) so `payload_bytes`
//! — the paper's send-volume quantity — stays engine-comparable; the
//! in-process engines report 0 overhead.
//!
//! ## Quick start
//!
//! ```no_run
//! use cecl::prelude::*;
//!
//! let graph = Graph::ring(8);
//! let spec = ExperimentSpec {
//!     dataset: "fashion".into(),
//!     algorithm: AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: true },
//!     epochs: 10,
//!     ..ExperimentSpec::default()
//! };
//! let report = run_experiment(&spec, &graph).unwrap();
//! println!("accuracy={:.1}% sent/epoch={}", report.final_accuracy * 100.0,
//!          report.mean_bytes_per_epoch);
//! ```
//!
//! Simulated, artifact-free, 512 nodes on a lossy network:
//!
//! ```no_run
//! use cecl::prelude::*;
//!
//! let graph = Graph::ring(512);
//! let spec = ExperimentSpec {
//!     algorithm: AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: false },
//!     nodes: 512,
//!     exec: ExecMode::Simulated(SimConfig {
//!         link: LinkSpec::Lossy { latency_us: 500, mbit_per_sec: 100.0, drop_p: 0.02 },
//!         ..SimConfig::default()
//!     }),
//!     ..ExperimentSpec::default()
//! };
//! let report = run_simulated_native(&spec, &graph).unwrap();
//! println!("sim time {:.2}s, retransmitted {} B",
//!          report.sim_time_secs.unwrap(), report.retransmit_bytes);
//! ```
//!
//! C-ECL over any edge codec (CLI: `--codec qsgd:4`; codecs that are
//! not linear for fixed ω — top-k, quantizers, low-rank, error
//! feedback — run the Eq. (11) dual rule automatically).  The
//! `low_rank:R[:iters]` codec is PowerGossip's compressor on the C-ECL
//! wire: rank-R power-iteration factors per layer matrix (`R` explicit
//! `(p, q)` pairs, deflated greedily, warm-started per edge from the
//! shared seed; `iters` refinement steps per rank, default 1), rank-1
//! tensors dense — byte-identical per neighbor per round to sync
//! `powergossip:R`, pinned by tests:
//!
//! ```no_run
//! use cecl::prelude::*;
//!
//! let spec = ExperimentSpec {
//!     algorithm: AlgorithmSpec::CEclCodec {
//!         codec: CodecSpec::parse("ef+top_k:0.01").unwrap(),
//!         theta: 1.0,
//!         dense_first_epoch: false,
//!     },
//!     ..ExperimentSpec::default()
//! };
//! ```
//!
//! ## Round policies
//!
//! Rounds are **per-edge**: every message carries its sender's round
//! counter, and [`algorithms::NodeStateMachine::on_message`] receives
//! that stamp rather than the receiver's round.  An
//! [`algorithms::RoundPolicy`] — selected via
//! [`coordinator::ExperimentSpec::rounds`] or `--rounds sync|async:<s>`
//! — decides when a node may finish its exchange and run its next K
//! local steps:
//!
//! * **`Sync`** (default): barrier on every edge's current-round
//!   message.  Byte- and trajectory-identical to the pre-async
//!   schedule on both engines — pinned by tests.
//! * **`Async { max_staleness }`** (virtual-time engine only):
//!   gossip-style, event-driven rounds.  Messages apply the moment they
//!   arrive (per-edge FIFO, shared-seed masks keyed by the *message's*
//!   round, so codec streams never desynchronize); a node steps once
//!   every edge has delivered state at most `max_staleness` rounds old.
//!   A straggler or one slow edge then delays only its own edges
//!   instead of barring the whole graph — C-ECL consumes the freshest
//!   dual it has per neighbor (stale-dual C-ECL), D-PSGD averages the
//!   freshest parameters.  The bound is enforced in-protocol
//!   (`round_end` errors on a violation) and reported as
//!   [`coordinator::Report::max_staleness`].
//!
//! **PowerGossip's conversation-counter contract.**  PowerGossip's
//! interactive multi-phase pipeline runs async through per-edge
//! *conversation counters*: conversation `c` on an edge is the exchange
//! both endpoints start at their own local round `c` (one start per
//! edge per round, so the counters agree at both ends by construction,
//! with no negotiation traffic), and every piece of derived randomness
//! — the degenerate-collapse q̂ reseed — keys off that counter, never
//! off a message's round stamp.  A conversation that straddles rounds
//! keeps running while the node steps; its rank-1 correction is parked
//! and applied at the node's next `round_end` (deferred application),
//! where the staleness bound is enforced on the per-edge conversation
//! clock exactly like C-ECL's dual clock.  Under sync the counter
//! equals the round and the trajectory is bit-identical to the legacy
//! schedule (pinned by tests).
//!
//! ```no_run
//! use cecl::prelude::*;
//!
//! let spec = ExperimentSpec {
//!     algorithm: AlgorithmSpec::CEcl { k_frac: 0.10, theta: 1.0, dense_first_epoch: false },
//!     nodes: 64,
//!     exec: ExecMode::Simulated(SimConfig {
//!         link: LinkSpec::Constant { latency_us: 30_000 },
//!         stragglers: vec![(11, 8.0)],         // one 8x-slow node
//!         edge_links: vec![(3, LinkSpec::Constant { latency_us: 100 })],
//!         ..SimConfig::default()
//!     }),
//!     rounds: RoundPolicy::Async { max_staleness: 2 },
//!     ..ExperimentSpec::default()
//! };
//! ```
//!
//! ## Module map
//!
//! | module | contents |
//! |---|---|
//! | [`analysis`] | determinism lint engine (`repro lint`): scoped source rules + the allow-list directive |
//! | [`compress`] | rand-k mask sampler, COO vectors, low-rank (PowerGossip primitives + `low_rank` codec) |
//! | [`compress::codec`] | **edge codecs**: `EdgeCodec`/`Frame`/`EdgeCtx`/`CodecSpec`, identity / rand-k (explicit + values-only wire) / top-k / QSGD / sign / low-rank / error feedback |
//! | [`comm`] | `Msg` (dense / sparse / codec frame / scalar), byte meter (incl. churn-drop counters), threaded bus |
//! | [`algorithms`] | `NodeAlgorithm` + `NodeStateMachine` protocol drivers (C-ECL family, D-PSGD, PowerGossip, and the rivals CHOCO-SGD / LEAD), `RoundPolicy` (sync / bounded-staleness async), per-edge lifecycle |
//! | [`coordinator`] | `ExperimentSpec` → `Report` on the in-process engines |
//! | [`sim`] | virtual-time engine: event queue, link models (incl. per-edge overrides), stragglers, first-class churn events |
//! | [`net`] | real-socket engine: framed wire protocol ([`net::wire`]), per-node TCP runtime with reader threads, localhost launcher + multi-process node entry |
//! | [`experiments`] | tables, figures, ablations, simulated time-to-accuracy (churn ladder) |
//! | [`graph`] | topologies, `TopologyView` (epoch-stamped live snapshot), `ChurnSchedule` (outage / edge churn / node join-leave / random rule) |
//! | [`data`] | synthetic datasets + the heterogeneity axis: homogeneous / heterogeneous(8-of-10) / **Dirichlet(α)** label-skew partitions |
//! | [`quadratic`], [`model`], [`runtime`] | convex substrate, manifests, PJRT |
//!
//! ## Rival baselines and the heterogeneity axis
//!
//! The paper's headline — operator splitting tolerates data
//! heterogeneity that breaks gossip averaging — needs rivals to beat.
//! [`algorithms::ChocoNode`] (CHOCO-SGD: per-edge replicas `x̂`,
//! consensus step scaled by γ = τ) and [`algorithms::LeadNode`] (LEAD:
//! primal–dual with per-edge z-estimates) are first-class
//! `NodeStateMachine`s compressing through the **same** [`compress`]
//! edge codecs — `--algorithm choco:rand_k:0.1` ships byte-identical
//! frames to the C-ECL `rand_k:0.1` row, so comparisons isolate the
//! algorithm.  Both obey the full per-edge lifecycle (churn
//! birth/teardown, `EdgeClock` staleness gating under `--rounds
//! async:<s>`); CHOCO-SGD with the identity codec degenerates
//! bit-exactly to D-PSGD (pinned by tests).
//!
//! Data skew is the `--heterogeneity` axis (all run commands):
//! `homogeneous`, `heterogeneous[:c]` (the paper's 8-of-10 split), or
//! `dirichlet:<alpha>` — per-node class proportions drawn from a
//! symmetric Dirichlet(α) with equal node sizes ([`data::Partition`]).
//! The head-to-head table sweeps algorithm × codec × α (a Dirichlet
//! value expands to the ladder {α, 1.0, ∞}) under sync or async rounds:
//!
//! ```text
//! repro sim --table --heterogeneity dirichlet:0.1 --rounds async:2 \
//!           --nodes 64 --dataset tiny
//! ```
//!
//! ## Dynamic topology
//!
//! The base [`graph::Graph`] is the immutable **universe** of edges; a
//! [`graph::ChurnSchedule`] (CLI `--churn`, grammar
//! `edge:<e>@<from_ns>..<to_ns> | node:<n>@join:<ns>|leave:<ns> |
//! random:<rate>[:<seed>]`, plus `--outage` sugar) declares when edges
//! and nodes are out of service.  The virtual-time engine turns every
//! transition into a first-class event: it maintains an epoch-stamped
//! [`graph::TopologyView`] that flows through every
//! [`algorithms::NodeStateMachine`] callback in place of a fixed
//! neighbor slice, drains in-flight frames of a removed edge as typed
//! churn drops (`Report::frames_dropped_by_churn`; send bytes stay
//! metered), and evaluates staleness bounds over currently-live edges
//! only.
//!
//! **Per-edge state lifecycle.**  On edge *death* the endpoints retire
//! their per-edge state — the C-ECL dual `z_{i|j}` (zeroed out of
//! `zsum`), error-feedback residuals, PowerGossip conversations — via a
//! typed teardown.  On edge *birth* (a churn re-add is a fresh
//! `EdgeLife::epoch`) each endpoint allocates a new codec instance from
//! its `CodecSpec` and warm-starts the dual from its current primal at
//! the consensus fixed point `z_{i|j} = α·A_{i|j}·w_i` — the
//! initialization that keeps the Eq. (11) update sane mid-training.
//! Shared-seed derivations (`compress::codec::EdgeCtx::epoch`,
//! PowerGossip q̂ streams) fold the epoch in for epoch ≥ 1, so an old
//! incarnation's residuals/warm-starts can never be resurrected against
//! a new one; epoch 0 keeps the legacy derivation paths, which is why
//! an **empty schedule replays the pre-churn trajectories and byte
//! counts bit-identically** (pinned by the replay/equivalence suites).
//! A revived edge activates at `1 + max(endpoint rounds)` — assigned by
//! the engine so both endpoints (and PowerGossip's conversation
//! counters, which restart at that offset) open the edge at the same
//! round number under sync and async alike.
//!
//! ## Scaling & parallel simulation
//!
//! The virtual-time engine is built to run **million-node** topologies
//! in one process (`cargo bench --bench sim_scale` walks the 64 → 512 →
//! 8k → 100k → 1M rung ladder; `BENCH_sim_scale.json` is the checked-in
//! trajectory).  Five layers make that work:
//!
//! * **Pooled frames** — codec encoders draw their output buffers from
//!   a thread-local free list and [`compress::codec::Frame`] returns
//!   its bytes on drop, so the steady-state event loop allocates
//!   nothing per message.
//! * **Zero-allocation receive** —
//!   [`compress::EdgeCodec::decode_into`] decodes a frame into
//!   caller-owned scratch instead of returning a fresh `Vec`.  The
//!   contract: on success `out` is **fully overwritten** (coordinates a
//!   sparse frame omits are written as zero, never left stale),
//!   bit-identical to what `decode` would have returned; on error the
//!   scratch contents are unspecified.  Every `NodeStateMachine` and
//!   the net runtime hold per-edge scratch across rounds, so a
//!   steady-state round performs zero pool misses and zero allocating
//!   decodes — pinned by thread-local counters
//!   ([`compress::hotpath_counters`]) in the `sim` suite, and guarded
//!   at the source level by the `decode-alloc` lint rule (no `Vec`
//!   construction inside a `decode_into` of the wire files).
//! * **SoA parameter arena** — [`model::Arena`] packs every node's
//!   flat parameter vector (and C-ECL's per-edge duals) into one
//!   contiguous fixed-stride slab instead of per-node `Vec<f32>`s:
//!   row *i* is the partition-local node (or edge-slot) index, rows
//!   are reached via `row`/`row_mut`/`iter_rows`, and
//!   `from_vecs`/`into_vecs` round-trip the legacy layout bit-exactly.
//!   One allocation per partition, cache-linear row walks, and the
//!   fused round kernels in [`linalg`] (`fused_prox_step_f32`,
//!   `dual_mix_f32`, `consensus_mix_f32`, …) stream over those rows
//!   4-way unrolled while preserving the scalar per-element expression
//!   tree — each kernel is pinned bit-identical to its `_reference`
//!   twin, so the arena + fused path replays the exact pre-refactor
//!   trajectories.
//! * **Calendar queue** — the event queue ([`sim`]'s internal
//!   `CalendarQueue`) is a bucket wheel keyed by virtual nanoseconds
//!   with a sorted overflow heap, O(1) amortized push/pop at any queue
//!   depth.  Same-timestamp events pop in a **structural total order**
//!   (event class, then node / directed-edge key, then a per-edge FIFO
//!   sequence) that no scheduling layout can perturb.
//! * **Partitioned conservative PDES** — `SimConfig::threads: N`
//!   (CLI `--threads N`) splits the node set into `N` contiguous
//!   blocks, each owning its nodes' events and outgoing couriers.
//!   Workers advance window-by-window under a conservative **lookahead**
//!   equal to the minimum inter-partition link latency
//!   ([`sim::LinkSpec::min_latency_ns`]), exchanging cross-partition
//!   deliveries at window barriers; churn applies at window boundaries
//!   on all partitions at once.
//!
//! **Determinism contract:** serial is the `N = 1` degenerate case of
//! the same windowed loop, every event executes in the structural total
//! order, and all per-message randomness is derived from
//! `(seed, directed edge, FIFO sequence)` rather than from scheduling
//! history — so **any `--threads N` yields bit-identical trajectories,
//! byte counters, virtual clocks, and `Report`s** (pinned by the
//! `sim_parallel` suite up to 8192 nodes and by thread-invariance tests
//! on the experiment tables).  Zero-latency cross-partition links give
//! a zero lookahead window; the engine then quietly falls back to
//! serial rather than deadlock.
//!
//! ## Determinism invariants
//!
//! The bit-identical-replay and byte-exact-accounting claims above are
//! *enforced*, not aspirational: `repro lint` (the [`analysis`] module,
//! a required CI step) walks `rust/src` and rejects API uses that would
//! let host state leak into a deterministic path.  The scopes:
//!
//! | scope | banned | why |
//! |---|---|---|
//! | [`sim`], [`algorithms`], [`compress`], [`graph`] | `std::time::Instant`, `SystemTime` | virtual time is the only clock; a wall-clock read forks replay |
//! | same modules | `HashMap`, `HashSet` | iteration order depends on the host hash seed — `BTreeMap`/`BTreeSet`/`Vec` only |
//! | same modules | `thread_rng`, `OsRng` | all randomness derives from the seeded counter-mode [`util::rng::Pcg`] |
//! | decode/parse fns of `compress/codec.rs`, `compress/coo.rs`, `compress/low_rank.rs`, `net/wire.rs` | `.unwrap()`, `.expect(...)`, panic-family macros, direct indexing | peer bytes are untrusted; corrupt frames must surface typed `CodecError` / `CommError`, never a panic |
//! | `decode_into` fns of the same wire files | `Vec::new`, `Vec::with_capacity`, `vec![...]`, `.to_vec()`, `.collect()` | the zero-allocation receive contract: scratch is reused across rounds, never rebuilt per message |
//!
//! `Instant` stays legal in [`net`], [`coordinator`], and
//! `util::bench` — the engines that *measure* wall-clock rather than
//! simulate it.  `#[cfg(test)]` modules are exempt everywhere.
//!
//! Exceptions are spelled inline as a comment of the form
//! `det:allow(rule[, rule]): justification` (trailing on the offending
//! line, or standalone directly above it) — the justification text is
//! mandatory, unknown rule names are themselves violations, and the
//! lint suppresses nothing without both, so every escape hatch is
//! visible and argued in the diff.  Crate-wide bans that need no
//! module scoping (`SystemTime`, `HashMap`, `HashSet`) are also
//! declared in `clippy.toml` via `disallowed-types` /
//! `disallowed-methods`, and the `[lints]` table in `Cargo.toml`
//! denies `clippy::undocumented_unsafe_blocks` so an `unsafe impl`
//! can't land without a `// SAFETY:` argument.

pub mod algorithms;
pub mod analysis;
pub mod comm;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod quadratic;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{AlgorithmSpec, RoundPolicy};
    pub use crate::compress::{CodecSpec, EdgeCodec, EdgeCtx, Frame, RandK,
                              WireMode};
    pub use crate::coordinator::{run_experiment, run_simulated_native,
                                 ExecMode, ExperimentSpec, Report};
    pub use crate::data::{Partition, SyntheticSpec};
    pub use crate::graph::{ChurnSchedule, EdgeLife, Graph, Topology,
                           TopologyView};
    pub use crate::metrics::History;
    pub use crate::net::{run_net_native, run_net_node, NetConfig};
    pub use crate::quadratic::QuadraticNetwork;
    pub use crate::runtime::Engine;
    pub use crate::sim::{LinkSpec, SimConfig};
    pub use crate::util::rng::Pcg;
}
