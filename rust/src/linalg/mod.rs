//! Dense linear-algebra substrate (f64), used by the convex-quadratic
//! theory validation (exact Eq. (3) prox via Cholesky) and spectral
//! estimation of the L, μ, δ constants of Theorem 1.
//!
//! Deliberately small and dependency-free: row-major [`Mat`], Cholesky
//! factorization/solve, power iteration for extreme eigenvalues of
//! symmetric PSD matrices.
//!
//! ## Fused f32 round kernels
//!
//! The second half of the module is the f32 hot-path substrate shared
//! by the per-round update rules: the Eq. (6) prox step
//! ([`fused_prox_step_f32`]), weighted accumulation
//! ([`axpy_f32`] / [`scaled_copy_f32`] / [`consensus_mix_f32`]), and
//! the C-ECL dual mixes ([`dual_mix_f32`] / [`dual_diff_mix_f32`]).
//! Every kernel is 4-way unrolled across *independent* elements and
//! ships a `_reference` twin (the plain loop); because the unrolled
//! body applies the identical per-element f32 expression tree, the two
//! halves are pinned **bit-identical** by the test suite — same
//! contract as the `matvec` halves in `compress::low_rank`.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// Random Gaussian matrix (used by tests and synthetic problems).
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Pcg) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// `selfᵀ * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for (j, a) in row.iter().enumerate() {
                y[j] += a * xi;
            }
        }
        y
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, b) in out_row.iter_mut().zip(orow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Gram matrix `selfᵀ * self` (symmetric PSD).
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.cols {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..self.cols {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
        for i in 0..self.cols {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// `self += scale * I` (in place, square only).
    pub fn add_diag(&mut self, scale: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += scale;
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factor (lower-triangular L with A = L Lᵀ) of a symmetric
/// positive-definite matrix.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factorize; returns `None` if `a` is not (numerically) SPD.
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // Forward: L y = b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // Backward: Lᵀ x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }
}

// --------------------------------------------------------------------------
// Vector helpers over f64 slices.
// --------------------------------------------------------------------------

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Largest eigenvalue of a symmetric PSD matrix via power iteration.
pub fn max_eig_sym(a: &Mat, iters: usize, rng: &mut crate::util::rng::Pcg) -> f64 {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut lambda = 0.0;
    for _ in 0..iters {
        let w = a.matvec(&v);
        let nw = norm2(&w);
        if nw < 1e-300 {
            return 0.0;
        }
        v = w.iter().map(|x| x / nw).collect();
        lambda = dot(&v, &a.matvec(&v));
    }
    lambda
}

/// Smallest eigenvalue of a symmetric PSD matrix: power iteration on
/// `(sigma I - A)` with `sigma >= lambda_max`.
pub fn min_eig_sym(a: &Mat, iters: usize, rng: &mut crate::util::rng::Pcg) -> f64 {
    let lmax = max_eig_sym(a, iters, rng);
    let sigma = lmax * 1.01 + 1e-9;
    let n = a.rows;
    let mut shifted = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            shifted[(i, j)] = -a[(i, j)];
        }
        shifted[(i, i)] += sigma;
    }
    let mu_shifted = max_eig_sym(&shifted, iters, rng);
    (sigma - mu_shifted).max(0.0)
}

// --------------------------------------------------------------------------
// Fused f32 round kernels.
//
// These are the inner loops of the per-round update rules: the Eq. (6)
// prox step (softmax local model), Metropolis-Hastings folds (D-PSGD /
// CHOCO), weighted consensus differences (CHOCO / LEAD), and the
// C-ECL dual mixes (Eq. (11)).  Each `*_f32` kernel is 4-way unrolled
// across independent elements; its `_reference` twin is the plain
// loop.  Unrolling never reassociates: every element goes through the
// same f32 expression tree in both halves, so results are pinned
// bit-identical (see `fused_kernels_bit_identical` below), which keeps
// the sim replay/parallel bit-identity suites valid through the fused
// paths.
// --------------------------------------------------------------------------

/// Eq. (6) fused prox step: `w[i] = (w[i] - eta*g[i] + eta*z[i]) / denom`.
pub fn fused_prox_step_f32(w: &mut [f32], g: &[f32], z: &[f32], eta: f32, denom: f32) {
    assert_eq!(w.len(), g.len());
    assert_eq!(w.len(), z.len());
    let n = w.len() / 4 * 4;
    let (wh, wt) = w.split_at_mut(n);
    let (gh, gt) = g.split_at(n);
    let (zh, zt) = z.split_at(n);
    for ((wc, gc), zc) in wh
        .chunks_exact_mut(4)
        .zip(gh.chunks_exact(4))
        .zip(zh.chunks_exact(4))
    {
        wc[0] = (wc[0] - eta * gc[0] + eta * zc[0]) / denom;
        wc[1] = (wc[1] - eta * gc[1] + eta * zc[1]) / denom;
        wc[2] = (wc[2] - eta * gc[2] + eta * zc[2]) / denom;
        wc[3] = (wc[3] - eta * gc[3] + eta * zc[3]) / denom;
    }
    for ((wv, &gv), &zv) in wt.iter_mut().zip(gt).zip(zt) {
        *wv = (*wv - eta * gv + eta * zv) / denom;
    }
}

/// Plain-loop twin of [`fused_prox_step_f32`]; bit-identical.
pub fn fused_prox_step_f32_reference(
    w: &mut [f32],
    g: &[f32],
    z: &[f32],
    eta: f32,
    denom: f32,
) {
    assert_eq!(w.len(), g.len());
    assert_eq!(w.len(), z.len());
    for ((wv, &gv), &zv) in w.iter_mut().zip(g).zip(z) {
        *wv = (*wv - eta * gv + eta * zv) / denom;
    }
}

/// `y[i] += alpha * x[i]` — the MH-fold accumulate.
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let n = y.len() / 4 * 4;
    let (yh, yt) = y.split_at_mut(n);
    let (xh, xt) = x.split_at(n);
    for (yc, xc) in yh.chunks_exact_mut(4).zip(xh.chunks_exact(4)) {
        yc[0] += alpha * xc[0];
        yc[1] += alpha * xc[1];
        yc[2] += alpha * xc[2];
        yc[3] += alpha * xc[3];
    }
    for (yv, &xv) in yt.iter_mut().zip(xt) {
        *yv += alpha * xv;
    }
}

/// Plain-loop twin of [`axpy_f32`]; bit-identical.
pub fn axpy_f32_reference(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `out[i] = alpha * x[i]` — the self-weight term that seeds a fold.
pub fn scaled_copy_f32(alpha: f32, x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let n = out.len() / 4 * 4;
    let (oh, ot) = out.split_at_mut(n);
    let (xh, xt) = x.split_at(n);
    for (oc, xc) in oh.chunks_exact_mut(4).zip(xh.chunks_exact(4)) {
        oc[0] = alpha * xc[0];
        oc[1] = alpha * xc[1];
        oc[2] = alpha * xc[2];
        oc[3] = alpha * xc[3];
    }
    for (ov, &xv) in ot.iter_mut().zip(xt) {
        *ov = alpha * xv;
    }
}

/// Plain-loop twin of [`scaled_copy_f32`]; bit-identical.
pub fn scaled_copy_f32_reference(alpha: f32, x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    for (ov, &xv) in out.iter_mut().zip(x) {
        *ov = alpha * xv;
    }
}

/// `acc[i] += wij * (plus[i] - minus[i])` — weighted consensus
/// difference (CHOCO replica gap, LEAD dual drive).
pub fn consensus_mix_f32(acc: &mut [f32], plus: &[f32], minus: &[f32], wij: f32) {
    assert_eq!(acc.len(), plus.len());
    assert_eq!(acc.len(), minus.len());
    let n = acc.len() / 4 * 4;
    let (ah, at) = acc.split_at_mut(n);
    let (ph, pt) = plus.split_at(n);
    let (mh, mt) = minus.split_at(n);
    for ((ac, pc), mc) in ah
        .chunks_exact_mut(4)
        .zip(ph.chunks_exact(4))
        .zip(mh.chunks_exact(4))
    {
        ac[0] += wij * (pc[0] - mc[0]);
        ac[1] += wij * (pc[1] - mc[1]);
        ac[2] += wij * (pc[2] - mc[2]);
        ac[3] += wij * (pc[3] - mc[3]);
    }
    for ((av, &pv), &mv) in at.iter_mut().zip(pt).zip(mt) {
        *av += wij * (pv - mv);
    }
}

/// Plain-loop twin of [`consensus_mix_f32`]; bit-identical.
pub fn consensus_mix_f32_reference(
    acc: &mut [f32],
    plus: &[f32],
    minus: &[f32],
    wij: f32,
) {
    assert_eq!(acc.len(), plus.len());
    assert_eq!(acc.len(), minus.len());
    for ((av, &pv), &mv) in acc.iter_mut().zip(plus).zip(minus) {
        *av += wij * (pv - mv);
    }
}

/// C-ECL Eq. (11) convex dual mix with incremental z-sum:
/// `z' = (1-theta)*z + theta*y; acc += a*(z' - z)`.
pub fn dual_mix_f32(z: &mut [f32], acc: &mut [f32], y: &[f32], theta: f32, a: f32) {
    assert_eq!(z.len(), acc.len());
    assert_eq!(z.len(), y.len());
    let n = z.len() / 4 * 4;
    let (zh, zt) = z.split_at_mut(n);
    let (ah, at) = acc.split_at_mut(n);
    let (yh, yt) = y.split_at(n);
    for ((zc, ac), yc) in zh
        .chunks_exact_mut(4)
        .zip(ah.chunks_exact_mut(4))
        .zip(yh.chunks_exact(4))
    {
        let o0 = zc[0];
        zc[0] = (1.0 - theta) * o0 + theta * yc[0];
        ac[0] += a * (zc[0] - o0);
        let o1 = zc[1];
        zc[1] = (1.0 - theta) * o1 + theta * yc[1];
        ac[1] += a * (zc[1] - o1);
        let o2 = zc[2];
        zc[2] = (1.0 - theta) * o2 + theta * yc[2];
        ac[2] += a * (zc[2] - o2);
        let o3 = zc[3];
        zc[3] = (1.0 - theta) * o3 + theta * yc[3];
        ac[3] += a * (zc[3] - o3);
    }
    for ((zv, av), &yv) in zt.iter_mut().zip(at.iter_mut()).zip(yt) {
        let old = *zv;
        *zv = (1.0 - theta) * old + theta * yv;
        *av += a * (*zv - old);
    }
}

/// Plain-loop twin of [`dual_mix_f32`]; bit-identical.
pub fn dual_mix_f32_reference(
    z: &mut [f32],
    acc: &mut [f32],
    y: &[f32],
    theta: f32,
    a: f32,
) {
    assert_eq!(z.len(), acc.len());
    assert_eq!(z.len(), y.len());
    for ((zv, av), &yv) in z.iter_mut().zip(acc.iter_mut()).zip(y) {
        let old = *zv;
        *zv = (1.0 - theta) * old + theta * yv;
        *av += a * (*zv - old);
    }
}

/// C-ECL delta-form dual mix (full-support diff path):
/// `delta = theta*(y - z); z += delta; acc += a*delta`.
pub fn dual_diff_mix_f32(z: &mut [f32], acc: &mut [f32], y: &[f32], theta: f32, a: f32) {
    assert_eq!(z.len(), acc.len());
    assert_eq!(z.len(), y.len());
    let n = z.len() / 4 * 4;
    let (zh, zt) = z.split_at_mut(n);
    let (ah, at) = acc.split_at_mut(n);
    let (yh, yt) = y.split_at(n);
    for ((zc, ac), yc) in zh
        .chunks_exact_mut(4)
        .zip(ah.chunks_exact_mut(4))
        .zip(yh.chunks_exact(4))
    {
        let d0 = theta * (yc[0] - zc[0]);
        zc[0] += d0;
        ac[0] += a * d0;
        let d1 = theta * (yc[1] - zc[1]);
        zc[1] += d1;
        ac[1] += a * d1;
        let d2 = theta * (yc[2] - zc[2]);
        zc[2] += d2;
        ac[2] += a * d2;
        let d3 = theta * (yc[3] - zc[3]);
        zc[3] += d3;
        ac[3] += a * d3;
    }
    for ((zv, av), &yv) in zt.iter_mut().zip(at.iter_mut()).zip(yt) {
        let delta = theta * (yv - *zv);
        *zv += delta;
        *av += a * delta;
    }
}

/// Plain-loop twin of [`dual_diff_mix_f32`]; bit-identical.
pub fn dual_diff_mix_f32_reference(
    z: &mut [f32],
    acc: &mut [f32],
    y: &[f32],
    theta: f32,
    a: f32,
) {
    assert_eq!(z.len(), acc.len());
    assert_eq!(z.len(), y.len());
    for ((zv, av), &yv) in z.iter_mut().zip(acc.iter_mut()).zip(y) {
        let delta = theta * (yv - *zv);
        *zv += delta;
        *av += a * delta;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn matvec_identity() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg::new(1);
        let a = Mat::randn(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Pcg::new(2);
        let a = Mat::randn(5, 3, &mut rng);
        let g1 = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g1.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Pcg::new(3);
        let a = Mat::randn(6, 4, &mut rng);
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for (p, q) in y1.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_solves_spd_system() {
        let mut rng = Pcg::new(4);
        let b = Mat::randn(8, 5, &mut rng);
        let mut a = b.gram();
        a.add_diag(0.5); // ensure SPD
        let x_true: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let rhs = a.matvec(&x_true);
        let chol = Cholesky::new(&a).expect("SPD");
        let x = chol.solve(&rhs);
        for (p, q) in x.iter().zip(&x_true) {
            assert!((p - q).abs() < 1e-8, "{p} vs {q}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1, 3
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn extreme_eigs_of_diagonal() {
        let mut a = Mat::eye(4);
        a[(0, 0)] = 9.0;
        a[(1, 1)] = 4.0;
        a[(2, 2)] = 2.0;
        a[(3, 3)] = 0.5;
        let mut rng = Pcg::new(5);
        let lmax = max_eig_sym(&a, 200, &mut rng);
        let lmin = min_eig_sym(&a, 200, &mut rng);
        assert!((lmax - 9.0).abs() < 1e-6, "lmax={lmax}");
        assert!((lmin - 0.5).abs() < 1e-6, "lmin={lmin}");
    }

    #[test]
    fn vector_helpers() {
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
        let mut z = vec![2.0, 4.0];
        scale(0.5, &mut z);
        assert_eq!(z, vec![1.0, 2.0]);
        assert_eq!(sub(&[3.0, 2.0], &[1.0, 1.0]), vec![2.0, 1.0]);
    }

    fn randn_f32(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn fused_kernels_bit_identical() {
        // Lengths straddling the unroll width, including remainders.
        for &n in &[0usize, 1, 3, 4, 5, 8, 17, 130] {
            let x = randn_f32(n, 10 + n as u64);
            let y0 = randn_f32(n, 20 + n as u64);
            let z0 = randn_f32(n, 30 + n as u64);

            let (mut a, mut b) = (y0.clone(), y0.clone());
            fused_prox_step_f32(&mut a, &x, &z0, 0.3, 1.7);
            fused_prox_step_f32_reference(&mut b, &x, &z0, 0.3, 1.7);
            assert_bits_eq(&a, &b, "prox");

            let (mut a, mut b) = (y0.clone(), y0.clone());
            axpy_f32(0.37, &x, &mut a);
            axpy_f32_reference(0.37, &x, &mut b);
            assert_bits_eq(&a, &b, "axpy");

            let (mut a, mut b) = (vec![0.0f32; n], vec![0.0f32; n]);
            scaled_copy_f32(-1.25, &x, &mut a);
            scaled_copy_f32_reference(-1.25, &x, &mut b);
            assert_bits_eq(&a, &b, "scaled_copy");

            let (mut a, mut b) = (y0.clone(), y0.clone());
            consensus_mix_f32(&mut a, &x, &z0, 0.41);
            consensus_mix_f32_reference(&mut b, &x, &z0, 0.41);
            assert_bits_eq(&a, &b, "consensus_mix");

            let (mut za, mut zb) = (z0.clone(), z0.clone());
            let (mut aa, mut ab) = (y0.clone(), y0.clone());
            dual_mix_f32(&mut za, &mut aa, &x, 0.4, 0.9);
            dual_mix_f32_reference(&mut zb, &mut ab, &x, 0.4, 0.9);
            assert_bits_eq(&za, &zb, "dual_mix z");
            assert_bits_eq(&aa, &ab, "dual_mix acc");

            let (mut za, mut zb) = (z0.clone(), z0.clone());
            let (mut aa, mut ab) = (y0.clone(), y0.clone());
            dual_diff_mix_f32(&mut za, &mut aa, &x, 0.4, 0.9);
            dual_diff_mix_f32_reference(&mut zb, &mut ab, &x, 0.4, 0.9);
            assert_bits_eq(&za, &zb, "dual_diff_mix z");
            assert_bits_eq(&aa, &ab, "dual_diff_mix acc");
        }
    }

    #[test]
    fn fused_kernels_known_values() {
        // axpy: y += 2x.
        let mut y = vec![1.0f32, 1.0, 1.0, 1.0, 1.0];
        axpy_f32(2.0, &[1.0, 2.0, 3.0, 4.0, 5.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        // prox with eta=0 divides by denom only.
        let mut w = vec![2.0f32, 4.0, 6.0, 8.0, 10.0];
        let zeros = vec![0.0f32; 5];
        fused_prox_step_f32(&mut w, &zeros, &zeros, 0.0, 2.0);
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        // dual mix with theta=1 replaces z by y and accumulates the jump.
        let mut z = vec![1.0f32, 1.0, 1.0, 1.0, 1.0];
        let mut acc = vec![0.0f32; 5];
        dual_mix_f32(&mut z, &mut acc, &[3.0, 3.0, 3.0, 3.0, 3.0], 1.0, 0.5);
        assert_eq!(z, vec![3.0; 5]);
        assert_eq!(acc, vec![1.0; 5]);
    }
}
