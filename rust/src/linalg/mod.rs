//! Dense linear-algebra substrate (f64), used by the convex-quadratic
//! theory validation (exact Eq. (3) prox via Cholesky) and spectral
//! estimation of the L, μ, δ constants of Theorem 1.
//!
//! Deliberately small and dependency-free: row-major [`Mat`], Cholesky
//! factorization/solve, power iteration for extreme eigenvalues of
//! symmetric PSD matrices.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// Random Gaussian matrix (used by tests and synthetic problems).
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Pcg) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// `selfᵀ * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for (j, a) in row.iter().enumerate() {
                y[j] += a * xi;
            }
        }
        y
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, b) in out_row.iter_mut().zip(orow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Gram matrix `selfᵀ * self` (symmetric PSD).
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.cols {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..self.cols {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
        for i in 0..self.cols {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// `self += scale * I` (in place, square only).
    pub fn add_diag(&mut self, scale: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += scale;
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factor (lower-triangular L with A = L Lᵀ) of a symmetric
/// positive-definite matrix.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factorize; returns `None` if `a` is not (numerically) SPD.
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // Forward: L y = b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // Backward: Lᵀ x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }
}

// --------------------------------------------------------------------------
// Vector helpers over f64 slices.
// --------------------------------------------------------------------------

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Largest eigenvalue of a symmetric PSD matrix via power iteration.
pub fn max_eig_sym(a: &Mat, iters: usize, rng: &mut crate::util::rng::Pcg) -> f64 {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut lambda = 0.0;
    for _ in 0..iters {
        let w = a.matvec(&v);
        let nw = norm2(&w);
        if nw < 1e-300 {
            return 0.0;
        }
        v = w.iter().map(|x| x / nw).collect();
        lambda = dot(&v, &a.matvec(&v));
    }
    lambda
}

/// Smallest eigenvalue of a symmetric PSD matrix: power iteration on
/// `(sigma I - A)` with `sigma >= lambda_max`.
pub fn min_eig_sym(a: &Mat, iters: usize, rng: &mut crate::util::rng::Pcg) -> f64 {
    let lmax = max_eig_sym(a, iters, rng);
    let sigma = lmax * 1.01 + 1e-9;
    let n = a.rows;
    let mut shifted = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            shifted[(i, j)] = -a[(i, j)];
        }
        shifted[(i, i)] += sigma;
    }
    let mu_shifted = max_eig_sym(&shifted, iters, rng);
    (sigma - mu_shifted).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn matvec_identity() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg::new(1);
        let a = Mat::randn(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Pcg::new(2);
        let a = Mat::randn(5, 3, &mut rng);
        let g1 = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g1.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Pcg::new(3);
        let a = Mat::randn(6, 4, &mut rng);
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for (p, q) in y1.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_solves_spd_system() {
        let mut rng = Pcg::new(4);
        let b = Mat::randn(8, 5, &mut rng);
        let mut a = b.gram();
        a.add_diag(0.5); // ensure SPD
        let x_true: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let rhs = a.matvec(&x_true);
        let chol = Cholesky::new(&a).expect("SPD");
        let x = chol.solve(&rhs);
        for (p, q) in x.iter().zip(&x_true) {
            assert!((p - q).abs() < 1e-8, "{p} vs {q}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1, 3
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn extreme_eigs_of_diagonal() {
        let mut a = Mat::eye(4);
        a[(0, 0)] = 9.0;
        a[(1, 1)] = 4.0;
        a[(2, 2)] = 2.0;
        a[(3, 3)] = 0.5;
        let mut rng = Pcg::new(5);
        let lmax = max_eig_sym(&a, 200, &mut rng);
        let lmin = min_eig_sym(&a, 200, &mut rng);
        assert!((lmax - 9.0).abs() < 1e-6, "lmax={lmax}");
        assert!((lmin - 0.5).abs() < 1e-6, "lmin={lmin}");
    }

    #[test]
    fn vector_helpers() {
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
        let mut z = vec![2.0, 4.0];
        scale(0.5, &mut z);
        assert_eq!(z, vec![1.0, 2.0]);
        assert_eq!(sub(&[3.0, 2.0], &[1.0, 1.0]), vec![2.0, 1.0]);
    }
}
