//! `repro` — the C-ECL reproduction CLI.
//!
//! ```text
//! repro table1   [--epochs N --dataset fashion|cifar ...]   Table 1 (homogeneous)
//! repro table2   [...]                                      Table 2 (heterogeneous)
//! repro table3   [...]                                      Table 3 (topology bytes)
//! repro fig1     [--topology ring ...]                      Figure 1 curves -> CSV
//! repro topology [--topology ring --nodes 8] [--viz]        Figure 2 (adjacency)
//! repro theory   [--rounds N --dim D ...]                   Theorem 1 validation
//! repro train    --algorithm cecl:0.1 [--partition hetero]  one run
//! repro train    --codec qsgd:4 | ef+top_k:0.01 | ...       codec run
//! repro launch   --nodes 8 --codec rand_k:0.1 [--verify-bytes]   TCP deployment
//! repro node     --node 0 --peers ip:port,... [--listen ip:port] one process
//! repro ablation-naive | ablation-warmup | ablation-wire
//! repro lint     [--root DIR]                              determinism static analysis
//! ```

use std::net::{SocketAddr, TcpListener};
use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, Result};

use cecl::algorithms::AlgorithmSpec;
use cecl::coordinator::{run_simulated_native, run_with_engine, ExecMode};
use cecl::data::Partition;
use cecl::experiments::{ablations, fig1, sim as sim_exp, tables, theory,
                        Sizing};
use cecl::graph::{ChurnSchedule, Graph, Topology};
use cecl::model::Manifest;
use cecl::net::{run_net_native, run_net_node, NetConfig};
use cecl::runtime::Engine;
use cecl::sim::{LinkSpec, SimConfig};
use cecl::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let command = args.command.clone().unwrap_or_else(|| "help".to_string());
    match command.as_str() {
        "table1" | "table2" => {
            let sizing = Sizing::from_args(&args);
            check_unknown(&args)?;
            let (engine, manifest) = load(&sizing)?;
            let (partition, label) = if command == "table1" {
                (Partition::Homogeneous, "table1")
            } else {
                (Partition::Heterogeneous { classes_per_node: 8 }, "table2")
            };
            let (table, _) = tables::run_accuracy_table(
                &engine, &manifest, &sizing, partition, label,
            )?;
            println!("--- {label} ({}) ---", partition.name());
            println!("{}", table.render());
        }
        "table3" => {
            let sizing = Sizing::from_args(&args);
            check_unknown(&args)?;
            let (engine, manifest) = load(&sizing)?;
            let table = tables::run_topology_table(&engine, &manifest, &sizing)?;
            println!("--- table3 (send/epoch by topology) ---");
            println!("{}", table.render());
        }
        "fig1" => {
            let sizing = Sizing::from_args(&args);
            let topologies = match args.get_opt::<String>("topology") {
                Some(name) => vec![Topology::from_name(&name)
                    .ok_or_else(|| anyhow!("unknown topology {name}"))?],
                None => Topology::paper_set().to_vec(),
            };
            check_unknown(&args)?;
            let (engine, manifest) = load(&sizing)?;
            let paths = fig1::run_fig1(&engine, &manifest, &sizing, &topologies)?;
            println!("wrote {} CSV series:", paths.len());
            for p in paths {
                println!("  {}", p.display());
            }
        }
        "topology" => {
            let nodes = args.get("nodes", 8usize);
            let name = args.get_str("topology", "ring");
            let _viz = args.flag("viz");
            check_unknown(&args)?;
            let topology = Topology::from_name(&name)
                .ok_or_else(|| anyhow!("unknown topology {name}"))?;
            let graph = Graph::build(topology, nodes);
            println!("--- {} ---", topology.name());
            println!("{}", graph.ascii_viz());
            println!(
                "Metropolis-Hastings weight row of node 0: {:?}",
                graph.mh_weights()[0]
                    .iter()
                    .map(|w| (w * 1000.0).round() / 1000.0)
                    .collect::<Vec<_>>()
            );
        }
        "theory" => {
            let cfg = theory::TheoryConfig {
                nodes: args.get("nodes", 8),
                dim: args.get("dim", 24),
                rows: args.get("rows", 40),
                ridge: args.get("ridge", 0.5),
                hetero: args.get("hetero", 0.5),
                rounds: args.get("rounds", 200),
                seed: args.get("seed", 42),
            };
            check_unknown(&args)?;
            theory::run_theory(&cfg)?;
        }
        "train" => {
            let sizing = Sizing::from_args(&args);
            // `--codec SPEC` runs C-ECL over that edge codec directly.
            let algorithm = pick_algorithm(&args, &sizing, true)?;
            let partition = match sizing.partition {
                // `--heterogeneity` (the shared axis flag) wins.
                Some(p) => p,
                None => match args.get_str("partition", "homogeneous").as_str() {
                    "heterogeneous" | "hetero" => Partition::Heterogeneous {
                        // Paper default: 8-of-10. Lower = stronger client
                        // drift (the `ablation-drift` stress regime).
                        classes_per_node: args.get("classes-per-node", 8usize),
                    },
                    other => Partition::parse(other)
                        .map_err(|e| anyhow!("--partition: {e}"))?,
                },
            };
            let topo_name = args.get_str("topology", "ring");
            check_unknown(&args)?;
            let topology = Topology::from_name(&topo_name)
                .ok_or_else(|| anyhow!("unknown topology {topo_name}"))?;
            let graph = Graph::build(topology, sizing.nodes);
            let (engine, manifest) = load(&sizing)?;
            let ds = sizing.datasets.first().cloned().unwrap();
            let mut spec = sizing.spec_base(&ds, partition);
            spec.algorithm = algorithm;
            spec.verbose = true;
            let report = run_with_engine(&engine, &manifest, &spec, &graph)?;
            println!(
                "\n{} on {ds} ({}, {}): final acc {:.3}, best {:.3}, \
                 send/epoch {:.0} KB, wallclock {:.1}s",
                report.algorithm,
                partition.name(),
                topology.name(),
                report.final_accuracy,
                report.best_accuracy,
                report.mean_bytes_per_epoch / 1024.0,
                report.wallclock_secs
            );
        }
        "sim" => {
            // Artifact-free virtual-time run (native softmax backend):
            // works with zero PJRT artifacts, scales to 512+ nodes, and
            // reports simulated time-to-accuracy.
            let sizing = Sizing::from_args(&args);
            // `--codec SPEC` (first entry) selects C-ECL over that
            // codec; the full list also extends the `--table` ladder.
            let algorithm = pick_algorithm(&args, &sizing, false)?;
            let topo_name = args.get_str("topology", "ring");
            let link_name = args.get_str("link", "bandwidth");
            let latency_us = args.get("latency-us", 500u64);
            let mbit = args.get("mbit-per-sec", 100.0f64);
            let drop_p = args.get("drop-p", 0.05f64);
            let compute_us = args.get("compute-us-per-step", 1000u64);
            // Partition-parallel event loop; 1 = serial.  Any value
            // produces the same trajectory bit-for-bit (conservative
            // PDES with link-latency lookahead).
            let threads = args.get("threads", 1usize);
            let table_mode = args.flag("table");
            let target = args.get("target-acc", 0.5f64);
            let stragglers = parse_stragglers(
                &args.get_str("straggler", ""),
            )?;
            let edge_links = parse_edge_links(
                &args.get_str("edge-link", ""),
            )?;
            let churn = parse_churn(
                &args.get_str("churn", ""),
                &args.get_str("outage", ""),
            )?;
            check_unknown(&args)?;
            let link = match link_name.as_str() {
                "ideal" => LinkSpec::Ideal,
                "constant" => LinkSpec::Constant { latency_us },
                "bandwidth" => LinkSpec::Bandwidth {
                    latency_us,
                    mbit_per_sec: mbit,
                },
                "lossy" => LinkSpec::Lossy {
                    latency_us,
                    mbit_per_sec: mbit,
                    drop_p,
                },
                other => return Err(anyhow!("unknown link model {other}")),
            };
            let cfg = SimConfig {
                link,
                edge_links,
                compute_ns_per_step: compute_us.saturating_mul(1000),
                stragglers,
                churn,
                threads,
            };
            if table_mode {
                let policies = sim_exp::policy_ladder(&sizing);
                let (table, _) =
                    sim_exp::run_sim_table(&sizing, &cfg, target, &policies)?;
                println!(
                    "--- sim time-to-accuracy (ring {} nodes, rounds {}) ---",
                    sizing.nodes,
                    sizing.rounds.name()
                );
                println!("{}", table.render());
            } else {
                let topology = Topology::from_name(&topo_name)
                    .ok_or_else(|| anyhow!("unknown topology {topo_name}"))?;
                let graph = Graph::build(topology, sizing.nodes);
                let ds = sizing.datasets.first().cloned().unwrap();
                let partition =
                    sizing.partition.unwrap_or(Partition::Homogeneous);
                let mut spec = sizing.spec_base(&ds, partition);
                spec.algorithm = algorithm;
                spec.verbose = true;
                spec.exec = ExecMode::Simulated(cfg);
                let has_churn = match &spec.exec {
                    ExecMode::Simulated(c) => c.churn.has_churn(),
                    ExecMode::Threaded => false,
                };
                let report = run_simulated_native(&spec, &graph)?;
                // Static rows print `—` for the churn counters (the
                // table convention), so a run can never be misread as
                // "zero churn events happened" when none were possible.
                let churn_cell = if has_churn {
                    format!("{} transitions / {} dropped frames",
                            report.edges_churned,
                            report.frames_dropped_by_churn)
                } else {
                    "—".to_string()
                };
                println!(
                    "\n{} on {} ({} nodes, {}, rounds {}): final acc {:.3}, \
                     sim time {:.2}s, max lag {} rounds, churn {}, \
                     sent {:.0} KB/node/epoch, \
                     retransmitted {:.0} KB, wallclock {:.2}s",
                    report.algorithm,
                    topology.name(),
                    sizing.nodes,
                    report.dataset,
                    spec.rounds.name(),
                    report.final_accuracy,
                    report.sim_time_secs.unwrap_or(0.0),
                    report.max_staleness,
                    churn_cell,
                    report.mean_bytes_per_epoch / 1024.0,
                    report.retransmit_bytes as f64 / 1024.0,
                    report.wallclock_secs
                );
            }
        }
        "launch" => {
            // Real-socket run: a full localhost TCP deployment in one
            // process — one listener, one worker thread, and one
            // framed-wire runtime per node ("the byte-exact Frame wire
            // over TCP").  Artifact-free like `sim`.
            let sizing = Sizing::from_args(&args);
            // Same warmup default as `sim`, so `--verify-bytes`
            // compares byte counts of identical experiments.
            let algorithm = pick_algorithm(&args, &sizing, false)?;
            let topo_name = args.get_str("topology", "ring");
            let verify_bytes = args.flag("verify-bytes");
            let net = net_config(&args);
            check_unknown(&args)?;
            let topology = Topology::from_name(&topo_name)
                .ok_or_else(|| anyhow!("unknown topology {topo_name}"))?;
            let graph = Graph::build(topology, sizing.nodes);
            let ds = sizing.datasets.first().cloned().unwrap();
            let partition = sizing.partition.unwrap_or(Partition::Homogeneous);
            let mut spec = sizing.spec_base(&ds, partition);
            spec.algorithm = algorithm;
            spec.verbose = true;
            let report = run_net_native(&spec, &graph, &net)?;
            println!(
                "\n{} on {} ({} nodes over loopback TCP, rounds {}): \
                 final acc {:.3}, max lag {} rounds, \
                 sent {:.0} KB/node/epoch payload \
                 + {:.0} KB total wire headers, wallclock {:.2}s",
                report.algorithm,
                topology.name(),
                sizing.nodes,
                spec.rounds.name(),
                report.final_accuracy,
                report.max_staleness,
                report.mean_bytes_per_epoch / 1024.0,
                report.header_overhead_bytes as f64 / 1024.0,
                report.wallclock_secs
            );
            if verify_bytes {
                // Acceptance gate: the socket deployment's per-edge
                // payload bytes must equal the virtual-time engine's
                // prediction for the same spec and seed.
                let mut sim_spec = spec.clone();
                sim_spec.verbose = false;
                sim_spec.exec = ExecMode::Simulated(SimConfig::default());
                let predicted = run_simulated_native(&sim_spec, &graph)?;
                if predicted.edge_payload_bytes != report.edge_payload_bytes
                    || predicted.total_bytes != report.total_bytes
                {
                    return Err(anyhow!(
                        "verify-bytes: socket payload bytes diverge from \
                         the sim prediction (net {} B vs sim {} B total)",
                        report.total_bytes,
                        predicted.total_bytes
                    ));
                }
                println!(
                    "verify-bytes: OK — {} directed-edge slots match the \
                     sim prediction exactly ({} payload B total)",
                    report.edge_payload_bytes.len(),
                    report.total_bytes
                );
            }
        }
        "node" => {
            // One node of a multi-process deployment: every process gets
            // the same spec and the same full --peers table (its own
            // entry included) and derives its data partition from the
            // shared seed — no coordinator.
            let sizing = Sizing::from_args(&args);
            let algorithm = pick_algorithm(&args, &sizing, false)?;
            let node = args.get("node", 0usize);
            let listen = args.get_opt::<String>("listen");
            let peers = args.get_str("peers", "");
            let topo_name = args.get_str("topology", "ring");
            let net = net_config(&args);
            check_unknown(&args)?;
            let peer_addrs: Vec<SocketAddr> = peers
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        anyhow!("--peers `{p}`: expected ip:port")
                    })
                })
                .collect::<Result<_>>()?;
            if peer_addrs.len() != sizing.nodes {
                return Err(anyhow!(
                    "--peers lists {} addresses for --nodes {}",
                    peer_addrs.len(),
                    sizing.nodes
                ));
            }
            if node >= sizing.nodes {
                return Err(anyhow!("--node {node} out of range"));
            }
            let topology = Topology::from_name(&topo_name)
                .ok_or_else(|| anyhow!("unknown topology {topo_name}"))?;
            let graph = Graph::build(topology, sizing.nodes);
            let ds = sizing.datasets.first().cloned().unwrap();
            let partition = sizing.partition.unwrap_or(Partition::Homogeneous);
            let mut spec = sizing.spec_base(&ds, partition);
            spec.algorithm = algorithm;
            spec.verbose = true;
            let listen_addr =
                listen.unwrap_or_else(|| peer_addrs[node].to_string());
            let listener = TcpListener::bind(&listen_addr).map_err(|e| {
                anyhow!("binding {listen_addr}: {e}")
            })?;
            let summary =
                run_net_node(&spec, &graph, node, listener, &peer_addrs, &net)?;
            println!(
                "node {} done: final acc {:.3}, sent {:.0} KB payload \
                 + {:.0} KB wire headers, max lag {} rounds",
                summary.node,
                summary.final_accuracy,
                summary.bytes_sent as f64 / 1024.0,
                summary.header_overhead_bytes as f64 / 1024.0,
                summary.max_staleness
            );
        }
        "ablation-naive" => {
            let sizing = Sizing::from_args(&args);
            check_unknown(&args)?;
            let (engine, manifest) = load(&sizing)?;
            let t = ablations::run_naive_ablation(&engine, &manifest, &sizing)?;
            println!("--- ablation: Eq.11 vs Eq.13 ---\n{}", t.render());
        }
        "ablation-warmup" => {
            let sizing = Sizing::from_args(&args);
            check_unknown(&args)?;
            let (engine, manifest) = load(&sizing)?;
            let t = ablations::run_warmup_ablation(&engine, &manifest, &sizing)?;
            println!("--- ablation: first-epoch dense warmup ---\n{}", t.render());
        }
        "ablation-drift" => {
            let sizing = Sizing::from_args(&args);
            check_unknown(&args)?;
            let (engine, manifest) = load(&sizing)?;
            let t = ablations::run_drift_ablation(&engine, &manifest, &sizing)?;
            println!("--- ablation: client-drift strength ---\n{}", t.render());
        }
        "ablation-wire" => {
            let sizing = Sizing::from_args(&args);
            check_unknown(&args)?;
            let manifest = load_manifest(&sizing)?;
            let t = ablations::run_wire_ablation(&manifest, &sizing)?;
            println!("--- ablation: wire format ---\n{}", t.render());
        }
        "lint" => {
            let root = args.get_str(
                "root",
                concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"),
            );
            check_unknown(&args)?;
            let violations = cecl::analysis::lint_tree(Path::new(&root))
                .map_err(|e| anyhow!("lint walk of {root} failed: {e}"))?;
            for v in &violations {
                println!("{v}");
            }
            if violations.is_empty() {
                println!("repro lint: clean ({root})");
            } else {
                eprintln!("repro lint: {} violation(s)", violations.len());
                std::process::exit(1);
            }
        }
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
        }
        other => {
            eprintln!("unknown command: {other}\n{HELP}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Resolve the algorithm for single-run commands: `--codec SPEC` means
/// C-ECL over that edge codec; combining it with an explicit
/// `--algorithm` is rejected so results are never silently mislabeled.
/// Both spellings of a codec run (`--codec X` and `--algorithm cecl:X`)
/// get the same per-command warmup default, so they build identical
/// experiments.
fn pick_algorithm(args: &Args, sizing: &Sizing,
                  dense_first_epoch: bool) -> Result<AlgorithmSpec> {
    let alg_name = args.get_opt::<String>("algorithm");
    if !sizing.codecs.is_empty() && alg_name.is_some() {
        return Err(anyhow!(
            "--codec and --algorithm are mutually exclusive: --codec \
             always runs C-ECL over the given edge codec (use \
             `--algorithm cecl:<spec>` for the same thing)"
        ));
    }
    if sizing.codecs.len() > 1 {
        return Err(anyhow!(
            "this command runs a single experiment; --codec takes one \
             spec here (comma lists extend the table ladders: \
             `sim --table`, table1/table2)"
        ));
    }
    if let Some(codec) = sizing.codecs.first() {
        return Ok(AlgorithmSpec::CEclCodec {
            codec: codec.clone(),
            theta: 1.0,
            dense_first_epoch,
        });
    }
    let name = alg_name.unwrap_or_else(|| "cecl:0.1".to_string());
    // The algorithm grammar names every offending token itself (broken
    // embedded codec specs, degenerate fractions, θ out of range, …).
    let mut alg = AlgorithmSpec::parse(&name)
        .map_err(|e| anyhow!("--algorithm: {e}"))?;
    if let AlgorithmSpec::CEclCodec { dense_first_epoch: dfe, .. } = &mut alg {
        *dfe = dense_first_epoch;
    }
    Ok(alg)
}

/// Socket-engine transport knobs shared by `launch` and `node`.
fn net_config(args: &Args) -> NetConfig {
    NetConfig {
        connect_timeout: Duration::from_secs(
            args.get("connect-timeout-secs", 10u64),
        ),
        stall_timeout: Duration::from_secs(
            args.get("stall-timeout-secs", 30u64),
        ),
        kill: None,
    }
}

/// Parse `--straggler n:factor[,n:factor...]` into `SimConfig`
/// straggler entries (range and duplicate validation happens in the
/// engine, next to the edge-link checks).
fn parse_stragglers(s: &str) -> Result<Vec<(usize, f64)>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let p = p.trim();
            let (node, factor) = p.split_once(':').ok_or_else(|| {
                anyhow!(
                    "--straggler `{p}`: expected <node>:<factor> \
                     (e.g. 0:8 for an 8x slowdown of node 0)"
                )
            })?;
            Ok((
                node.parse().map_err(|_| {
                    anyhow!("--straggler `{p}`: `{node}` is not a node index")
                })?,
                factor.parse().map_err(|_| {
                    anyhow!("--straggler `{p}`: `{factor}` is not a factor")
                })?,
            ))
        })
        .collect()
}

/// Parse `--churn` (grammar: `cecl::graph::CHURN_GRAMMAR`) plus the
/// `--outage e@from..to[,...]` sugar (an outage is the state-preserving
/// `outage:` item of the same schedule) into one `ChurnSchedule`.
fn parse_churn(churn: &str, outage: &str) -> Result<ChurnSchedule> {
    let mut sched = ChurnSchedule::parse(churn)
        .map_err(|e| anyhow!("--churn: {e}"))?;
    for item in outage.split(',').filter(|p| !p.trim().is_empty()) {
        let rest = format!("outage:{}", item.trim());
        let extra = ChurnSchedule::parse(&rest)
            .map_err(|e| anyhow!("--outage: {e}"))?;
        sched.merge(extra);
    }
    Ok(sched)
}

/// Parse `--edge-link e@spec[,e@spec...]` into per-edge link
/// overrides (spec grammar: `LinkSpec::parse`).
fn parse_edge_links(s: &str) -> Result<Vec<(usize, LinkSpec)>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let p = p.trim();
            let (edge, spec) = p.split_once('@').ok_or_else(|| {
                anyhow!(
                    "--edge-link `{p}`: expected <edge>@<link spec> \
                     (e.g. 0@constant:5000)"
                )
            })?;
            Ok((
                edge.parse().map_err(|_| {
                    anyhow!("--edge-link `{p}`: `{edge}` is not an edge index")
                })?,
                LinkSpec::parse(spec)
                    .map_err(|e| anyhow!("--edge-link `{p}`: {e}"))?,
            ))
        })
        .collect()
}

fn check_unknown(args: &Args) -> Result<()> {
    let unknown = args.unknown_keys();
    if unknown.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("unknown options: {unknown:?}"))
    }
}

fn load_manifest(sizing: &Sizing) -> Result<Manifest> {
    let _ = sizing;
    Manifest::load_default()
}

fn load(sizing: &Sizing) -> Result<(Engine, Manifest)> {
    let manifest = load_manifest(sizing)?;
    let engine = Engine::cpu()?;
    Ok((engine, manifest))
}

const HELP: &str = "\
repro — C-ECL (Takezawa et al. 2022) reproduction

commands:
  table1           accuracy + send/epoch, homogeneous, ring(8)
  table2           accuracy + send/epoch, heterogeneous (8-of-10)
  table3           send/epoch across topologies
  fig1             accuracy curves -> results/fig1_*.csv
  topology --viz   print adjacency (Figure 2)
  theory           Theorem 1 / Corollary 2 rate validation
  train            one run: --algorithm sgd|dpsgd|ecl|cecl:K|powergossip:N
                   |choco:SPEC|lead:SPEC (the compressed-gossip rivals)
                   or --codec SPEC (C-ECL over that edge codec)
  sim              virtual-time run, artifact-free (scales to 1M nodes):
                   --link ideal|constant|bandwidth|lossy --latency-us N
                   --mbit-per-sec F --drop-p F --compute-us-per-step N
                   --threads N (partition-parallel event loop; any N
                   yields the same trajectory bit-for-bit)
                   --straggler n:factor[,...] (per-node compute slowdown)
                   --edge-link e@SPEC[,...]   (heterogeneous per-edge links,
                   SPEC: ideal|constant:LAT|bandwidth:LAT:MBIT|
                   lossy:LAT:MBIT:P)
                   --churn ITEM[,...]         (dynamic topology; ITEM:
                   edge:<e>@<from_ns>..<to_ns> | node:<n>@join:<ns> |
                   node:<n>@leave:<ns> | random:<rate>[:<seed>] |
                   outage:<e>@<from_ns>..<to_ns>; edge/node churn tears
                   down per-edge state, re-adds are fresh edge epochs)
                   --outage e@from..to[,...]  (sugar for outage: items —
                   traffic held, state preserved)
                   --table (time-to-accuracy ladder incl. the codec ladder;
                   with --rounds async:S it sweeps sync vs async, with
                   --churn it sweeps static vs churn, with --heterogeneity
                   dirichlet:A it sweeps the α ladder {A, 1.0, ∞})
                   --target-acc F --codec SPEC[,SPEC...]
  launch           real-socket run: spawns a full localhost TCP
                   deployment in one process (the byte-exact codec
                   frames over a framed wire protocol); artifact-free
                   --verify-bytes (assert per-edge payload bytes match
                   the sim prediction for the same seed)
                   --connect-timeout-secs N --stall-timeout-secs N
  node             one node of a multi-process deployment:
                   --node I --peers ip:port,... (full table, own entry
                   included; all processes share spec + seed)
                   [--listen ip:port] (defaults to own --peers entry)
  ablation-naive   Eq.11 vs Eq.13 dual compression
  ablation-warmup  first-epoch dense on/off
  ablation-wire    explicit-index vs values-only rand-k wire modes
  lint             determinism static analysis over rust/src (CI gate):
                   wall-clock/HashMap/ambient-RNG bans in sim|algorithms
                   |compress|graph, panic+indexing bans in decode/parse
                   paths; suppress with a justified inline allow comment
                   [--root DIR] (exit 1 on any violation)

codec specs (--codec, also `--algorithm cecl:SPEC`):
  identity | rand_k:K | rand_k:K:values | top_k:K | qsgd:B | sign
  | low_rank:R[:iters] | ef+<codec>
                   e.g. rand_k:0.1, qsgd:4, ef+top_k:0.01, low_rank:2
  (non-linear codecs — top_k/qsgd/sign/low_rank/ef — run the Eq. 11
  dual rule; low_rank:R is PowerGossip's compressor on the C-ECL wire,
  byte-identical to powergossip:R per neighbor per round)

round policies (--rounds; async runs on the virtual-time and socket
engines):
  sync             bulk-synchronous rounds (default; pre-async pinned
                   trajectory)
  async:S          per-edge clocks, gossip-style: a node steps once every
                   edge has delivered state at most S rounds stale
                   (PowerGossip runs on per-edge conversation counters)

heterogeneity (--heterogeneity, all run commands; `train` also accepts
the legacy --partition spelling):
  homogeneous      i.i.d. label split (default)
  heterogeneous[:c] paper-style c-of-10 label split (default c = 8)
  dirichlet:A      per-node class proportions ~ Dirichlet(α): A = 0.1 is
                   severe skew, A = 1.0 moderate, large A → homogeneous

common options:
  --dataset fashion|cifar   --epochs N        --nodes N
  --train-per-node N        --test-size N     --eta F
  --local-steps K           --eval-every N    --seed N
  --dual-path native|pjrt   --verbose         --rounds sync|async:S
  --partition homo|hetero   --topology chain|ring|multiplex-ring
                            |fully-connected|star|torus:RxC
                            (torus:RxC is an R x C wrap-around grid and
                            needs exactly R*C nodes, e.g. torus:16x32)
";
