//! Training metrics: per-epoch history, accuracy/loss aggregation, and
//! the communication accounting surfaced in the paper's tables.

use std::fmt;

use crate::util::table::Table;

/// Typed metric-extraction failure.  A run that never reached its
/// accuracy target (a straggler-heavy lossy scenario genuinely may
/// not) or that has no virtual clock is an *outcome*, not a reason to
/// `unwrap`-abort a whole sweep — drivers print `—` for these, and
/// code that requires the value gets a typed error to propagate.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricsError {
    /// `time_to_accuracy` never reached `target`; `best` is the best
    /// accuracy the run did reach.
    TargetNeverReached { target: f64, best: f64 },
    /// The run has no simulated clock (threaded engine).
    NoSimClock,
}

impl fmt::Display for MetricsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MetricsError::TargetNeverReached { target, best } => write!(
                f,
                "accuracy target {target:.3} never reached (best {best:.3})"
            ),
            MetricsError::NoSimClock => {
                write!(f, "run has no simulated clock (threaded engine)")
            }
        }
    }
}

impl std::error::Error for MetricsError {}

/// One evaluation point in a run.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Mean test accuracy over nodes (the paper's Fig. 1 y-axis).
    pub mean_accuracy: f64,
    /// Mean test loss over nodes.
    pub mean_loss: f64,
    /// Mean training loss over nodes since the previous record.
    pub train_loss: f64,
    /// Cumulative mean bytes sent per node.
    pub cum_bytes_per_node: f64,
    /// Virtual time at which the last node completed this epoch
    /// (seconds; 0.0 under the threaded engine, which has no clock).
    pub sim_time_secs: f64,
}

/// Full run history.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub records: Vec<EpochRecord>,
}

impl History {
    pub fn push(&mut self, r: EpochRecord) {
        self.records.push(r);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.records.last().map(|r| r.mean_accuracy).unwrap_or(0.0)
    }

    /// Best (max) accuracy seen — robust to end-of-run noise, mirrors
    /// common reporting practice.
    pub fn best_accuracy(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.mean_accuracy)
            .fold(0.0, f64::max)
    }

    pub fn final_loss(&self) -> f64 {
        self.records.last().map(|r| r.mean_loss).unwrap_or(f64::NAN)
    }

    /// Mean bytes sent per node per epoch over the whole run.
    pub fn bytes_per_node_epoch(&self) -> f64 {
        match self.records.last() {
            Some(last) if last.epoch > 0 => {
                last.cum_bytes_per_node / last.epoch as f64
            }
            _ => 0.0,
        }
    }

    /// Time-to-accuracy: the first evaluation whose mean accuracy
    /// reaches `target`, as `(epoch, sim_time_secs)`.  `None` if the
    /// run never got there.  Under the threaded engine the returned
    /// time is 0.0 (no virtual clock).
    pub fn time_to_accuracy(&self, target: f64) -> Option<(usize, f64)> {
        self.records
            .iter()
            .find(|r| r.mean_accuracy >= target)
            .map(|r| (r.epoch, r.sim_time_secs))
    }

    /// [`History::time_to_accuracy`] for callers that *require* the
    /// target to have been reached: a typed error (with the best
    /// accuracy actually seen) instead of an `Option` to unwrap.
    pub fn require_time_to_accuracy(
        &self,
        target: f64,
    ) -> Result<(usize, f64), MetricsError> {
        self.time_to_accuracy(target)
            .ok_or(MetricsError::TargetNeverReached {
                target,
                best: self.best_accuracy(),
            })
    }

    /// Accuracy series as (epoch, accuracy) pairs (Fig. 1 CSV payload).
    pub fn accuracy_series(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .map(|r| (r.epoch, r.mean_accuracy))
            .collect()
    }

    /// Render the history as a CSV-able table.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new([
            "epoch",
            "mean_accuracy",
            "mean_loss",
            "train_loss",
            "cum_bytes_per_node",
            "sim_time_secs",
        ]);
        for r in &self.records {
            t.row([
                r.epoch.to_string(),
                format!("{:.4}", r.mean_accuracy),
                format!("{:.4}", r.mean_loss),
                format!("{:.4}", r.train_loss),
                format!("{:.0}", r.cum_bytes_per_node),
                format!("{:.4}", r.sim_time_secs),
            ]);
        }
        t
    }
}

/// Running mean accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Mean {
    sum: f64,
    n: usize,
}

impl Mean {
    pub fn add(&mut self, x: f64) {
        self.sum += x;
        self.n += 1;
    }

    pub fn get(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn take(&mut self) -> f64 {
        let v = self.get();
        *self = Mean::default();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(epoch: usize, acc: f64, bytes: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            mean_accuracy: acc,
            mean_loss: 1.0,
            train_loss: 1.0,
            cum_bytes_per_node: bytes,
            sim_time_secs: epoch as f64 * 0.5,
        }
    }

    #[test]
    fn history_aggregates() {
        let mut h = History::default();
        h.push(record(10, 0.5, 1000.0));
        h.push(record(20, 0.8, 2000.0));
        h.push(record(30, 0.7, 3000.0));
        assert_eq!(h.final_accuracy(), 0.7);
        assert_eq!(h.best_accuracy(), 0.8);
        assert!((h.bytes_per_node_epoch() - 100.0).abs() < 1e-12);
        assert_eq!(h.accuracy_series().len(), 3);
        // time-to-accuracy: first record at or above target.
        assert_eq!(h.time_to_accuracy(0.6), Some((20, 10.0)));
        assert_eq!(h.time_to_accuracy(0.4), Some((10, 5.0)));
        assert_eq!(h.time_to_accuracy(0.95), None);
        // The checked form carries the target and the best accuracy.
        assert_eq!(h.require_time_to_accuracy(0.6), Ok((20, 10.0)));
        let err = h.require_time_to_accuracy(0.95).unwrap_err();
        assert_eq!(
            err,
            MetricsError::TargetNeverReached { target: 0.95, best: 0.8 }
        );
        assert!(err.to_string().contains("never reached"), "{err}");
    }

    #[test]
    fn empty_history_is_safe() {
        let h = History::default();
        assert_eq!(h.final_accuracy(), 0.0);
        assert_eq!(h.bytes_per_node_epoch(), 0.0);
        assert!(h.final_loss().is_nan());
    }

    #[test]
    fn mean_accumulator() {
        let mut m = Mean::default();
        assert!(m.get().is_nan());
        m.add(1.0);
        m.add(3.0);
        assert_eq!(m.get(), 2.0);
        assert_eq!(m.count(), 2);
        assert_eq!(m.take(), 2.0);
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn table_render() {
        let mut h = History::default();
        h.push(record(1, 0.25, 10.0));
        let t = h.to_table();
        assert!(t.render().contains("0.2500"));
    }
}
