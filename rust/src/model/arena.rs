//! Structure-of-arrays parameter arena: one contiguous f32 slab holding
//! a family of per-slot vectors (node parameters, dual variables,
//! replica estimates) as fixed-stride rows.
//!
//! The sim engine and the algorithm state machines index rows by the
//! PR-8 CSR slot order (partition-local node index, or neighbor slot),
//! so a partition's round sweep walks the slab linearly instead of
//! chasing one heap box per node.  Rows may have different logical
//! lengths (the stride is the maximum); [`Arena::row`] /
//! [`Arena::row_mut`] return exactly the logical prefix, so all
//! existing length-checked code sees the same slices it saw with
//! `Vec<Vec<f32>>`.
//!
//! The arena is storage only — it never reorders or rescales values —
//! so converting a field from `Vec<Vec<f32>>` to `Arena` is bit-exact
//! by construction.

/// Contiguous slab of `rows` f32 vectors at a fixed stride.
#[derive(Debug, Clone, PartialEq)]
pub struct Arena {
    data: Vec<f32>,
    stride: usize,
    lens: Vec<usize>,
}

impl Arena {
    /// `rows` zero-filled rows, each of logical length `len`.
    pub fn zeros(rows: usize, len: usize) -> Arena {
        Arena {
            data: vec![0.0; rows * len],
            stride: len,
            lens: vec![len; rows],
        }
    }

    /// Pack owned vectors into a slab.  The stride is the longest row;
    /// shorter rows keep their logical length and pad with zeros that
    /// [`Arena::row`] never exposes.
    pub fn from_vecs(rows: Vec<Vec<f32>>) -> Arena {
        let stride = rows.iter().map(|r| r.len()).max().unwrap_or(0);
        let lens: Vec<usize> = rows.iter().map(|r| r.len()).collect();
        let mut data = vec![0.0; rows.len() * stride];
        for (i, r) in rows.iter().enumerate() {
            data[i * stride..i * stride + r.len()].copy_from_slice(r);
        }
        Arena { data, stride, lens }
    }

    /// Unpack back into owned per-row vectors (logical lengths).
    pub fn into_vecs(self) -> Vec<Vec<f32>> {
        self.lens
            .iter()
            .enumerate()
            .map(|(i, &n)| self.data[i * self.stride..i * self.stride + n].to_vec())
            .collect()
    }

    pub fn rows(&self) -> usize {
        self.lens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lens.is_empty()
    }

    /// Fixed row stride in elements (the longest logical row).
    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.stride..i * self.stride + self.lens[i]]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.stride..i * self.stride + self.lens[i]]
    }

    /// The whole slab, padding included — bulk fills and tests.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// The whole slab, padding included — bulk fills and tests.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Set every element (all rows) to `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Iterate rows in slot order.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        (0..self.rows()).map(move |i| self.row(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_layout() {
        let a = Arena::zeros(3, 4);
        assert_eq!(a.rows(), 3);
        assert_eq!(a.stride(), 4);
        assert_eq!(a.row(2), &[0.0; 4]);
        assert_eq!(a.as_slice().len(), 12);
    }

    #[test]
    fn from_vecs_roundtrip_uniform() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut a = Arena::from_vecs(rows.clone());
        assert_eq!(a.rows(), 3);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        a.row_mut(1)[0] = 9.0;
        let out = a.into_vecs();
        assert_eq!(out[1], vec![9.0, 4.0]);
        assert_eq!(out[0], rows[0]);
        assert_eq!(out[2], rows[2]);
    }

    #[test]
    fn ragged_rows_keep_logical_lengths() {
        let a = Arena::from_vecs(vec![vec![1.0], vec![2.0, 3.0, 4.0]]);
        assert_eq!(a.stride(), 3);
        assert_eq!(a.row(0), &[1.0]);
        assert_eq!(a.row(1), &[2.0, 3.0, 4.0]);
        assert_eq!(a.into_vecs(), vec![vec![1.0], vec![2.0, 3.0, 4.0]]);
    }

    #[test]
    fn empty_arena() {
        let a = Arena::from_vecs(Vec::new());
        assert!(a.is_empty());
        assert_eq!(a.rows(), 0);
        assert!(a.into_vecs().is_empty());
    }

    #[test]
    fn fill_and_iter_rows() {
        let mut a = Arena::zeros(2, 3);
        a.fill(7.0);
        let rows: Vec<&[f32]> = a.iter_rows().collect();
        assert_eq!(rows, vec![&[7.0f32; 3][..], &[7.0f32; 3][..]]);
    }
}
