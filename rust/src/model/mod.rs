//! Model metadata on the rust side: the artifact manifest written by
//! `python/compile/aot.py`, the flat-parameter layout (layer names,
//! shapes, offsets), and the per-layer matrix views that PowerGossip
//! compresses.  Also home of the structure-of-arrays [`Arena`] that
//! the sim engine and algorithm state use for parameter/dual storage.

pub mod arena;

pub use arena::Arena;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// One named parameter tensor inside the flat vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl Layer {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }

    /// PowerGossip matrix view: tensors of rank >= 2 are seen as
    /// `(prod(shape[..-1]), shape[-1])` matrices; rank-1 tensors (biases,
    /// GN scales) have no view and are exchanged dense.
    pub fn matrix_view(&self) -> Option<(usize, usize)> {
        if self.shape.len() >= 2 {
            let cols = *self.shape.last().unwrap();
            Some((self.size() / cols, cols))
        } else {
            None
        }
    }
}

/// Manifest entry for one dataset-scale model.
#[derive(Debug, Clone)]
pub struct DatasetManifest {
    pub name: String,
    pub d: usize,
    pub d_pad: usize,
    /// (height, width, channels)
    pub input: (usize, usize, usize),
    pub classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub train_step: PathBuf,
    pub eval_step: PathBuf,
    pub dual_update: PathBuf,
    pub init_w: PathBuf,
    pub layers: Vec<Layer>,
}

impl DatasetManifest {
    pub fn sample_len(&self) -> usize {
        self.input.0 * self.input.1 * self.input.2
    }

    /// Load the initial flat parameter vector (little-endian f32[d_pad]).
    pub fn load_init_w(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.init_w)
            .with_context(|| format!("reading {:?}", self.init_w))?;
        if bytes.len() != 4 * self.d_pad {
            bail!(
                "{:?}: expected {} bytes, got {}",
                self.init_w,
                4 * self.d_pad,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Matrix views for PowerGossip: `(name, offset, rows, cols)`.
    pub fn matrix_views(&self) -> Vec<(String, usize, usize, usize)> {
        self.layers
            .iter()
            .filter_map(|l| {
                l.matrix_view()
                    .map(|(r, c)| (l.name.clone(), l.offset, r, c))
            })
            .collect()
    }

    /// Rank-1 tensors: `(name, offset, len)` — exchanged dense by
    /// PowerGossip.
    pub fn vector_views(&self) -> Vec<(String, usize, usize)> {
        self.layers
            .iter()
            .filter(|l| l.matrix_view().is_none())
            .map(|l| (l.name.clone(), l.offset, l.size()))
            .collect()
    }

    /// In-memory manifest for the artifact-free linear (softmax) model
    /// used by the virtual-time engine's native backend: a
    /// `sample_len × classes` weight matrix (a PowerGossip matrix view)
    /// plus a `classes` bias (a rank-1 view), no padding, no artifact
    /// files.  `d = (h·w·c + 1) · classes`.
    pub fn synthetic_linear(
        name: &str,
        input: (usize, usize, usize),
        classes: usize,
        batch: usize,
        eval_batch: usize,
    ) -> DatasetManifest {
        let sample_len = input.0 * input.1 * input.2;
        let d = (sample_len + 1) * classes;
        DatasetManifest {
            name: name.to_string(),
            d,
            d_pad: d,
            input,
            classes,
            batch,
            eval_batch,
            train_step: PathBuf::new(),
            eval_step: PathBuf::new(),
            dual_update: PathBuf::new(),
            init_w: PathBuf::new(),
            layers: vec![
                Layer {
                    name: "w".to_string(),
                    shape: vec![sample_len, classes],
                    offset: 0,
                },
                Layer {
                    name: "b".to_string(),
                    shape: vec![classes],
                    offset: sample_len * classes,
                },
            ],
        }
    }
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub smoke: PathBuf,
    pub datasets: BTreeMap<String, DatasetManifest>,
}

impl Manifest {
    /// Parse the manifest and resolve artifact paths relative to `dir`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Default artifact dir: `$CECL_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("CECL_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
        let version = lines
            .next()
            .ok_or_else(|| anyhow!("empty manifest"))?;
        if version != "version 1" {
            bail!("unsupported manifest version: {version:?}");
        }
        let mut smoke = None;
        let mut datasets = BTreeMap::new();
        let mut current: Option<DatasetManifest> = None;

        for line in lines {
            let mut parts = line.split_whitespace();
            let key = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            let arg = |i: usize| -> Result<&str> {
                rest.get(i)
                    .copied()
                    .ok_or_else(|| anyhow!("manifest line {line:?}: missing arg {i}"))
            };
            let num = |i: usize| -> Result<usize> {
                arg(i)?
                    .parse()
                    .with_context(|| format!("manifest line {line:?}"))
            };
            match key {
                "smoke" => smoke = Some(dir.join(arg(0)?)),
                "dataset" => {
                    if current.is_some() {
                        bail!("manifest: nested dataset block");
                    }
                    current = Some(DatasetManifest {
                        name: arg(0)?.to_string(),
                        d: 0,
                        d_pad: 0,
                        input: (0, 0, 0),
                        classes: 0,
                        batch: 0,
                        eval_batch: 0,
                        train_step: PathBuf::new(),
                        eval_step: PathBuf::new(),
                        dual_update: PathBuf::new(),
                        init_w: PathBuf::new(),
                        layers: Vec::new(),
                    });
                }
                "end" => {
                    let mut ds = current
                        .take()
                        .ok_or_else(|| anyhow!("manifest: stray `end`"))?;
                    // Compute layer offsets and validate totals.
                    let mut offset = 0;
                    for l in &mut ds.layers {
                        l.offset = offset;
                        offset += l.size();
                    }
                    if offset != ds.d {
                        bail!(
                            "dataset {}: layer sizes sum to {offset}, d={}",
                            ds.name,
                            ds.d
                        );
                    }
                    if ds.d_pad < ds.d {
                        bail!("dataset {}: d_pad < d", ds.name);
                    }
                    datasets.insert(ds.name.clone(), ds);
                }
                _ => {
                    let ds = current
                        .as_mut()
                        .ok_or_else(|| anyhow!("manifest: {key:?} outside dataset"))?;
                    match key {
                        "d" => ds.d = num(0)?,
                        "d_pad" => ds.d_pad = num(0)?,
                        "input" => ds.input = (num(0)?, num(1)?, num(2)?),
                        "classes" => ds.classes = num(0)?,
                        "batch" => ds.batch = num(0)?,
                        "eval_batch" => ds.eval_batch = num(0)?,
                        "train_step" => ds.train_step = dir.join(arg(0)?),
                        "eval_step" => ds.eval_step = dir.join(arg(0)?),
                        "dual_update" => ds.dual_update = dir.join(arg(0)?),
                        "init_w" => ds.init_w = dir.join(arg(0)?),
                        "layer" => {
                            let name = arg(0)?.to_string();
                            let shape: Vec<usize> = rest[1..]
                                .iter()
                                .map(|s| s.parse())
                                .collect::<std::result::Result<_, _>>()
                                .with_context(|| format!("layer {line:?}"))?;
                            if shape.is_empty() {
                                bail!("layer {name}: empty shape");
                            }
                            ds.layers.push(Layer {
                                name,
                                shape,
                                offset: 0,
                            });
                        }
                        _ => bail!("manifest: unknown key {key:?}"),
                    }
                }
            }
        }
        if current.is_some() {
            bail!("manifest: unterminated dataset block");
        }
        Ok(Manifest {
            smoke: smoke.ok_or_else(|| anyhow!("manifest: no smoke artifact"))?,
            datasets,
        })
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetManifest> {
        self.datasets.get(name).ok_or_else(|| {
            anyhow!(
                "dataset {name:?} not in manifest (have: {:?})",
                self.datasets.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1
smoke smoke.hlo.txt
dataset tiny
d 14
d_pad 16
input 2 2 1
classes 3
batch 4
eval_batch 8
train_step ts.hlo.txt
eval_step ev.hlo.txt
dual_update du.hlo.txt
init_w init.bin
layer conv_w 2 2 1 2
layer conv_b 2
layer dense_w 2 2
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.smoke, PathBuf::from("/a/smoke.hlo.txt"));
        let ds = m.dataset("tiny").unwrap();
        assert_eq!(ds.d, 14);
        assert_eq!(ds.d_pad, 16);
        assert_eq!(ds.input, (2, 2, 1));
        assert_eq!(ds.layers.len(), 3);
        assert_eq!(ds.layers[0].offset, 0);
        assert_eq!(ds.layers[1].offset, 8);
        assert_eq!(ds.layers[2].offset, 10);
        assert_eq!(ds.sample_len(), 4);
    }

    #[test]
    fn matrix_views_skip_rank1() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        let ds = m.dataset("tiny").unwrap();
        let views = ds.matrix_views();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0], ("conv_w".to_string(), 0, 4, 2));
        assert_eq!(views[1], ("dense_w".to_string(), 10, 2, 2));
        let vecs = ds.vector_views();
        assert_eq!(vecs, vec![("conv_b".to_string(), 8, 2)]);
    }

    #[test]
    fn rejects_bad_totals() {
        let bad = SAMPLE.replace("d 14", "d 99");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_stray_end() {
        let bad = SAMPLE.replace("classes 3", "classez 3");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
        assert!(Manifest::parse("version 1\nend\n", Path::new("/a")).is_err());
    }

    #[test]
    fn unknown_dataset_lookup_fails() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert!(m.dataset("nope").is_err());
    }

    #[test]
    fn synthetic_linear_layout() {
        let ds = DatasetManifest::synthetic_linear("sim", (4, 4, 1), 10, 8, 16);
        assert_eq!(ds.sample_len(), 16);
        assert_eq!(ds.d, 17 * 10);
        assert_eq!(ds.d_pad, ds.d);
        let views = ds.matrix_views();
        assert_eq!(views, vec![("w".to_string(), 0, 16, 10)]);
        let vecs = ds.vector_views();
        assert_eq!(vecs, vec![("b".to_string(), 160, 10)]);
        // Offsets + sizes tile d exactly.
        let total: usize = ds.layers.iter().map(|l| l.size()).sum();
        assert_eq!(total, ds.d);
    }

    #[test]
    fn real_manifest_when_built() {
        // Validates against the actual artifacts when present.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return; // run `make artifacts` to enable
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["fashion", "cifar"] {
            let ds = m.dataset(name).unwrap();
            assert!(ds.d > 0 && ds.d_pad >= ds.d && ds.d_pad % 1024 == 0);
            assert!(ds.train_step.exists());
            assert!(ds.eval_step.exists());
            assert!(ds.dual_update.exists());
            let w = ds.load_init_w().unwrap();
            assert_eq!(w.len(), ds.d_pad);
            assert!(w.iter().all(|v| v.is_finite()));
        }
    }
}
