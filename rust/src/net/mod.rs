//! The third execution engine: real sockets.
//!
//! The threaded bus and the virtual-time sim are in-process stand-ins;
//! this module drives the exact same `algorithms::NodeStateMachine`s
//! over actual TCP streams — the byte-exact codec `Frame` wire format
//! promoted to a length-prefixed binary protocol ([`wire`]).  Three
//! layers:
//!
//! * [`wire`] — the framed protocol: 24-byte header (magic / version /
//!   kind / src / epoch / round / payload length) + payload, with the
//!   header bytes metered apart from payload bytes so the paper's
//!   payload accounting stays engine-comparable;
//! * [`runtime`] (crate-private) — per-node mesh rendezvous over
//!   `TcpListener`/`TcpStream`, one reader thread per neighbor, and a
//!   round pump that mirrors the sim's delivery admission, so a sync
//!   run is byte- *and* trajectory-identical to the simulator while
//!   `--rounds async:<s>` runs event-driven off real arrivals — the
//!   first async execution off the simulator;
//! * this module — the deployment layer: [`run_net_native`] spawns a
//!   whole localhost deployment in one process (one OS thread + one
//!   listener per node) and aggregates a standard
//!   [`Report`](crate::coordinator::Report); [`run_net_node`] runs a
//!   single node against explicit peer addresses (the `repro node`
//!   multi-process path).
//!
//! Fault model: a peer that vanishes without the protocol's `Bye`
//! (crash, kill -9, reset) maps onto the PR-5 churn lifecycle — the
//! typed `CommError` kills the edge in the local `TopologyView`, buffered
//! frames drain as churn drops, and the machine gets the same
//! `on_topology` teardown a simulated churn event delivers — so a
//! deployment survives node loss instead of deadlocking.  The
//! [`NetConfig::kill`] hook injects exactly that fault for tests.

pub mod wire;

pub(crate) mod runtime;

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, ensure, Result};

use crate::algorithms::{build_machine, BuildCtx, DualPath};
use crate::comm::Meter;
use crate::coordinator::{build_schedule, native_input, ExperimentSpec, Report,
                         NATIVE_SIM_BATCH};
use crate::data::{build_node_datasets, Dataset, SyntheticSpec};
use crate::graph::Graph;
use crate::metrics::{EpochRecord, History, Mean};
use crate::model::DatasetManifest;
use crate::sim::{LocalUpdate, Schedule, SoftmaxLocal};

use runtime::{connect_mesh, NetNodeRuntime, NodeOutcome};

/// Socket-engine knobs (transport only — the experiment itself is the
/// same [`ExperimentSpec`] the other engines take).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Mesh rendezvous budget: how long dials retry and accepts wait
    /// while peers come up.
    pub connect_timeout: Duration,
    /// How long a round may sit with no traffic before the node calls
    /// the deployment wedged (a crashed peer closes its socket and is
    /// handled; a *hung* peer is only caught by this).
    pub stall_timeout: Duration,
    /// Fault injection: `(node, round)` makes that node slam its
    /// sockets shut (no `Bye`) right after that round's `round_end` —
    /// crash semantics for the churn-lifecycle tests.
    pub kill: Option<(usize, usize)>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            connect_timeout: Duration::from_secs(10),
            stall_timeout: Duration::from_secs(30),
            kill: None,
        }
    }
}

/// Per-node result of a multi-process [`run_net_node`] run (the
/// aggregated `Report` lives with whoever launched the processes).
#[derive(Debug, Clone)]
pub struct NodeRunSummary {
    pub node: usize,
    /// Payload bytes this node sent (first-copy, headers excluded).
    pub bytes_sent: u64,
    /// Wire framing overhead this node sent.
    pub header_overhead_bytes: u64,
    pub max_staleness: usize,
    /// This node's own accuracy at the last eval boundary.
    pub final_accuracy: f64,
}

/// Everything the deployment shares, derived once from the spec.
struct Prep {
    ds: DatasetManifest,
    sched: Schedule,
    trains: Vec<Dataset>,
    test: Arc<Dataset>,
    init_w: Vec<f32>,
    classes: usize,
}

fn prepare(spec: &ExperimentSpec, graph: &Graph) -> Result<Prep> {
    ensure!(
        spec.algorithm.is_decentralized(),
        "net engine: {} is not decentralized — a socket deployment needs \
         nodes that exchange (use the threaded or sim engine for SGD)",
        spec.algorithm.name()
    );
    ensure!(
        graph.n() == spec.nodes,
        "net engine: graph has {} nodes, spec expects {}",
        graph.n(),
        spec.nodes
    );
    let classes = 10;
    let ds = DatasetManifest::synthetic_linear(
        &spec.dataset,
        native_input(&spec.dataset),
        classes,
        NATIVE_SIM_BATCH,
        NATIVE_SIM_BATCH,
    );
    let sched = build_schedule(spec, spec.train_per_node, ds.batch)?;
    let (h, w, c) = ds.input;
    let data_spec = SyntheticSpec::for_dataset(
        &spec.dataset, h, w, c, classes, spec.seed,
    );
    let (trains, test) = build_node_datasets(
        &data_spec,
        spec.partition,
        spec.nodes,
        spec.train_per_node,
        spec.test_size,
    );
    Ok(Prep {
        init_w: vec![0.0f32; ds.d_pad],
        ds,
        sched,
        trains,
        test: Arc::new(test),
        classes,
    })
}

/// Build one node's protocol machine + local numerics — identical
/// construction to the sim's native path, which is what makes the
/// cross-engine byte/trajectory identity hold.
fn build_protocol(
    spec: &ExperimentSpec,
    graph: &Arc<Graph>,
    prep: &Prep,
    node: usize,
    train: Dataset,
) -> Result<(Box<dyn crate::algorithms::NodeStateMachine>,
             Box<dyn LocalUpdate>)> {
    let ctx = BuildCtx {
        node,
        graph: Arc::clone(graph),
        manifest: prep.ds.clone(),
        seed: spec.seed,
        eta: spec.eta,
        local_steps: spec.local_steps,
        rounds_per_epoch: prep.sched.rounds_per_epoch,
        dual_path: DualPath::Native,
        runtime: None,
        round_policy: spec.rounds,
    };
    let machine = build_machine(&spec.algorithm, &ctx)?;
    let local: Box<dyn LocalUpdate> = Box::new(SoftmaxLocal::new(
        node,
        train,
        Arc::clone(&prep.test),
        prep.classes,
        spec.seed,
        spec.eta,
        NATIVE_SIM_BATCH,
        spec.local_steps,
    )?);
    Ok((machine, local))
}

enum EvalMsg {
    /// `(node, epoch, accuracy, loss, train_loss)`.
    Eval(usize, usize, f64, f64, f64),
    /// The node stopped reporting (killed or failed): stop waiting on
    /// its eval slots.
    Dead(usize),
}

/// Run a whole localhost deployment in one process: one listener, one
/// worker thread, and one socket runtime per node, all loopback TCP.
/// The artifact-free softmax backend supplies the numerics (like
/// `run_simulated_native`), so this needs no PJRT and no network beyond
/// `127.0.0.1`.
pub fn run_net_native(spec: &ExperimentSpec, graph: &Graph,
                      net: &NetConfig) -> Result<Report> {
    let t0 = std::time::Instant::now();
    let prep = prepare(spec, graph)?;
    let graph = Arc::new(graph.clone());
    let n = spec.nodes;
    if let Some((node, round)) = net.kill {
        ensure!(node < n, "net: kill target {node} out of range");
        ensure!(
            round < prep.sched.total_rounds(),
            "net: kill round {round} is past the schedule"
        );
    }

    // Bind every listener before spawning anything, so the full address
    // table exists up front and rendezvous cannot race the launcher.
    let mut listeners = Vec::with_capacity(n);
    let mut addrs: Vec<SocketAddr> = Vec::with_capacity(n);
    for node in 0..n {
        let l = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| anyhow!("net: binding node {node} listener: {e}"))?;
        addrs.push(l.local_addr()?);
        listeners.push(l);
    }

    let meter = Meter::with_edges(n, graph.edges().len());
    let abort = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<EvalMsg>();

    let mut history = History::default();
    let mut outcomes: Vec<NodeOutcome> = Vec::new();
    let sched = &prep.sched;
    let prep_ref = &prep;
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (node, (listener, train)) in
            listeners.into_iter().zip(prep_ref.trains.iter().cloned()).enumerate()
        {
            let tx = tx.clone();
            let graph = Arc::clone(&graph);
            let meter = Arc::clone(&meter);
            let abort = Arc::clone(&abort);
            let addrs = addrs.clone();
            handles.push(s.spawn(move || -> Result<NodeOutcome> {
                let mut on_eval =
                    |epoch: usize, acc: f64, loss: f64, tl: f64| -> Result<()> {
                        tx.send(EvalMsg::Eval(node, epoch, acc, loss, tl))
                            .map_err(|_| anyhow!("collector closed"))
                    };
                let kill_after = match net.kill {
                    Some((k, r)) if k == node => Some(r),
                    _ => None,
                };
                let res = (|| -> Result<NodeOutcome> {
                    let (machine, local) =
                        build_protocol(spec, &graph, prep_ref, node, train)?;
                    let links = connect_mesh(node, &graph, listener, &addrs,
                                             &meter, net.connect_timeout)?;
                    let rt = NetNodeRuntime::new(
                        node,
                        Arc::clone(&graph),
                        links,
                        Arc::clone(&meter),
                        spec.rounds,
                        net.stall_timeout,
                        Arc::clone(&abort),
                    );
                    rt.run(machine, local, prep_ref.init_w.clone(), sched,
                           kill_after, &mut on_eval)
                })();
                match &res {
                    Ok(o) if o.killed => {
                        let _ = tx.send(EvalMsg::Dead(node));
                    }
                    Ok(_) => {}
                    Err(_) => {
                        // Unblock siblings waiting on a round this node
                        // will never finish.
                        abort.store(true, Ordering::Relaxed);
                        let _ = tx.send(EvalMsg::Dead(node));
                    }
                }
                res
            }));
        }
        drop(tx);

        // Collector: per-epoch slots keyed by node, means taken in node
        // order (bit-deterministic); a dead node's slots stop counting.
        type Slot = Vec<Option<(f64, f64, f64)>>;
        let mut pending: BTreeMap<usize, Slot> = BTreeMap::new();
        let mut dead = vec![false; n];
        let mut done = 0usize;
        let expected = sched.eval_rounds.len();
        let mut complete_ready =
            |pending: &mut BTreeMap<usize, Slot>, dead: &[bool],
             history: &mut History, done: &mut usize| {
                loop {
                    let Some((&epoch, slots)) = pending.iter().next() else {
                        return;
                    };
                    let full = slots
                        .iter()
                        .enumerate()
                        .all(|(i, s)| s.is_some() || dead[i]);
                    if !full {
                        return;
                    }
                    let slots = pending.remove(&epoch).expect("just observed");
                    let (mut a, mut l, mut t) =
                        (Mean::default(), Mean::default(), Mean::default());
                    let mut reporting = 0usize;
                    for sv in slots.into_iter().flatten() {
                        a.add(sv.0);
                        l.add(sv.1);
                        t.add(sv.2);
                        reporting += 1;
                    }
                    if reporting > 0 {
                        let rec = EpochRecord {
                            epoch,
                            mean_accuracy: a.take(),
                            mean_loss: l.take(),
                            train_loss: t.take(),
                            cum_bytes_per_node: meter.mean_bytes_per_node(),
                            sim_time_secs: 0.0,
                        };
                        if spec.verbose {
                            println!(
                                "[net:{}] epoch {:>4}: acc {:.3} loss {:.3} \
                                 train {:.3} sent/node {:.0} KB ({} nodes)",
                                spec.algorithm.name(),
                                rec.epoch,
                                rec.mean_accuracy,
                                rec.mean_loss,
                                rec.train_loss,
                                rec.cum_bytes_per_node / 1024.0,
                                reporting
                            );
                        }
                        history.push(rec);
                    }
                    *done += 1;
                }
            };
        while done < expected {
            match rx.recv() {
                Ok(EvalMsg::Eval(node, epoch, acc, loss, tl)) => {
                    let entry = pending
                        .entry(epoch)
                        .or_insert_with(|| vec![None; n]);
                    entry[node] = Some((acc, loss, tl));
                    complete_ready(&mut pending, &dead, &mut history, &mut done);
                }
                Ok(EvalMsg::Dead(node)) => {
                    dead[node] = true;
                    // A death may complete epochs that were only waiting
                    // on this node's slot.
                    complete_ready(&mut pending, &dead, &mut history, &mut done);
                }
                Err(_) => break, // all workers exited (possibly with error)
            }
        }
        for h in handles {
            outcomes.push(
                h.join().map_err(|_| anyhow!("net: node thread panicked"))??,
            );
        }
        Ok(())
    })?;

    let total_bytes = meter.total_bytes();
    Ok(Report {
        algorithm: spec.algorithm.name(),
        dataset: spec.dataset.clone(),
        partition: spec.partition.name(),
        topology: "graph".to_string(),
        final_accuracy: history.final_accuracy(),
        best_accuracy: history.best_accuracy(),
        history,
        mean_bytes_per_epoch: total_bytes as f64 / n as f64
            / spec.epochs as f64,
        total_bytes,
        retransmit_bytes: 0,
        sim_time_secs: None,
        max_staleness: outcomes
            .iter()
            .map(|o| o.max_staleness)
            .max()
            .unwrap_or(0),
        edges_churned: meter.edges_churned(),
        frames_dropped_by_churn: meter.churn_dropped_frames(),
        header_overhead_bytes: meter.total_header_overhead_bytes(),
        edge_payload_bytes: meter.edge_payload_bytes().unwrap_or_default(),
        wallclock_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Run exactly one node of a deployment in this process, rendezvousing
/// with peers at explicit socket addresses — the `repro node` path for
/// real multi-process (and, with routable addresses, multi-host)
/// deployments.  Every process must be started with the same spec and
/// the same full address table; data partitions are derived
/// deterministically from the shared seed, so no coordinator is needed.
pub fn run_net_node(
    spec: &ExperimentSpec,
    graph: &Graph,
    node: usize,
    listener: TcpListener,
    peer_addrs: &[SocketAddr],
    net: &NetConfig,
) -> Result<NodeRunSummary> {
    ensure!(node < spec.nodes, "net: node {node} out of range");
    ensure!(
        peer_addrs.len() == spec.nodes,
        "net: address table has {} entries for {} nodes",
        peer_addrs.len(),
        spec.nodes
    );
    let mut prep = prepare(spec, graph)?;
    let graph = Arc::new(graph.clone());
    let train = prep.trains.swap_remove(node);
    let (machine, local) = build_protocol(spec, &graph, &prep, node, train)?;
    let meter = Meter::with_edges(spec.nodes, graph.edges().len());
    let links = connect_mesh(node, &graph, listener, peer_addrs, &meter,
                             net.connect_timeout)?;
    let rt = NetNodeRuntime::new(
        node,
        Arc::clone(&graph),
        links,
        Arc::clone(&meter),
        spec.rounds,
        net.stall_timeout,
        Arc::new(AtomicBool::new(false)),
    );
    let mut final_accuracy = f64::NAN;
    let verbose = spec.verbose;
    let mut on_eval = |epoch: usize, acc: f64, loss: f64, tl: f64| -> Result<()> {
        final_accuracy = acc;
        if verbose {
            println!(
                "[net node {node}] epoch {epoch:>4}: acc {acc:.3} \
                 loss {loss:.3} train {tl:.3}"
            );
        }
        Ok(())
    };
    let outcome = rt.run(machine, local, prep.init_w.clone(), &prep.sched,
                         None, &mut on_eval)?;
    Ok(NodeRunSummary {
        node,
        bytes_sent: meter.bytes_sent(node),
        header_overhead_bytes: meter.header_overhead_bytes(node),
        max_staleness: outcome.max_staleness,
        final_accuracy,
    })
}
